"""KV-cache mechanics: ring wraparound, trash slots, window masking."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import build_model
from repro.models.layers import TRASH_SLOTS, make_attention_cache, _INVALID_POS


def test_cache_allocates_trash_slots():
    cfg = get_smoke("granite-8b")
    cache = make_attention_cache(cfg, 2, 32)
    assert cache["k"].shape[1] == 32 + TRASH_SLOTS
    assert (np.asarray(cache["pos"]) == _INVALID_POS).all()


def test_window_ring_wraparound_matches_full_forward(rng):
    """A sliding-window model decoded past the window length must agree with
    its own full forward pass (ring reuse must not corrupt attention)."""
    cfg = dataclasses.replace(get_smoke("granite-8b"), dtype="float32",
                              sliding_window=8)
    model = build_model(cfg)
    params = model.init(rng)
    B, S = 1, 24   # 3x window
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 3,
                                cfg.vocab_size)

    full_logits, _ = model.forward(params, {"tokens": tokens})

    cache = model.init_cache(params, B, 1024)
    assert cache["layers"]["k"].shape[2] == 8 + TRASH_SLOTS  # ring == window
    got = []
    for t in range(S):
        lg, cache = model.decode(params, tokens[:, t:t + 1],
                                 jnp.full((B, 1), t, jnp.int32), cache)
        got.append(lg[:, 0])
    got = jnp.stack(got, 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


def test_rollback_then_rewrite_is_consistent(rng):
    """Spec-decode style: write K speculative tokens, roll the index back,
    rewrite different tokens at the same positions — the final logits must
    equal a straight-line decode of the committed sequence."""
    cfg = dataclasses.replace(get_smoke("granite-8b"), dtype="float32")
    model = build_model(cfg)
    params = model.init(rng)
    B = 1
    committed = jax.random.randint(jax.random.PRNGKey(1), (B, 12), 3,
                                   cfg.vocab_size)
    junk = jax.random.randint(jax.random.PRNGKey(2), (B, 4), 3,
                              cfg.vocab_size)

    # path A: prefill 8, speculate 4 junk tokens at 8..11, roll back,
    # then decode the real tokens 8..11
    cache = model.init_cache(params, B, 64)
    _, cache = model.prefill(params, committed[:, :8], cache)
    pos = jnp.arange(8, 12, dtype=jnp.int32)[None]
    _, cache_j = model.decode(params, junk, pos, cache)
    cache_j = dict(cache_j)
    cache_j["index"] = jnp.full((B,), 8, jnp.int32)   # rollback
    lg_a, _ = model.decode(params, committed[:, 8:12], pos, cache_j)

    # path B: straight-line
    cache2 = model.init_cache(params, B, 64)
    _, cache2 = model.prefill(params, committed[:, :8], cache2)
    lg_b, _ = model.decode(params, committed[:, 8:12], pos, cache2)

    np.testing.assert_allclose(np.asarray(lg_a), np.asarray(lg_b),
                               rtol=1e-5, atol=1e-5)


def test_moe_capacity_drops_overflow(rng):
    """Tokens routed past expert capacity must fall into the spill row and
    contribute zero (not corrupt other tokens)."""
    import repro.models.layers as L
    from repro.configs.base import ModelConfig
    cfg = ModelConfig(family="moe", d_model=16, n_experts=2, top_k=1,
                      expert_d_ff=32, capacity_factor=0.01, dtype="float32")
    p = L.init_moe(cfg, rng)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 16))
    out, aux = L.apply_moe(cfg, p, x)
    assert out.shape == x.shape
    assert jnp.isfinite(out).all()
    # capacity 8 (minimum) of 64 tokens -> most outputs are exactly zero
    zero_rows = (jnp.abs(out[0]).sum(-1) == 0).sum()
    assert int(zero_rows) >= 40


def test_moe_aux_loss_balanced_router():
    """A perfectly uniform router gives the minimal aux loss (== 1)."""
    import repro.models.layers as L
    from repro.configs.base import ModelConfig
    cfg = ModelConfig(family="moe", d_model=8, n_experts=4, top_k=2,
                      expert_d_ff=16, dtype="float32")
    p = L.init_moe(cfg, jax.random.PRNGKey(0))
    p = dict(p, router=jnp.zeros_like(p["router"]))  # uniform logits
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 8))
    _, aux = L.apply_moe(cfg, p, x)
    assert abs(float(aux) - 1.0) < 0.05
