"""Paged KV cache: block pool round-trips, rollback-as-truncate parity,
pool-headroom admission, and the paged Pallas decode-attention kernel.

The invariant under test (docs/ARCHITECTURE.md): a paged cache holding the
same committed tokens as a dense cache must produce identical logits — for
prefill, decode, speculative write + rollback, and full serving runs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.configs.base import ModelConfig
from repro.core import EngineConfig, IndependentDrafter
from repro.models import build_model
from repro.models.paging import (BlockPool, PagedCacheConfig, full_tables,
                                 used_blocks)
from repro.serving import Request, SamplingParams, ServerConfig, SpecServer


# ---------------------------------------------------------------------------
# Host-side pool
# ---------------------------------------------------------------------------

def test_block_pool_alloc_free_roundtrip():
    pool = BlockPool(9)                 # block 0 is trash: 8 allocatable
    assert pool.available == 8
    a = pool.alloc(3)
    b = pool.alloc(5)
    assert pool.available == 0
    assert sorted(a + b) == list(range(1, 9))   # trash never handed out
    assert pool.alloc(1) is None                # exhausted: all-or-nothing
    pool.free(a)
    assert pool.available == 3
    c = pool.alloc(2)
    assert set(c) <= set(a)                     # freed blocks recirculate
    with pytest.raises(ValueError):
        pool.free(a[:1] if a[0] not in c else a[-1:])  # double free refused
    with pytest.raises(ValueError):
        pool.free([0])                          # trash is unfreeable


def test_blocks_for_and_used_blocks():
    pc = PagedCacheConfig(block_size=16, n_blocks=8)
    assert pc.blocks_for(1) == 1
    assert pc.blocks_for(16) == 1
    assert pc.blocks_for(17) == 2
    assert pc.max_blocks(100) == 7
    assert used_blocks(33, 16) == 3


# ---------------------------------------------------------------------------
# Model-level parity: paged cache == dense cache on identical history
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dense_model(rng):
    cfg = dataclasses.replace(get_smoke("granite-8b"), dtype="float32")
    model = build_model(cfg)
    return cfg, model, model.init(rng)


def _paged_cache(model, params, batch, max_len, bs=8):
    pc = PagedCacheConfig(block_size=bs,
                          n_blocks=1 + batch * (-(-max_len // bs)))
    cache = model.init_cache(params, batch, max_len, paged=pc)
    return model.assign_blocks(cache, jnp.ones((batch,), bool),
                               full_tables(batch, pc.max_blocks(max_len)))


def test_paged_prefill_decode_matches_dense(dense_model):
    cfg, model, params = dense_model
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 3,
                              cfg.vocab_size)
    dense = model.init_cache(params, B, 64)
    paged = _paged_cache(model, params, B, 64)
    lg_d, dense = model.prefill(params, toks[:, :8], dense)
    lg_p, paged = model.prefill(params, toks[:, :8], paged)
    np.testing.assert_allclose(np.asarray(lg_d), np.asarray(lg_p),
                               rtol=1e-5, atol=1e-5)
    for t in range(8, S):
        pos = jnp.full((B, 1), t, jnp.int32)
        ld, dense = model.decode(params, toks[:, t:t + 1], pos, dense)
        lp, paged = model.decode(params, toks[:, t:t + 1], pos, paged)
        np.testing.assert_allclose(np.asarray(ld), np.asarray(lp),
                                   rtol=1e-5, atol=1e-5)


def test_paged_rollback_as_truncate_parity(dense_model):
    """Spec-decode rollback: write K junk drafts, rewind the index (the
    device half of the block-list truncate — the slot keeps its blocks,
    stale entries are position-masked), rewrite the real tokens.  Final
    logits must equal the dense cache doing the same dance."""
    cfg, model, params = dense_model
    B = 2
    committed = jax.random.randint(jax.random.PRNGKey(1), (B, 12), 3,
                                   cfg.vocab_size)
    junk = jax.random.randint(jax.random.PRNGKey(2), (B, 4), 3,
                              cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(8, 12, dtype=jnp.int32)[None], (B, 4))

    def spec_dance(cache):
        _, cache = model.prefill(params, committed[:, :8], cache)
        _, cache = model.decode(params, junk, pos, cache)
        cache = dict(cache)
        cache["index"] = jnp.full((B,), 8, jnp.int32)     # rollback
        lg, _ = model.decode(params, committed[:, 8:12], pos, cache)
        return np.asarray(lg)

    lg_dense = spec_dance(model.init_cache(params, B, 64))
    lg_paged = spec_dance(_paged_cache(model, params, B, 64))
    np.testing.assert_allclose(lg_paged, lg_dense, rtol=1e-5, atol=1e-5)


def test_unmapped_slot_drops_writes(dense_model):
    """A slot whose table rows were never mapped (all trash) must drop its
    KV writes whole: after mapping and writing real tokens, logits must not
    see the earlier write."""
    cfg, model, params = dense_model
    B, S = 1, 6
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 3,
                              cfg.vocab_size)
    pc = PagedCacheConfig(block_size=8, n_blocks=1 + (-(-32 // 8)))
    unmapped = model.init_cache(params, B, 32, paged=pc)       # no blocks
    _, leaked = model.prefill(params, toks, unmapped)          # dropped
    leaked = model.reset_slots(leaked, jnp.ones((B,), bool))
    leaked = model.assign_blocks(leaked, jnp.ones((B,), bool),
                                 full_tables(B, pc.max_blocks(32)))
    fresh = _paged_cache(model, params, B, 32)
    lg_a, _ = model.prefill(params, toks, leaked)
    lg_b, _ = model.prefill(params, toks, fresh)
    np.testing.assert_allclose(np.asarray(lg_a), np.asarray(lg_b),
                               rtol=1e-5, atol=1e-5)


def test_hybrid_attn_subcache_pages(rng):
    """Hybrid targets page their shared-attention sub-cache; the mamba
    recurrent state stays dense (O(1) per slot)."""
    cfg = ModelConfig(name="h", family="hybrid", n_layers=4,
                      hybrid_attn_every=2, d_model=64, n_heads=2,
                      n_kv_heads=2, d_ff=128, ssm_state=16, ssm_head_dim=32,
                      vocab_size=61, dtype="float32")
    model = build_model(cfg)
    params = model.init(rng)
    B = 2
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 10), 3,
                              cfg.vocab_size)
    lg_d, _ = model.prefill(params, toks, model.init_cache(params, B, 32))
    lg_p, _ = model.prefill(params, toks,
                            _paged_cache(model, params, B, 32))
    np.testing.assert_allclose(np.asarray(lg_p), np.asarray(lg_d),
                               rtol=1e-4, atol=1e-4)


def test_ssm_accepts_paged_as_zero_block(rng):
    """Pure-ssm targets route through the paged server with a zero-block
    layout: ``paged`` is accepted and the cache simply carries no
    pool/table leaves — identical to the dense recurrent cache."""
    cfg = dataclasses.replace(get_smoke("xlstm-1.3b"), dtype="float32")
    model = build_model(cfg)
    params = model.init(rng)
    paged = model.init_cache(params, 1, 32, paged=PagedCacheConfig(8, 8))
    dense = model.init_cache(params, 1, 32)
    assert not any("table" in str(p) for p in
                   jax.tree_util.tree_flatten_with_path(paged)[0])
    assert (jax.tree_util.tree_structure(paged)
            == jax.tree_util.tree_structure(dense))


def test_sliding_window_pages_as_block_ring(dense_model):
    """Sliding-window targets page through a window-bounded ring of
    blocks: the table covers min(max_len, window) tokens, not max_len."""
    cfg, model, params = dense_model
    cfg_w = dataclasses.replace(cfg, sliding_window=8)
    cache = build_model(cfg_w).init_cache(params, 1, 32,
                                          paged=PagedCacheConfig(4, 8))
    lay = cache["layers"]
    assert lay["table"].shape[-1] == 2          # ceil(8 / 4) blocks
    # logical positions cover the ring plus trash slots only
    from repro.models.layers import TRASH_SLOTS
    assert lay["pos"].shape[-1] == 2 * 4 + TRASH_SLOTS


# ---------------------------------------------------------------------------
# Paged Pallas decode-attention kernel
# ---------------------------------------------------------------------------

def test_paged_decode_attention_kernel_matches_ref():
    from repro.kernels import ops, ref
    rng = np.random.default_rng(0)
    B, H, Hkv, D, bs, MB = 3, 4, 2, 16, 8, 4
    N = 1 + B * MB
    L = MB * bs
    q = jnp.asarray(rng.normal(size=(B, H, D)), jnp.float32)
    k_pool = jnp.asarray(rng.normal(size=(N, bs, Hkv, D)), jnp.float32)
    v_pool = jnp.asarray(rng.normal(size=(N, bs, Hkv, D)), jnp.float32)
    table = np.array(full_tables(B, MB))
    rng.shuffle(table.reshape(-1))      # physical order != logical order
    table = jnp.asarray(table)
    lens = jnp.asarray([5, 20, 32])
    k_pos = jnp.where(jnp.arange(L)[None] < lens[:, None],
                      jnp.arange(L)[None], -(1 << 30)).astype(jnp.int32)
    q_pos = (lens - 1).astype(jnp.int32)

    out = ops.paged_decode_attention(q, k_pool, v_pool, table, k_pos, q_pos)
    from repro.models.paging import gather_dense_view
    dense = gather_dense_view({"k_pool": k_pool, "v_pool": v_pool,
                               "table": table, "pos": k_pos})
    want = ref.decode_attention_ref(q, dense["k"], dense["v"], dense["pos"],
                                    q_pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Serving: pool-headroom admission
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def serve_setup():
    cfg = dataclasses.replace(get_smoke("granite-8b"), dtype="float32")
    tgt = build_model(cfg)
    d_cfg = ModelConfig(name="d", family="dense", n_layers=1, d_model=64,
                        n_heads=2, n_kv_heads=2, d_ff=128,
                        vocab_size=cfg.vocab_size, dtype="float32")
    drf = build_model(d_cfg)
    return (cfg, tgt, drf, tgt.init(jax.random.PRNGKey(1)),
            drf.init(jax.random.PRNGKey(2)))


def _serve(setup, cache, *, slots=2, pool_blocks=0, n=5, max_tokens=10):
    cfg, tgt, drf, t_params, d_params = setup
    server = SpecServer(
        tgt, IndependentDrafter(drf, k=3, temperature=0.0),
        t_params, d_params,
        EngineConfig(k=3, rule="strict", mode="greedy", temperature=0.0),
        ServerConfig(slots=slots, max_len=96, max_prompt_len=12,
                     cache=cache, block_size=8, pool_blocks=pool_blocks))
    rng = np.random.default_rng(0)
    for i in range(n):
        server.submit(Request(
            uid=i,
            prompt=rng.integers(3, cfg.vocab_size, size=6 + i).astype(np.int32),
            params=SamplingParams(max_tokens=max_tokens)))
    return {r.uid: np.asarray(r.tokens) for r in server.run()}, server


def test_paged_server_matches_dense_server(serve_setup):
    dense, _ = _serve(serve_setup, "dense")
    paged, server = _serve(serve_setup, "paged")
    assert sorted(dense) == sorted(paged)
    for uid in dense:
        np.testing.assert_array_equal(dense[uid], paged[uid],
                                      err_msg=f"uid {uid}")
    # harvest returned every block: the pool drains back to full
    assert server.pool.available == server.pool.n_blocks - 1


def test_pool_exhaustion_defers_admission(serve_setup):
    """A pool holding ~one request's worth of blocks must serialise the
    queue — admission refused until harvest frees blocks — and still serve
    every request with outputs identical to the roomy pool."""
    dense, _ = _serve(serve_setup, "dense")
    # one request needs ceil((11 + 10 + 5)/8) = 4 blocks; 5 usable blocks
    # never fit two requests at once
    tight, server = _serve(serve_setup, "paged", pool_blocks=6)
    for uid in dense:
        np.testing.assert_array_equal(dense[uid], tight[uid],
                                      err_msg=f"uid {uid}")
    assert server.pool.available == server.pool.n_blocks - 1


def test_oversized_request_rejected_at_submit(serve_setup):
    """A request that can NEVER fit the pool must be rejected at submit —
    raising mid-admission would strand same-batch neighbours whose blocks
    were already allocated but whose prefill never ran."""
    cfg, tgt, drf, t_params, d_params = serve_setup
    server = SpecServer(
        tgt, IndependentDrafter(drf, k=3, temperature=0.0),
        t_params, d_params,
        EngineConfig(k=3, rule="strict", mode="greedy", temperature=0.0),
        ServerConfig(slots=1, max_len=96, max_prompt_len=12,
                     cache="paged", block_size=8, pool_blocks=3))
    with pytest.raises(ValueError, match="blocks"):
        server.submit(Request(uid=0,
                              prompt=np.arange(3, 12).astype(np.int32),
                              params=SamplingParams(max_tokens=40)))
    assert not server.queue


def test_paged_admits_more_longctx_slots_than_dense(serve_setup):
    """At equal device KV memory a long-context config (big max_len, short
    actual usage) admits >= 2x the concurrent requests under paging.  The
    capacity arithmetic is checked here; the measured-concurrency version
    lives in benchmarks/serving_throughput.py."""
    cfg, tgt, drf, t_params, d_params = serve_setup
    from repro.models.layers import TRASH_SLOTS
    max_len, bs, dense_slots = 192, 16, 2
    kv_tokens = dense_slots * (max_len + TRASH_SLOTS)
    pool_blocks = kv_tokens // bs
    server = SpecServer(
        tgt, IndependentDrafter(drf, k=3, temperature=0.0),
        t_params, d_params,
        EngineConfig(k=3, rule="strict", mode="greedy", temperature=0.0),
        ServerConfig(slots=16, max_len=max_len, max_prompt_len=12,
                     cache="paged", block_size=bs, pool_blocks=pool_blocks))
    per_req = server._blocks_needed(8, 8)
    paged_concurrent = min(16, (pool_blocks - 1) // per_req)
    assert paged_concurrent >= 2 * dense_slots

    rng = np.random.default_rng(1)
    for i in range(paged_concurrent):
        server.submit(Request(
            uid=i,
            prompt=rng.integers(3, cfg.vocab_size, size=8).astype(np.int32),
            params=SamplingParams(max_tokens=8)))
    server._admit()
    in_flight = sum(r is not None for r in server.slot_req)
    assert in_flight == paged_concurrent      # all admitted at once
    resps = server.run()
    assert sorted(r.uid for r in resps) == list(range(paged_concurrent))
