"""Sharding-plan tests (no multi-device mesh needed: specs are pure data)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_shape, get_smoke, list_archs
from repro.launch.shardplan import cache_specs, rules_for
from repro.models import build_model
from repro.sharding import axis_rules, param_specs
from repro.sharding.rules import single_pod_rules


def test_param_specs_dense():
    cfg = dataclasses.replace(get_smoke("granite-8b"), dtype="float32")
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    rules = single_pod_rules()
    rules["fsdp"] = ("data",)
    rules["fsdp_head"] = ("data",)
    with axis_rules(rules):
        specs = param_specs(params)
    blk = specs["blocks"]
    assert blk["attn"]["wq"] == P(None, "data", "model")   # layer, fsdp, heads
    assert blk["attn"]["wo"] == P(None, "model", "data")
    assert blk["mlp"]["w1"] == P(None, "data", "model")
    assert blk["norm1"]["scale"] == P()
    assert specs["embedding"] == P("model", "data")


def test_param_specs_divisible_16way():
    """Every sharded weight dim must divide by 16 under the single-pod plan
    (uneven shards are legal in GSPMD but we keep the plan clean)."""
    for arch in list_archs():
        cfg = get_config(arch)
        model = build_model(cfg)
        params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        shape = get_shape("decode_32k")
        rules = rules_for(arch, shape, multi_pod=False)
        with axis_rules(rules):
            specs = param_specs(params)
        flat_p, _ = jax.tree_util.tree_flatten_with_path(params)
        flat_s = jax.tree_util.tree_flatten(
            specs, is_leaf=lambda x: isinstance(x, P))[0]
        for (pth, leaf), spec in zip(flat_p, flat_s):
            for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 10):
                if ax == "model":
                    name = "/".join(str(getattr(k, 'key', k)) for k in pth)
                    assert dim % 16 == 0, (arch, name, leaf.shape, spec)


@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
@pytest.mark.parametrize("arch", ["granite-8b", "zamba2-2.7b", "xlstm-1.3b",
                                  "whisper-large-v3", "dbrx-132b"])
def test_cache_specs_structure_matches_cache(arch, shape_name):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    enc = None
    if cfg.family == "audio":
        enc = jax.ShapeDtypeStruct((2, cfg.encoder_seq_len, cfg.d_model),
                                   jnp.float32)
    cache = jax.eval_shape(
        lambda p, f: model.init_cache(p, 2, 128, encoder_frames=f),
        params, enc)
    rules = rules_for(arch, get_shape(shape_name), multi_pod=False)
    specs = cache_specs(cache, rules)
    # same tree structure and every spec rank <= leaf rank
    jax.tree_util.tree_map(
        lambda leaf, sp: None, cache, specs)
    flat_c = jax.tree_util.tree_leaves(cache)
    flat_s = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    for leaf, sp in zip(flat_c, flat_s):
        assert len(sp) <= leaf.ndim, (leaf.shape, sp)


def test_rules_long500k_batch_unsharded():
    rules = rules_for("granite-8b", get_shape("long_500k"), multi_pod=True)
    assert rules["batch"] is None
    assert rules["kv_seq"] == "data"


def test_rules_train_fsdp():
    rules = rules_for("deepseek-67b", get_shape("train_4k"), multi_pod=True)
    assert rules["fsdp"] == ("pod", "data")
    assert rules["batch"] == ("pod", "data")


def test_granite_moe_exceptions():
    rules = rules_for("granite-moe-3b-a800m", get_shape("decode_32k"),
                      multi_pod=False)
    assert rules["experts"] is None   # 40 % 16 != 0
    assert rules["heads"] is None     # 24 % 16 != 0
    assert rules["ff"] == "model"
