"""Observability tests: lifecycle tracing, metrics registry, tick spans.

The telemetry stack (``src/repro/obs/``) must be a pure *observer*: it
reads only host-resident values the harvest poll already transferred, so
turning it on may not add a single device→host transfer, change a single
token, or perturb host-sync counts — asserted below for both the serial
and the pipelined (overlap + admission-ring) tick.  The remaining tests
pin the artifacts: Prometheus text that parses, a Perfetto-loadable
Chrome trace covering the tick phases, a lifecycle JSONL with exactly one
finish per uid, and per-request timestamps that are monotone and
consistent with the harvested token counts.
"""
import dataclasses
import importlib.util
import json
import os

import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.configs.base import ModelConfig
from repro.core import EngineConfig, IndependentDrafter
from repro.core.metrics import itl, ttft
from repro.models import build_model
from repro.obs import (Counter, Gauge, Histogram, MetricsRegistry,
                       RequestTracer, ServerTelemetry, SpanRecorder,
                       chrome_trace_json, prometheus_text)
from repro.obs.export import read_events_jsonl, write_events_jsonl
from repro.serving import Request, SamplingParams, ServerConfig, SpecServer

# the artifact checker doubles as the schema oracle for these tests
_spec = importlib.util.spec_from_file_location(
    "check_trace", os.path.join(os.path.dirname(__file__), os.pardir,
                                "tools", "check_trace.py"))
check_trace = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_trace)


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_smoke("granite-8b"), dtype="float32")
    tgt = build_model(cfg)
    d_cfg = ModelConfig(name="d", family="dense", n_layers=1, d_model=64,
                        n_heads=2, n_kv_heads=2, d_ff=128,
                        vocab_size=cfg.vocab_size, dtype="float32")
    drf = build_model(d_cfg)
    return (cfg, tgt, drf, tgt.init(jax.random.PRNGKey(1)),
            drf.init(jax.random.PRNGKey(2)))


def _requests(cfg, n, seed=17, budgets=(3, 7, 13), plen_hi=13):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(4, plen_hi))
        reqs.append(Request(
            uid=i,
            prompt=rng.integers(3, cfg.vocab_size,
                                size=plen).astype(np.int32),
            params=SamplingParams(max_tokens=int(budgets[i % len(budgets)]))))
    return reqs


def _server(setup, *, telemetry=None, k=3, slots=2, **scfg):
    cfg, tgt, drf, tp, dp = setup
    return SpecServer(
        tgt, IndependentDrafter(drf, k=k, temperature=0.0), tp, dp,
        EngineConfig(k=k, rule="mars", mode="greedy", temperature=0.0),
        ServerConfig(slots=slots, max_len=96, max_prompt_len=12,
                     steps_per_sync=3, **scfg),
        telemetry=telemetry)


def _run(server, reqs):
    for r in reqs:
        server.submit(dataclasses.replace(r))
    out = {r.uid: r for r in server.run()}
    assert sorted(out) == sorted(r.uid for r in reqs)
    return out


# ---------------------------------------------------------------------------
# registry / export units
# ---------------------------------------------------------------------------

def test_registry_instruments():
    reg = MetricsRegistry(namespace="t")
    c = reg.counter("reqs_total", "requests")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("depth")
    g.set(4)
    g.inc(-1)
    assert g.value == 3.0
    h = reg.histogram("lat_seconds", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 0.5, 5.0):
        h.observe(v)
    # cumulative le-semantics: <=0.1, <=1.0, +Inf
    assert list(h.bucket_counts) == [1, 3, 4]
    assert h.count == 4 and h.sum == pytest.approx(6.05)
    assert h.percentile(50) == pytest.approx(0.5)
    # get-or-create: same name -> same object, kind mismatch -> error
    assert reg.counter("reqs_total") is c
    with pytest.raises(TypeError):
        reg.gauge("reqs_total")
    assert [m.name for m in reg.metrics()] == \
        ["t_reqs_total", "t_depth", "t_lat_seconds"]


def test_histogram_window_ring():
    h = Histogram("h", window=4)
    for v in range(10):
        h.observe(float(v))
    assert h.count == 10
    assert sorted(h.window_values()) == [6.0, 7.0, 8.0, 9.0]


def test_prometheus_text_parses():
    tel = ServerTelemetry(annotate=False)
    tel.on_submit(0, prompt_len=8, max_tokens=4)
    tel.on_admitted(0, 1, theta=0.9)
    tel.on_first_commit(0, 2)
    tel.on_finish(0, n_tokens=4, n_cycles=2, n_accepted=3, n_relaxed=1,
                  margin_ema=0.7, theta=0.9, blocks_held=2)
    tel.on_sync(queue_depth=0, slots_active=1, inflight=0, margin_mean=0.7)
    text = prometheus_text(tel.registry)
    assert check_trace.check_prometheus(text) == []
    assert "mars_requests_finished_total 1" in text
    assert 'mars_ttft_seconds_bucket{le="+Inf"} 1' in text


def test_prometheus_checker_catches_rot():
    # the oracle itself must reject broken exposition, or the round-trip
    # test above proves nothing
    assert check_trace.check_prometheus("mars_oops_total 1\n")
    assert check_trace.check_prometheus(
        "# TYPE mars_h histogram\n"
        'mars_h_bucket{le="1.0"} 5\nmars_h_bucket{le="+Inf"} 3\n'
        "mars_h_sum 1.0\nmars_h_count 3\n")


def test_chrome_trace_schema(tmp_path):
    rec = SpanRecorder(annotate=False)
    with rec.span("harvest", flush=False):
        with rec.span("gather", slots=2):
            pass
    rec.counter("inflight_snapshots", 2)
    doc = json.loads(chrome_trace_json(rec))
    assert check_trace.check_chrome_trace(
        doc, require_spans=("harvest", "gather")) == []
    assert check_trace.check_chrome_trace(doc, require_spans=("retune",))
    # the file is plain JSON Perfetto/chrome://tracing can open
    p = tmp_path / "trace.json"
    p.write_text(chrome_trace_json(rec))
    assert json.loads(p.read_text())["traceEvents"]


def test_events_jsonl_roundtrip(tmp_path):
    tr = RequestTracer()
    tr.on_submit(7, prompt_len=5, max_tokens=3)
    tr.on_admitted(7, 0, theta=0.85)
    tr.on_finish(7, n_tokens=3, n_cycles=1, n_accepted=2, n_relaxed=0,
                 margin_ema=0.0, theta=0.85, blocks_held=0)
    path = str(tmp_path / "events.jsonl")
    write_events_jsonl(tr.events, path)
    with open(path) as f:
        lines = f.readlines()
    assert check_trace.check_events_jsonl(lines) == []
    back = read_events_jsonl(path)
    assert [e["event"] for e in back] == ["submit", "admitted", "finish"]
    assert back[-1]["n_tokens"] == 3 and back[-1]["ttft_s"] is not None


def test_ttft_itl_helpers():
    assert ttft(1.0, 3.5) == pytest.approx(2.5)
    assert ttft(None, 3.5) is None and ttft(1.0, None) is None
    assert ttft(3.0, 2.0) == 0.0          # clamped, never negative
    assert itl(2.0, 6.0, 8) == pytest.approx(0.5)
    assert itl(2.0, 6.0, 0) is None       # no tokens after first commit
    assert itl(None, 6.0, 4) is None


# ---------------------------------------------------------------------------
# server integration: the observer may not perturb the system
# ---------------------------------------------------------------------------

def test_token_parity_and_lifecycle(setup):
    """Fixed-theta serial serve with telemetry on vs off: identical tokens,
    identical host syncs; every trace monotone submit <= admitted <=
    first_commit <= finish with token counts matching the responses."""
    reqs = _requests(setup[0], 8)
    off = _server(setup)
    base = _run(off, reqs)
    tel = ServerTelemetry(annotate=False)
    on = _server(setup, telemetry=tel)
    out = _run(on, reqs)
    for uid in base:
        np.testing.assert_array_equal(out[uid].tokens, base[uid].tokens,
                                      err_msg=f"req {uid}")
    assert on.host_syncs == off.host_syncs

    traces = {t.uid: t for t in tel.finished_traces()}
    assert sorted(traces) == sorted(r.uid for r in reqs)
    for uid, t in traces.items():
        assert t.submit_s <= t.admitted_s <= t.first_commit_s <= t.finish_s
        assert t.n_tokens == len(out[uid].tokens)
        assert t.ttft_s is not None and t.ttft_s >= 0
        assert t.latency_s == pytest.approx(t.finish_s - t.submit_s)
        if t.itl_s is not None:           # needs >= 2 harvest observations
            span = t.finish_s - t.first_commit_s
            after = t.n_tokens - t.tokens_at_first_commit
            assert t.itl_s == pytest.approx(span / after)
        assert 0 < t.n_accepted + t.n_cycles   # device stats rode the poll
    assert int(tel.tokens.value) == sum(len(r.tokens) for r in out.values())
    # multi-sync budgets (13 > steps_per_sync * (k+1) is false here, but
    # budget 13 spans several cycles) must yield at least one real ITL
    assert any(t.itl_s is not None for t in traces.values())


@pytest.mark.parametrize("variant", [
    pytest.param(dict(), id="serial"),
    pytest.param(dict(overlap=True, ring_depth=3, cache="paged"),
                 id="overlap-ring"),
])
def test_zero_extra_transfers(setup, variant):
    """Telemetry must ride the polls the server already pays for: the
    device_get call count AND host-sync count are identical on vs off."""
    reqs = _requests(setup[0], 8, seed=23)
    real = jax.device_get
    counts = {}
    try:
        for label, tel in (("off", None),
                           ("on", ServerTelemetry(annotate=False))):
            n = 0

            def counting(*a, **kw):
                nonlocal n
                n += 1
                return real(*a, **kw)

            srv = _server(setup, telemetry=tel, **variant)
            jax.device_get = counting
            _run(srv, reqs)
            jax.device_get = real
            counts[label] = (n, srv.host_syncs)
    finally:
        jax.device_get = real
    assert counts["on"] == counts["off"], counts


def test_overlap_stats_peek_stays_device_free(setup):
    """Satellite: ``SpecServer.stats`` under overlap reads the newest
    already-harvested snapshot — no device poll, no drained pipeline."""
    reqs = _requests(setup[0], 8, seed=31)
    srv = _server(setup, overlap=True, ring_depth=3, cache="paged")
    for r in reqs:
        srv.submit(dataclasses.replace(r))
    real = jax.device_get

    def forbidden(*a, **kw):
        raise AssertionError("stats peek touched the device")

    saw_pending = False
    for _ in range(10_000):
        if (not srv.queue and all(r is None for r in srv.slot_req)
                and not srv._pending and not srv._ring_staged):
            break
        srv._admit()
        srv.step()
        pending_before = len(srv._pending)
        syncs_before = srv.host_syncs
        jax.device_get = forbidden
        try:
            with jax.transfer_guard_device_to_host("disallow"):
                stats = srv.stats
        finally:
            jax.device_get = real
        assert srv.host_syncs == syncs_before
        assert len(srv._pending) == pending_before   # pipeline not drained
        saw_pending = saw_pending or pending_before > 0
        for key in ("cycles", "commits", "slot_idle_ticks"):
            assert key in stats
        srv.sync()
    if srv._pending:
        srv.sync(flush=True)
    assert saw_pending                               # peek ran mid-pipeline
    assert len(srv.run()) == len(reqs)


def test_spans_cover_tick_phases(setup):
    tel = ServerTelemetry(annotate=False)
    _run(_server(setup, telemetry=tel), _requests(setup[0], 6))
    names = tel.spans.span_names()
    for phase in ("admit", "dispatch", "harvest", "gather"):
        assert phase in names, names
    doc = json.loads(chrome_trace_json(tel.spans))
    assert check_trace.check_chrome_trace(
        doc, require_spans=("admit", "dispatch", "harvest")) == []


def test_adaptive_retunes_and_theta_path(setup):
    """Under the adaptive controller the retune span appears, the retune
    counter moves, and traces record the theta trajectory starting at the
    admission theta."""
    tel = ServerTelemetry(annotate=False)
    srv = _server(setup, telemetry=tel, theta_mode="adaptive",
                  overlap=True, ring_depth=3, cache="paged")
    _run(srv, _requests(setup[0], 10, seed=41, budgets=(9, 13, 17)))
    assert "retune" in tel.spans.span_names()
    assert tel.retunes.value > 0
    traces = tel.finished_traces()
    assert all(t.theta_path for t in traces)
    assert any(len(t.theta_path) > 1 for t in traces)   # a retune landed
    for t in traces:
        for ts, th in t.theta_path:
            assert t.admitted_s <= ts <= t.finish_s + 1e-9
            assert 0.0 < th <= 1.0
    # ring-staged lifecycles: staged strictly before seated
    staged = [t for t in traces if t.staged_via_ring and t.staged_s]
    assert staged
    assert all(t.staged_s <= t.admitted_s for t in staged)
    assert tel.ring_staged.value == len(staged)


def test_cancel_queued_request(setup):
    tel = ServerTelemetry(annotate=False)
    srv = _server(setup, telemetry=tel, slots=1)
    reqs = _requests(setup[0], 3, seed=47)
    for r in reqs:
        srv.submit(dataclasses.replace(r))
    assert srv.cancel(1)                   # still queued (1 slot, 3 reqs)
    assert not srv.cancel(99)              # unknown uid
    out = {r.uid: r for r in srv.run()}
    assert sorted(out) == [0, 2]
    assert tel.canceled.value == 1
    tr = tel.tracer.traces[1]
    assert tr.cancel_s is not None and tr.finish_s is None
    assert [e for e in tel.tracer.events
            if e["event"] == "cancel"][0]["uid"] == 1


def test_server_artifacts_validate(setup, tmp_path):
    """End-to-end: run a server, write all three artifacts, and hold them
    against the same schema checks the CI smoke leg runs."""
    tel = ServerTelemetry(annotate=False)
    out = _run(_server(setup, telemetry=tel), _requests(setup[0], 6, seed=53))
    m, t, e = (str(tmp_path / n) for n in ("m.prom", "t.json", "e.jsonl"))
    tel.write(m, t, e)
    with open(m) as f:
        assert check_trace.check_prometheus(f.read()) == []
    with open(t) as f:
        assert check_trace.check_chrome_trace(
            json.load(f), require_spans=("admit", "dispatch", "harvest")) == []
    with open(e) as f:
        assert check_trace.check_events_jsonl(f) == []
    finishes = [ev for ev in read_events_jsonl(e) if ev["event"] == "finish"]
    assert sorted(ev["uid"] for ev in finishes) == sorted(out)
    s = tel.summary()
    assert s["finished"] == len(out) and s["span_events"] > 0
