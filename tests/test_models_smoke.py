"""Per-architecture smoke tests: a REDUCED same-family variant runs one
forward and one train step on CPU; output shapes asserted, no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke, list_archs
from repro.models import build_model
from repro.optim import adamw
from repro.train import make_train_step

B, S = 2, 16


def _batch(cfg, rng, seq=S):
    tokens = jax.random.randint(rng, (B, seq), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.family == "audio":
        batch["encoder_frames"] = jax.random.normal(
            rng, (B, cfg.encoder_seq_len, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_forward_shapes_no_nan(arch, rng):
    cfg = dataclasses.replace(get_smoke(arch), dtype="float32")
    model = build_model(cfg)
    params = model.init(rng)
    batch = _batch(cfg, rng)
    logits, aux = jax.jit(lambda p, b: model.forward(p, b))(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert jnp.isfinite(logits).all()
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch", list_archs())
def test_decode_matches_forward(arch, rng):
    """Prefill+decode must reproduce the full-sequence forward logits.

    MoE capacity depends on the token count per call, so capacity is raised
    until nothing drops — token dropping is the one legitimate divergence
    between chunked decode and full forward."""
    cfg = dataclasses.replace(get_smoke(arch), dtype="float32",
                              capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(rng)
    batch = _batch(cfg, rng)
    tokens = batch["tokens"]

    full_logits, _ = model.forward(params, batch)

    cache = model.init_cache(params, B, 64,
                             encoder_frames=batch.get("encoder_frames"))
    lg1, cache = model.prefill(params, tokens[:, :S - 2], cache)
    pos = cache["index"][:, None] + jnp.arange(2)[None]
    lg2, cache = model.decode(params, tokens[:, S - 2:], pos, cache)

    got = jnp.concatenate([lg1, lg2], axis=1)
    # recurrent chunked paths accumulate differently; tolerance is loose-ish
    assert jnp.allclose(got, full_logits, rtol=2e-3, atol=2e-3), (
        jnp.abs(got - full_logits).max())


@pytest.mark.parametrize("arch", list_archs())
def test_one_train_step(arch, rng):
    cfg = dataclasses.replace(get_smoke(arch), dtype="float32")
    model = build_model(cfg)
    params = model.init(rng)
    batch = _batch(cfg, rng, seq=S + 1)
    tx = adamw(1e-3)
    step = jax.jit(make_train_step(model, tx))
    opt = tx.init(params)
    params, opt, metrics = step(params, opt, batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    assert all(jnp.isfinite(x).all() for x in jax.tree.leaves(params))


@pytest.mark.parametrize("arch", ["granite-8b", "zamba2-2.7b", "xlstm-1.3b",
                                  "whisper-large-v3"])
def test_masked_decode_is_noop(arch, rng):
    """A fully-masked decode must not change logits of later real decodes."""
    cfg = dataclasses.replace(get_smoke(arch), dtype="float32")
    model = build_model(cfg)
    params = model.init(rng)
    batch = _batch(cfg, rng)
    tokens = batch["tokens"]
    enc = batch.get("encoder_frames")

    cache_a = model.init_cache(params, B, 64, encoder_frames=enc)
    _, cache_a = model.prefill(params, tokens[:, :8], cache_a)
    cache_b = jax.tree.map(lambda x: x, cache_a)

    # apply a masked (no-op) decode to cache_b
    junk = jnp.full((B, 3), 5, jnp.int32)
    pos = cache_b["index"][:, None] + jnp.arange(3)[None]
    _, cache_b = model.decode(params, junk, pos, cache_b,
                              token_mask=jnp.zeros((B, 3), bool))
    assert int(cache_b["index"][0]) == int(cache_a["index"][0])

    nxt = tokens[:, 8:9]
    pos_a = cache_a["index"][:, None]
    la, _ = model.decode(params, nxt, pos_a, cache_a)
    lb, _ = model.decode(params, nxt, pos_a, cache_b)
    assert jnp.allclose(la, lb, rtol=1e-5, atol=1e-5)
