import dataclasses

import jax
import pytest

# Tests run on the single real CPU device (the dry-run forces 512 devices in
# its own subprocess only).
jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def f32(cfg):
    return dataclasses.replace(cfg, dtype="float32")
