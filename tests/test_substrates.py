"""Optimizer / data / checkpoint / trainer substrate tests."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.data import ByteTokenizer, MarkovCorpus, make_lm_batches
from repro.optim import adamw, apply_updates
from repro.optim.schedule import cosine_schedule


def test_adamw_minimises_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros((3,))}
    tx = adamw(0.1, weight_decay=0.0)
    state = tx.init(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"] - target))

    for _ in range(200):
        g = jax.grad(loss)(params)
        upd, state = tx.update(g, state, params)
        params = apply_updates(params, upd)
    assert float(loss(params)) < 1e-2


def test_cosine_schedule_shape():
    s = cosine_schedule(1.0, 10, 100)
    assert float(s(jnp.asarray(0))) == 0.0
    assert abs(float(s(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(s(jnp.asarray(100))) <= 0.11
    assert float(s(jnp.asarray(55))) < float(s(jnp.asarray(15)))


def test_byte_tokenizer_roundtrip():
    tok = ByteTokenizer()
    text = "MARS: margin-aware vérification ✓"
    ids = tok.encode(text, bos=True, eos=True)
    assert ids[0] == tok.BOS and ids[-1] == tok.EOS
    assert tok.decode(ids) == text


def test_markov_corpus_entropy_knob():
    lo = MarkovCorpus(vocab_size=32, temperature=0.3, seed=1)
    hi = MarkovCorpus(vocab_size=32, temperature=2.0, seed=1)
    ent = lambda p: -(p * np.log(np.maximum(p, 1e-12))).sum(-1).mean()
    assert ent(hi._probs) > ent(lo._probs) + 0.3


def test_lm_batches_shapes():
    corpus = MarkovCorpus(vocab_size=16, seed=0)
    batches = list(make_lm_batches(corpus, batch=4, seq_len=32, n_batches=3))
    assert len(batches) == 3
    assert batches[0]["tokens"].shape == (4, 33)
    assert batches[0]["tokens"].max() < 16


def test_checkpoint_roundtrip():
    tree = {"a": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
            "b": [jnp.ones((4,), jnp.int32), jnp.zeros((2,), jnp.float32)]}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 7, tree)
        assert latest_step(d) == 7
        out = load_checkpoint(d, 7, jax.tree.map(np.asarray, tree))
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_trainer_reduces_loss(rng):
    import dataclasses
    from repro.configs import get_smoke
    from repro.models import build_model
    from repro.train import Trainer, TrainerConfig

    cfg = dataclasses.replace(get_smoke("granite-8b"), dtype="float32",
                              vocab_size=32)
    model = build_model(cfg)
    params = model.init(rng)
    corpus = MarkovCorpus(vocab_size=32, temperature=0.7, seed=0)
    trainer = Trainer(model, TrainerConfig(lr=3e-3, warmup_steps=5,
                                           total_steps=40, log_every=10))
    params, hist = trainer.fit(
        params, make_lm_batches(corpus, batch=8, seq_len=32, n_batches=40),
        log=lambda s: None)
    # 40 CPU steps: expect a clear but not dramatic decrease
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.05
