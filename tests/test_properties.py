"""Property-based tests (hypothesis) on system invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import verify as V
from repro.models.layers import blockwise_attention

SET = dict(max_examples=20, deadline=None)


@settings(**SET)
@given(
    b=st.integers(1, 3),
    k=st.integers(1, 8),
    v=st.integers(5, 200),
    theta=st.floats(0.5, 0.99),
    seed=st.integers(0, 2**31 - 1),
)
def test_verify_chain_invariants(b, k, v, theta, seed):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.standard_normal((b, k + 1, v)) * 2, jnp.float32)
    draft = jnp.asarray(rng.integers(0, v, (b, k)), jnp.int32)
    key = jax.random.PRNGKey(seed % 1000)

    strict = V.verify_chain(draft, logits, rule="strict", mode="greedy",
                            key=key)
    mars = V.verify_chain(draft, logits, rule="mars", mode="greedy",
                          theta=theta, key=key)

    for res in (strict, mars):
        n_a, n_c = np.asarray(res.n_accept), np.asarray(res.n_commit)
        assert ((0 <= n_a) & (n_a <= k)).all()
        assert (n_c == n_a + 1).all()
        out = np.asarray(res.out_tokens)
        d = np.asarray(draft)
        for i in range(b):
            # accepted prefix must equal the draft prefix
            np.testing.assert_array_equal(out[i, :n_a[i]], d[i, :n_a[i]])

    # MARS (greedy base) accepts a superset of strict accepts
    assert (np.asarray(mars.n_accept) >= np.asarray(strict.n_accept)).all()
    assert (np.asarray(mars.n_relaxed)
            <= np.asarray(mars.n_accept)).all()


@settings(**SET)
@given(
    theta=st.floats(0.5, 0.99),
    seed=st.integers(0, 2**31 - 1),
)
def test_relaxation_iff_margin_condition(theta, seed):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.standard_normal((4, 6, 50)) * 3, jnp.float32)
    draft = jnp.asarray(rng.integers(0, 50, (4, 6)), jnp.int32)
    relax = np.asarray(V.mars_relax_mask(draft, logits, theta))
    vals, idx = jax.lax.top_k(logits, 2)
    z1, z2 = np.asarray(vals[..., 0]), np.asarray(vals[..., 1])
    expected = (np.asarray(draft) == np.asarray(idx[..., 1])) \
        & (z1 > 0) & (z2 > 0) & (z2 / np.maximum(z1, 1e-30) > theta)
    np.testing.assert_array_equal(relax, expected)


@settings(**SET)
@given(
    b=st.integers(1, 2),
    t=st.integers(1, 5),
    s=st.integers(4, 40),
    hkv=st.sampled_from([1, 2]),
    g=st.sampled_from([1, 3]),
    d=st.sampled_from([8, 16]),
    chunk=st.sampled_from([4, 16, 64]),
    window=st.sampled_from([0, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_blockwise_attention_matches_naive(b, t, s, hkv, g, d, chunk, window,
                                           seed):
    """Chunked online-softmax attention == naive masked softmax attention,
    for any chunking — the invariant every attention path relies on."""
    rng = np.random.default_rng(seed)
    h = hkv * g
    q = jnp.asarray(rng.standard_normal((b, t, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    q_pos = jnp.tile(jnp.arange(s - t, s)[None], (b, 1)).astype(jnp.int32)
    k_pos = jnp.tile(jnp.arange(s)[None], (b, 1)).astype(jnp.int32)

    got = blockwise_attention(q, k, v, q_pos, k_pos, window=window,
                              chunk=chunk)

    # naive reference
    qg = q.reshape(b, t, hkv, g, d)
    scores = jnp.einsum("btkgd,bskd->btkgs", qg, k) / np.sqrt(d)
    valid = (k_pos[:, None, :] >= 0) & (k_pos[:, None, :] <= q_pos[:, :, None])
    if window:
        valid &= k_pos[:, None, :] > (q_pos[:, :, None] - window)
    scores = jnp.where(valid[:, :, None, None, :], scores, -1e9)
    probs = jax.nn.softmax(scores, axis=-1)
    want = jnp.einsum("btkgs,bskd->btkgd", probs, v).reshape(b, t, h, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@settings(**SET)
@given(
    window=st.integers(3, 10),
    bs=st.sampled_from([3, 4]),
    ops=st.lists(st.tuples(st.integers(1, 4), st.integers(0, 4)),
                 min_size=2, max_size=6),
    seed=st.integers(0, 2**31 - 1),
)
def test_windowed_block_ring_wrapped_rewind(window, bs, ops, seed):
    """The sliding-window ring of blocks under arbitrary speculation.

    A paged windowed cache and the dense windowed ring are driven through
    the same random draft/accept/rollback sequence (rollback = index
    rewind: the committed cursor moves back, stale entries stay until
    overwritten).  With ``window % block_size`` free to be nonzero the
    paged ring wraps mid-block — the exact-ring contract.  Every cycle
    ends with the correction token committed at the rewind point (the
    engine's ``n_commit == n_accept + 1``), whose self-key guarantees the
    next read has a valid target.  After every op, reading at the
    committed head must (a) give identical outputs in both layouts and
    (b) never place attention mass outside ``(q_pos - window, q_pos]`` —
    checked exactly by storing one-hot values, so the output IS the
    per-absolute-position attention mass."""
    from repro.models.layers import (TRASH_SLOTS, _INVALID_POS, _cache_write,
                                     blockwise_attention)
    from repro.models.paging import (full_tables, paged_blockwise_attention,
                                     paged_cache_write)

    rng = np.random.default_rng(seed)
    d = 48                                   # >= max absolute position
    ring = window                            # max_len far above the window
    mb = -(-ring // bs)

    dense = {
        "k": jnp.zeros((1, ring + TRASH_SLOTS, 1, d), jnp.float32),
        "v": jnp.zeros((1, ring + TRASH_SLOTS, 1, d), jnp.float32),
        "pos": jnp.full((1, ring + TRASH_SLOTS), _INVALID_POS, jnp.int32),
    }
    paged = {
        "k_pool": jnp.zeros((1 + mb, bs, 1, d), jnp.float32),
        "v_pool": jnp.zeros((1 + mb, bs, 1, d), jnp.float32),
        "table": full_tables(1, mb),
        "pos": jnp.full((1, ring + TRASH_SLOTS), _INVALID_POS, jnp.int32),
        "trash": jnp.zeros((1,), jnp.int32),
    }

    keys = rng.standard_normal((d, 1, d)).astype(np.float32)  # key per pos
    q = jnp.asarray(rng.standard_normal((1, 1, 1, d)), np.float32)

    def write(c, j):
        # j speculative tokens at absolute c..c+j-1 plus one masked lane
        # (position -1 -> trash slot / trash block in either layout)
        pos = np.concatenate([np.arange(c, c + j), [-1]])[None]
        k_new = jnp.asarray(
            np.concatenate([keys[c:c + j], keys[:1]])[None])
        v_new = jnp.asarray(
            np.concatenate([np.eye(d, dtype=np.float32)[c:c + j],
                            np.zeros((1, d), np.float32)])[None, :, None])
        return (_cache_write(dense, k_new, v_new, jnp.asarray(pos)),
                paged_cache_write(paged, k_new, v_new, jnp.asarray(pos)))

    c = 3                                    # committed prompt
    dense, paged = write(0, c)
    for j, a_raw in ops:
        dense, paged = write(c, j)           # draft j tokens
        c += min(a_raw, j)                   # accept a, rewind the rest
        dense, paged = write(c, 1)           # correction token commits
        c += 1
        q_pos = jnp.asarray([[c - 1]], jnp.int32)
        got_d = blockwise_attention(q, dense["k"], dense["v"], q_pos,
                                    dense["pos"], window=window)
        got_p = paged_blockwise_attention(q, paged, q_pos, window=window)
        np.testing.assert_allclose(np.asarray(got_p), np.asarray(got_d),
                                   rtol=1e-5, atol=1e-5)
        # one-hot values: output coord i == mass attending absolute pos i
        mass = np.asarray(got_p)[0, 0, 0]
        in_win = np.zeros((d,), bool)
        in_win[max(0, c - window):c] = True
        assert mass[~in_win].max() < 1e-5, (c, mass)
        np.testing.assert_allclose(mass[in_win].sum(), 1.0, rtol=1e-5)


@settings(**SET)
@given(
    chunk=st.sampled_from([4, 8, 32]),
    s=st.integers(5, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_linear_recurrence_chunking_invariance(chunk, s, seed):
    """chunked_linear_recurrence must give identical results for any chunk
    size (== the sequential recurrence)."""
    from repro.models.ssm import chunked_linear_recurrence, recurrent_step
    rng = np.random.default_rng(seed)
    b, h, n, p = 1, 2, 4, 8
    c = jnp.asarray(rng.standard_normal((b, s, h, n)), jnp.float32)
    bm = jnp.asarray(rng.standard_normal((b, s, h, n)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    log_decay = -jnp.abs(
        jnp.asarray(rng.standard_normal((b, s, h)), jnp.float32)) * 0.2
    scale = jnp.abs(jnp.asarray(rng.standard_normal((b, s, h)), jnp.float32))
    h0 = jnp.asarray(rng.standard_normal((b, h, n, p)), jnp.float32) * 0.1

    y1, s1 = chunked_linear_recurrence(c, bm, v, log_decay, scale,
                                       chunk=chunk, init_state=h0)
    y2, s2 = recurrent_step(c, bm, v, log_decay, scale, h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=3e-4,
                               atol=3e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=3e-4,
                               atol=3e-4)
