"""End-to-end engine tests: the strict-greedy losslessness invariant and
MARS bookkeeping, across attention AND recurrent target families."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.configs.base import ModelConfig
from repro.core import (EngineConfig, IndependentDrafter, PLDrafter,
                        EagleDrafter, MedusaDrafter, init_eagle_params,
                        init_medusa_params, make_ar_generate_fn,
                        make_generate_fn, metrics)
from repro.models import build_model

NEW = 20
K = 4


def _pair(arch, rng):
    cfg = dataclasses.replace(get_smoke(arch), dtype="float32")
    tgt = build_model(cfg)
    d_cfg = ModelConfig(name="tiny-draft", family="dense", n_layers=1,
                        d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
                        vocab_size=cfg.vocab_size, dtype="float32")
    drf = build_model(d_cfg)
    return cfg, tgt, drf, tgt.init(jax.random.PRNGKey(1)), drf.init(
        jax.random.PRNGKey(2))


@pytest.mark.parametrize("arch", ["granite-8b", "dbrx-132b", "xlstm-1.3b",
                                  "zamba2-2.7b", "whisper-large-v3"])
def test_strict_greedy_equals_ar(arch, rng):
    """Lossless invariant: strict greedy spec-decode == greedy AR decode."""
    cfg, tgt, drf, t_params, d_params = _pair(arch, rng)
    if cfg.family == "audio":
        pytest.skip("AR/engine prompt-only path exercised via dense archs; "
                    "whisper decode correctness covered in smoke tests")
    B, S = 2, 8
    prompt = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                                cfg.vocab_size)
    plen = jnp.array([S, S - 2], jnp.int32)

    ar = make_ar_generate_fn(tgt, temperature=0.0)
    out_ar = ar(t_params, prompt, plen, jax.random.PRNGKey(9), max_new=NEW)

    eng = make_generate_fn(tgt, IndependentDrafter(drf, k=K, temperature=0.0),
                           EngineConfig(k=K, rule="strict", mode="greedy",
                                        temperature=0.0))
    out_sd = eng(t_params, d_params, prompt, plen, jax.random.PRNGKey(9),
                 max_new=NEW)

    for b in range(B):
        n = int(plen[b]) + NEW
        np.testing.assert_array_equal(
            np.asarray(out_ar["tokens"])[b, :n],
            np.asarray(out_sd["tokens"])[b, :n])


def test_mars_stats_consistent(rng):
    cfg, tgt, drf, t_params, d_params = _pair("granite-8b", rng)
    B, S = 2, 8
    prompt = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                                cfg.vocab_size)
    plen = jnp.full((B,), S, jnp.int32)
    eng = make_generate_fn(tgt, IndependentDrafter(drf, k=K),
                           EngineConfig(k=K, rule="mars", mode="sample",
                                        temperature=1.0))
    out = eng(t_params, d_params, prompt, plen, jax.random.PRNGKey(0),
              max_new=NEW)
    st = out["stats"]
    # commits per row equal generated length; tau within [1, K+1]
    np.testing.assert_array_equal(
        np.asarray(st["commits"]), np.asarray(out["lengths"] - plen))
    t = metrics.tau(st)
    assert 1.0 <= t <= K + 1
    assert (np.asarray(st["relaxed"]) <= np.asarray(st["accepts"])).all()


def test_eagle_and_medusa_drafters_run(rng):
    cfg, tgt, _, t_params, _ = _pair("granite-8b", rng)
    B, S = 2, 8
    prompt = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                                cfg.vocab_size)
    plen = jnp.full((B,), S, jnp.int32)

    eagle = EagleDrafter(tgt, k=K)
    e_params = init_eagle_params(cfg, jax.random.PRNGKey(7))
    eng = make_generate_fn(tgt, eagle, EngineConfig(k=K, rule="mars",
                                                    mode="greedy",
                                                    temperature=0.0))
    out = eng(t_params, e_params, prompt, plen, jax.random.PRNGKey(0),
              max_new=12)
    assert (np.asarray(out["lengths"]) >= S + 12).all()

    med = MedusaDrafter(tgt, k=3)
    m_params = init_medusa_params(cfg, jax.random.PRNGKey(8), 3)
    eng_m = make_generate_fn(tgt, med, EngineConfig(k=3, rule="mars",
                                                    mode="greedy",
                                                    temperature=0.0))
    out_m = eng_m(t_params, m_params, prompt, plen, jax.random.PRNGKey(0),
                  max_new=12)
    assert (np.asarray(out_m["lengths"]) >= S + 12).all()


def test_pld_copies_repetition(rng):
    """On a perfectly periodic prompt a PLD drafter should reach tau > 1
    whenever the target itself continues the period (forced here by checking
    the drafts, not the target)."""
    cfg, tgt, _, t_params, _ = _pair("granite-8b", rng)
    pld = PLDrafter(k=K, ngram=2)
    buf = jnp.asarray([[5, 6, 7, 8, 5, 6, 7, 8, 5, 6, 0, 0]], jnp.int32)
    extras = {"tokens_buf": buf, "lengths": jnp.asarray([10]),
              "index": jnp.asarray([9])}
    out, _ = pld.draft(None, {}, jnp.asarray([6]), extras,
                       jax.random.PRNGKey(0))
    # trailing 2-gram is (5, 6) at pos 8..9 -> latest earlier match at 4..5,
    # continuation = 7, 8, 5, 6
    np.testing.assert_array_equal(np.asarray(out.tokens[0]), [7, 8, 5, 6])


def test_whisper_engine_with_encoder_frames(rng):
    """Enc-dec target: spec decode conditioned on stub encoder frames."""
    cfg, tgt, drf, t_params, d_params = _pair("whisper-large-v3", rng)
    B, S = 2, 6
    prompt = jax.random.randint(jax.random.PRNGKey(3), (B, S), 3,
                                cfg.vocab_size)
    plen = jnp.full((B,), S, jnp.int32)
    frames = jax.random.normal(jax.random.PRNGKey(5),
                               (B, cfg.encoder_seq_len, cfg.d_model))
    gen = make_generate_fn(tgt, IndependentDrafter(drf, k=K),
                           EngineConfig(k=K, rule="mars", mode="sample"))
    out = gen(t_params, d_params, prompt, plen, jax.random.PRNGKey(0),
              max_new=10, encoder_frames=frames)
    assert (np.asarray(out["lengths"]) >= S + 10).all()
    # frames must actually matter: different frames -> different logits path
    out2 = gen(t_params, d_params, prompt, plen, jax.random.PRNGKey(0),
               max_new=10, encoder_frames=frames * 3.0)
    assert not np.array_equal(np.asarray(out["tokens"]),
                              np.asarray(out2["tokens"]))


def test_eos_truncation(rng):
    cfg, tgt, drf, t_params, d_params = _pair("granite-8b", rng)
    B, S = 1, 6
    prompt = jax.random.randint(jax.random.PRNGKey(3), (B, S), 3,
                                cfg.vocab_size)
    plen = jnp.full((B,), S, jnp.int32)
    # pick the first greedily generated token as "eos" so it must stop at 1
    ar = make_ar_generate_fn(tgt, temperature=0.0)
    first = int(np.asarray(ar(t_params, prompt, plen, jax.random.PRNGKey(0),
                              max_new=1)["tokens"])[0, S])
    eng = make_generate_fn(
        tgt, IndependentDrafter(drf, k=K, temperature=0.0),
        EngineConfig(k=K, rule="strict", mode="greedy", temperature=0.0,
                     eos_token=first))
    out = eng(t_params, d_params, prompt, plen, jax.random.PRNGKey(0),
              max_new=NEW)
    assert bool(out["finished"][0])
    assert int(out["lengths"][0]) == S + 1
    assert int(np.asarray(out["tokens"])[0, S]) == first
