"""Analytic cost-model sanity tests."""
import pytest

from repro.configs import get_config, get_shape
from repro.utils.costs import analytic_bytes, analytic_flops, cache_bytes


def test_train_flops_scale_6nd():
    cfg = get_config("granite-8b")
    shape = get_shape("train_4k")
    f = analytic_flops(cfg, shape)
    tokens = shape.global_batch * shape.seq_len
    lower = 6 * 7e9 * tokens          # 6·N·D ballpark (non-emb params)
    assert f > lower, (f, lower)
    assert f < 6 * 12e9 * tokens * 1.5


def test_decode_flops_2nd():
    cfg = get_config("granite-8b")
    shape = get_shape("decode_32k")
    f = analytic_flops(cfg, shape, verify_tokens=1)
    # ~2·N per token + attention over 32k context
    assert f > 2 * 7e9 * shape.global_batch
    assert f < 2 * 12e9 * shape.global_batch * 2


def test_moe_decode_uses_active_params():
    dbrx = get_config("dbrx-132b")
    shape = get_shape("decode_32k")
    f = analytic_flops(dbrx, shape)
    # active ~36B << total 132B
    assert f < 2 * 60e9 * shape.global_batch * 1.5


def test_window_caps_cache():
    cfg = get_config("granite-8b")
    shape = get_shape("long_500k")
    full = cache_bytes(cfg, shape)
    windowed = cache_bytes(cfg, shape, window=4096)
    assert windowed < full / 100


def test_ssm_state_constant_in_seq():
    cfg = get_config("xlstm-1.3b")
    c1 = cache_bytes(cfg, get_shape("decode_32k"))
    c2 = cache_bytes(cfg, get_shape("long_500k"))
    # state size scales only with batch (128 vs 1), never seq_len
    assert c2 < c1


def test_bytes_decode_dominated_by_params_plus_cache():
    cfg = get_config("deepseek-67b")
    shape = get_shape("decode_32k")
    b = analytic_bytes(cfg, shape)
    params = cfg.param_count() * 2
    cache = cache_bytes(cfg, shape)
    assert abs(b - (params + cache)) / b < 0.05
