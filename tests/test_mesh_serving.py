"""Sharded serving-mesh tests.

The multi-device half runs ``tests/_mesh_serving_main.py`` in a subprocess
with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — host-platform
devices must be forced before jax initialises, so the main test process
(pinned to the single real CPU device, see ``tests/conftest.py``) cannot
host the mesh itself.  The in-process half covers the host-side pieces that
need no devices: the per-shard block pool, spec construction, and the
fail-fast config validation.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_smoke
from repro.core import EngineConfig
from repro.models import build_model
from repro.models.paging import ShardedBlockPool, paged_unsupported_reason
from repro.serving import ServerConfig, SpecServer

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# Per-shard block pool (host side of the partitioned pool)
# ---------------------------------------------------------------------------

def test_sharded_pool_allocates_within_shard_ranges():
    pool = ShardedBlockPool(16, n_shards=2)          # shard ranges [0,8) [8,16)
    assert pool.shard_capacity == 7                  # first block reserved
    a = pool.alloc(3, shard=0)
    b = pool.alloc(3, shard=1)
    assert all(1 <= blk < 8 for blk in a)            # block 0 = trash
    assert all(9 <= blk < 16 for blk in b)           # block 8 reserved
    assert pool.available(0) == 4 and pool.available(1) == 4
    pool.free(a)
    assert pool.available(0) == 7


def test_sharded_pool_exhaustion_is_per_shard():
    pool = ShardedBlockPool(8, n_shards=2)           # 3 usable per shard
    assert pool.alloc(4, shard=0) is None            # too big for one shard
    assert pool.alloc(3, shard=0) is not None
    assert pool.alloc(1, shard=0) is None            # shard 0 empty...
    assert pool.alloc(3, shard=1) is not None        # ...shard 1 unaffected


def test_sharded_pool_rejects_bad_frees():
    pool = ShardedBlockPool(8, n_shards=2)
    with pytest.raises(ValueError, match="invalid/reserved"):
        pool.free([0])                               # trash block
    with pytest.raises(ValueError, match="invalid/reserved"):
        pool.free([4])                               # shard 1's reserved block
    blocks = pool.alloc(2, shard=0)
    pool.free(blocks)
    with pytest.raises(ValueError, match="double free"):
        pool.free(blocks[:1])
    with pytest.raises(ValueError):
        ShardedBlockPool(9, n_shards=2)              # not divisible


# ---------------------------------------------------------------------------
# Fail-fast config validation (no deep init_cache raise)
# ---------------------------------------------------------------------------

def test_every_arch_passes_paged_validation():
    """The stale fail-fast is gone: every family pages (ssm via the
    zero-block layout, sliding-window via the block ring), so
    ``paged_unsupported_reason`` reports support across the whole config
    registry — `tests/test_paged_archs.py` backs this with end-to-end
    parity."""
    from repro.configs import get_config, list_archs
    for arch in list_archs():
        assert paged_unsupported_reason(get_config(arch)) is None, arch


def test_quantized_pool_rejected_on_pure_ssm():
    # the one genuinely unsupported combination left: there is no KV pool
    # on a pure-ssm target, so quantized storage has nothing to quantize
    cfg = dataclasses.replace(get_smoke("xlstm-1.3b"), dtype="float32")
    target = build_model(cfg)
    with pytest.raises(ValueError, match="no attention KV pool"):
        SpecServer(target, None, None, None, EngineConfig(k=2),
                   ServerConfig(slots=2, cache="paged", kv_dtype="int8"))


def test_prefix_cache_rejected_on_sliding_window():
    cfg = dataclasses.replace(get_smoke("granite-8b"), dtype="float32",
                              sliding_window=8)
    target = build_model(cfg)
    with pytest.raises(ValueError) as e:
        SpecServer(target, None, None, None, EngineConfig(k=2),
                   ServerConfig(slots=2, cache="paged", prefix_cache="on"))
    assert "sliding-window" in str(e.value) and cfg.name in str(e.value)


def test_mesh_slots_divisibility_checked_first():
    cfg = dataclasses.replace(get_smoke("granite-8b"), dtype="float32")
    target = build_model(cfg)
    # raised before any mesh/device work, so it runs on the 1-device suite
    with pytest.raises(ValueError, match="divisible by the data axis"):
        SpecServer(target, None, None, None, EngineConfig(k=2),
                   ServerConfig(slots=3, mesh=(2, 1)))


def test_serving_mesh_needs_devices():
    from repro.launch.mesh import make_serving_mesh
    if len(jax.devices()) >= 2:
        pytest.skip("test assumes the single-device suite process")
    with pytest.raises(RuntimeError, match="xla_force_host_platform"):
        make_serving_mesh(2, 1)


# ---------------------------------------------------------------------------
# Carry / pool partition specs (pure data, no mesh needed)
# ---------------------------------------------------------------------------

def test_decode_state_specs_cover_carry_and_paged_pool():
    import jax.numpy as jnp
    import numpy as np
    from repro.core.session import DecodeSession
    from repro.core import IndependentDrafter
    from repro.configs.base import ModelConfig
    from repro.launch.shardplan import decode_state_specs
    from repro.models.paging import PagedCacheConfig
    from repro.sharding import serving_rules

    cfg = dataclasses.replace(get_smoke("granite-8b"), dtype="float32")
    tgt = build_model(cfg)
    d_cfg = ModelConfig(name="d", family="dense", n_layers=1, d_model=64,
                        n_heads=2, n_kv_heads=2, d_ff=128,
                        vocab_size=cfg.vocab_size, dtype="float32")
    drf = build_model(d_cfg)
    session = DecodeSession(tgt, IndependentDrafter(drf, k=2),
                            EngineConfig(k=2))
    t_params = tgt.init(jax.random.PRNGKey(0))
    d_params = drf.init(jax.random.PRNGKey(1))
    state = session.init_state(t_params, d_params, 4, 64,
                               paged=PagedCacheConfig(8, 33))
    specs = decode_state_specs(state, serving_rules())
    assert specs.buf == P("data", None)
    assert specs.finished == P("data")
    assert specs.budget == P("data")
    assert specs.key == P()
    lay = specs.t_cache["layers"]
    assert lay["k_pool"] == P(None, "data", None, "model", None)
    assert lay["table"] == P(None, "data", None)
    # drafter cache rows are slot-indexed too
    assert specs.d_state["cache"]["index"] == P("data")


# ---------------------------------------------------------------------------
# Multi-device parity (subprocess: forced 8 host devices)
# ---------------------------------------------------------------------------

def test_sharded_server_matches_offline_subprocess():
    """Dense AND paged serving on real ≥2-device meshes must be
    token-identical to the single-device offline path, with zero in-tick
    device→host transfers (see tests/_mesh_serving_main.py)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src"), env.get("PYTHONPATH", "")]).rstrip(
        os.pathsep)
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests",
                                      "_mesh_serving_main.py")],
        capture_output=True, text=True, env=env, timeout=900)
    assert proc.returncode == 0, (
        f"mesh parity subprocess failed\nstdout:\n{proc.stdout}\n"
        f"stderr:\n{proc.stderr}")
    assert "MESH-PARITY-OK" in proc.stdout, proc.stdout
