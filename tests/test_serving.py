"""Continuous-batching server tests.

The scheduler's contract (see ``serving/scheduler.py``) is device-resident:
per-request budgets and temperatures live in the ``DecodeState`` carry, the
tick loop performs zero device→host transfers, and the host observes the
carry only at sync points.  The regression tests below pin the serving bugs
the old host-synced scheduler hid: ``max_tokens`` overshoot, ignored
per-request temperature, and the stale pending token on zero-commit cycles.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.configs.base import ModelConfig
from repro.core import EngineConfig, IndependentDrafter
from repro.core.session import DecodeSession
from repro.models import build_model
from repro.serving import Request, SamplingParams, ServerConfig, SpecServer


@pytest.fixture(scope="module")
def server_setup():
    cfg = dataclasses.replace(get_smoke("granite-8b"), dtype="float32")
    tgt = build_model(cfg)
    d_cfg = ModelConfig(name="d", family="dense", n_layers=1, d_model=64,
                        n_heads=2, n_kv_heads=2, d_ff=128,
                        vocab_size=cfg.vocab_size, dtype="float32")
    drf = build_model(d_cfg)
    t_params = tgt.init(jax.random.PRNGKey(1))
    d_params = drf.init(jax.random.PRNGKey(2))
    return cfg, tgt, drf, t_params, d_params


def test_serves_more_requests_than_slots(server_setup):
    cfg, tgt, drf, t_params, d_params = server_setup
    server = SpecServer(
        tgt, IndependentDrafter(drf, k=3), t_params, d_params,
        EngineConfig(k=3, rule="mars", mode="sample", temperature=1.0),
        ServerConfig(slots=2, max_len=96, max_prompt_len=12))
    rng = np.random.default_rng(0)
    n = 5
    for i in range(n):
        server.submit(Request(
            uid=i,
            prompt=rng.integers(3, cfg.vocab_size, size=6).astype(np.int32),
            params=SamplingParams(max_tokens=10)))
    resps = server.run()
    assert len(resps) == n
    assert sorted(r.uid for r in resps) == list(range(n))
    for r in resps:
        assert len(r.tokens) >= 10
        assert r.n_cycles >= 1
        assert 1.0 <= r.tau <= 4.0


def test_slot_isolation(server_setup):
    """A request admitted mid-flight must not change a neighbour's output:
    serve the same prompt alone vs. alongside another request (greedy)."""
    cfg, tgt, drf, t_params, d_params = server_setup

    def serve(prompts, max_tokens=12):
        server = SpecServer(
            tgt, IndependentDrafter(drf, k=3, temperature=0.0),
            t_params, d_params,
            EngineConfig(k=3, rule="strict", mode="greedy", temperature=0.0),
            ServerConfig(slots=2, max_len=96, max_prompt_len=12))
        for i, p in enumerate(prompts):
            server.submit(Request(uid=i, prompt=p,
                                  params=SamplingParams(max_tokens=max_tokens)))
        return {r.uid: r.tokens for r in server.run()}

    rng = np.random.default_rng(7)
    p0 = rng.integers(3, cfg.vocab_size, size=8).astype(np.int32)
    p1 = rng.integers(3, cfg.vocab_size, size=5).astype(np.int32)
    alone = serve([p0])
    both = serve([p0, p1])
    np.testing.assert_array_equal(alone[0], both[0])


def test_max_tokens_budget_exact(server_setup):
    """Responses must never exceed ``max_tokens``.  The old scheduler only
    marked a slot finished *after* the over-producing cycle, so adversarial
    budgets (budget % (K+1) != 0) overshot by up to K tokens; the on-device
    budget clamp stops the commit mid-cycle."""
    cfg, tgt, drf, t_params, d_params = server_setup
    k = 3
    server = SpecServer(
        tgt, IndependentDrafter(drf, k=k), t_params, d_params,
        EngineConfig(k=k, rule="mars", mode="sample", temperature=1.0),
        ServerConfig(slots=2, max_len=96, max_prompt_len=12,
                     steps_per_sync=2))
    rng = np.random.default_rng(3)
    budgets = [7, 5, 9, 1, 6]          # none divisible by K+1 = 4
    for i, mt in enumerate(budgets):
        server.submit(Request(
            uid=i,
            prompt=rng.integers(3, cfg.vocab_size, size=6).astype(np.int32),
            params=SamplingParams(max_tokens=mt)))
    resps = {r.uid: r for r in server.run()}
    assert sorted(resps) == list(range(len(budgets)))
    for i, mt in enumerate(budgets):
        assert len(resps[i].tokens) <= mt
        # no EOS token configured: the budget is the only stop, so the
        # response must hit it exactly
        assert len(resps[i].tokens) == mt


def test_per_request_temperature(server_setup):
    """Per-request ``SamplingParams.temperature`` must reach verification.
    Two slots at T=0.1 vs T=10 against the same random-init pair: the hot
    slot's near-uniform target distribution accepts nearly every draft
    (u·q < p succeeds when p ≈ q), the cold slot's near-argmax distribution
    rejects nearly all of them — measurably different acceptance stats."""
    cfg, tgt, drf, t_params, d_params = server_setup
    server = SpecServer(
        tgt, IndependentDrafter(drf, k=3, temperature=1.0),
        t_params, d_params,
        EngineConfig(k=3, rule="strict", mode="sample", temperature=1.0),
        ServerConfig(slots=2, max_len=128, max_prompt_len=12,
                     steps_per_sync=2))
    rng = np.random.default_rng(5)
    prompt = rng.integers(3, cfg.vocab_size, size=8).astype(np.int32)
    server.submit(Request(uid=0, prompt=prompt.copy(),
                          params=SamplingParams(max_tokens=48,
                                                temperature=0.1)))
    server.submit(Request(uid=1, prompt=prompt.copy(),
                          params=SamplingParams(max_tokens=48,
                                                temperature=10.0)))
    resps = {r.uid: r for r in server.run()}
    tau_cold, tau_hot = resps[0].tau, resps[1].tau
    assert tau_hot > tau_cold + 0.5, (tau_cold, tau_hot)


def test_zero_commit_keeps_pending_token(server_setup):
    """Full-buffer unit test for the stale-pending-token bug: when the
    buffer clamp forces ``n_commit == 0``, the cycle must NOT load
    ``out_tokens[:, 0]`` (garbage for a row that committed nothing) into
    ``last_token``."""
    cfg, tgt, drf, t_params, d_params = server_setup
    session = DecodeSession(
        tgt, IndependentDrafter(drf, k=3, temperature=0.0),
        EngineConfig(k=3, rule="strict", mode="greedy", temperature=0.0))
    s = 12
    rng = np.random.default_rng(11)
    prompt = rng.integers(3, cfg.vocab_size, size=s).astype(np.int32)
    # buffer width s+1 => l_buf == s: the prompt fills the buffer entirely,
    # so the first cycle's buffer clamp forces n_commit == 0 on a live row
    state = session.init_state(t_params, d_params, 1, s)
    state = session.prefill(t_params, d_params, state,
                            jnp.asarray(prompt)[None],
                            jnp.asarray([s], jnp.int32))
    assert not bool(np.asarray(state.finished)[0])
    before = int(np.asarray(state.last_token)[0])
    state = session.cycle(t_params, d_params, state)
    assert int(np.asarray(state.lengths)[0]) == s      # nothing committed
    assert bool(np.asarray(state.finished)[0])         # row closed out
    assert int(np.asarray(state.last_token)[0]) == before


def test_eos_caps_fused_groups(server_setup):
    """With an EOS token configured a slot can finish long before its
    budget, so ``_group_size`` must cap fused groups at ``steps_per_sync``
    instead of fusing all the way to the budget bound — and EOS-terminated
    responses must still respect their budget."""
    cfg, tgt, drf, t_params, d_params = server_setup
    eos = 5
    server = SpecServer(
        tgt, IndependentDrafter(drf, k=3), t_params, d_params,
        EngineConfig(k=3, rule="mars", mode="sample", temperature=1.0,
                     eos_token=eos),
        ServerConfig(slots=2, max_len=96, max_prompt_len=12,
                     steps_per_sync=2))
    rng = np.random.default_rng(23)
    for i in range(4):
        server.submit(Request(
            uid=i,
            prompt=rng.integers(3, cfg.vocab_size, size=6).astype(np.int32),
            params=SamplingParams(max_tokens=40)))
    server._admit()
    # budget bound alone would fuse ceil(40 / 4) = 10 cycles; EOS caps it
    assert server._group_size() == 2
    resps = server.run()
    assert sorted(r.uid for r in resps) == list(range(4))
    for r in resps:
        assert 1 <= len(r.tokens) <= 40
        if len(r.tokens) < 40:          # stopped early => stopped at EOS
            assert r.tokens[-1] == eos


def test_serving_stress_sync_free_matches_offline(server_setup):
    """≥16 requests over 4 slots with mixed prompt lengths, budgets, and
    temperatures: every response must equal offline ``DecodeSession.generate``
    for the same request (greedy), and the tick loop must perform no
    device→host transfer except at sync/harvest (guarded by patching
    ``jax.device_get`` and checking the server's transfer counter)."""
    cfg, tgt, drf, t_params, d_params = server_setup
    k = 3
    ecfg = EngineConfig(k=k, rule="mars", mode="greedy", temperature=0.0)
    server = SpecServer(
        tgt, IndependentDrafter(drf, k=k, temperature=0.0),
        t_params, d_params, ecfg,
        ServerConfig(slots=4, max_len=96, max_prompt_len=12,
                     steps_per_sync=3))
    rng = np.random.default_rng(17)
    reqs = []
    budget_mix = [3, 7, 13]            # all with budget % (K+1) != 0
    for i in range(16):
        plen = int(rng.integers(4, 13))
        reqs.append(Request(
            uid=i,
            prompt=rng.integers(3, cfg.vocab_size, size=plen).astype(np.int32),
            params=SamplingParams(max_tokens=budget_mix[i % 3],
                                  temperature=float(rng.uniform(0.1, 4.0)))))
        server.submit(reqs[-1])

    real_device_get = jax.device_get

    def forbidden(*a, **kw):
        raise AssertionError("device→host transfer inside step()")

    # drive the scheduler loop by hand so the transfer guard wraps exactly
    # the tick (admit + fused cycles); sync/harvest legitimately transfers
    for _ in range(10_000):
        if not server.queue and all(r is None for r in server.slot_req):
            break
        server._admit()
        syncs_before = server.host_syncs
        jax.device_get = forbidden
        try:
            # transfer_guard catches implicit transfers on real accelerator
            # backends; on CPU, device buffers ARE host memory (zero-copy
            # reads don't trip it), hence the device_get patch + counter
            with jax.transfer_guard_device_to_host("disallow"):
                server.step()
        finally:
            jax.device_get = real_device_get
        assert server.host_syncs == syncs_before
        server.sync()
    resps = {r.uid: r for r in server.run()}   # drain (already empty)
    assert sorted(resps) == list(range(16))

    session = DecodeSession(
        tgt, IndependentDrafter(drf, k=k, temperature=0.0), ecfg)
    for req in reqs:
        mt = req.params.max_tokens
        plen = len(req.prompt)
        padded = np.zeros((12,), np.int32)      # fixed width: fewer compiles
        padded[:plen] = req.prompt
        out = session.generate(
            t_params, d_params, jnp.asarray(padded)[None],
            jnp.asarray([plen], jnp.int32), mt, jax.random.PRNGKey(0))
        offline = np.asarray(out["tokens"])[0, plen:plen + mt]
        assert len(resps[req.uid].tokens) == mt
        np.testing.assert_array_equal(resps[req.uid].tokens, offline,
                                      err_msg=f"req {req.uid}")
