"""Continuous-batching server tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.configs.base import ModelConfig
from repro.core import EngineConfig, IndependentDrafter
from repro.models import build_model
from repro.serving import Request, SamplingParams, ServerConfig, SpecServer


@pytest.fixture(scope="module")
def server_setup():
    cfg = dataclasses.replace(get_smoke("granite-8b"), dtype="float32")
    tgt = build_model(cfg)
    d_cfg = ModelConfig(name="d", family="dense", n_layers=1, d_model=64,
                        n_heads=2, n_kv_heads=2, d_ff=128,
                        vocab_size=cfg.vocab_size, dtype="float32")
    drf = build_model(d_cfg)
    t_params = tgt.init(jax.random.PRNGKey(1))
    d_params = drf.init(jax.random.PRNGKey(2))
    return cfg, tgt, drf, t_params, d_params


def test_serves_more_requests_than_slots(server_setup):
    cfg, tgt, drf, t_params, d_params = server_setup
    server = SpecServer(
        tgt, IndependentDrafter(drf, k=3), t_params, d_params,
        EngineConfig(k=3, rule="mars", mode="sample", temperature=1.0),
        ServerConfig(slots=2, max_len=96, max_prompt_len=12))
    rng = np.random.default_rng(0)
    n = 5
    for i in range(n):
        server.submit(Request(
            uid=i,
            prompt=rng.integers(3, cfg.vocab_size, size=6).astype(np.int32),
            params=SamplingParams(max_tokens=10)))
    resps = server.run()
    assert len(resps) == n
    assert sorted(r.uid for r in resps) == list(range(n))
    for r in resps:
        assert len(r.tokens) >= 10
        assert r.n_cycles >= 1
        assert 1.0 <= r.tau <= 4.0


def test_slot_isolation(server_setup):
    """A request admitted mid-flight must not change a neighbour's output:
    serve the same prompt alone vs. alongside another request (greedy)."""
    cfg, tgt, drf, t_params, d_params = server_setup

    def serve(prompts, max_tokens=12):
        server = SpecServer(
            tgt, IndependentDrafter(drf, k=3, temperature=0.0),
            t_params, d_params,
            EngineConfig(k=3, rule="strict", mode="greedy", temperature=0.0),
            ServerConfig(slots=2, max_len=96, max_prompt_len=12))
        for i, p in enumerate(prompts):
            server.submit(Request(uid=i, prompt=p,
                                  params=SamplingParams(max_tokens=max_tokens)))
        return {r.uid: r.tokens for r in server.run()}

    rng = np.random.default_rng(7)
    p0 = rng.integers(3, cfg.vocab_size, size=8).astype(np.int32)
    p1 = rng.integers(3, cfg.vocab_size, size=5).astype(np.int32)
    alone = serve([p0])
    both = serve([p0, p1])
    np.testing.assert_array_equal(alone[0], both[0])
