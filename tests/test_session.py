"""Tests for the shared DecodeSession engine core.

Covers the refactor's contract: chain and tree are interchangeable draft
topologies over one engine (parity at branch=1), the continuous-batching
server runs tree drafts end-to-end, and the fused Pallas kernel path agrees
with the reference on tree node logits.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import (EagleDrafter, EngineConfig, init_eagle_params,
                        make_generate_fn)
from repro.core.tree import make_caterpillar, verify_tree
from repro.models import build_model
from repro.serving import Request, SamplingParams, ServerConfig, SpecServer

K = 3


@pytest.fixture(scope="module")
def eagle_setup():
    cfg = dataclasses.replace(get_smoke("granite-8b"), dtype="float32")
    tgt = build_model(cfg)
    t_params = tgt.init(jax.random.PRNGKey(1))
    e_params = init_eagle_params(cfg, jax.random.PRNGKey(7))
    return cfg, tgt, t_params, e_params


@pytest.mark.parametrize("rule", ["strict", "mars"])
def test_chain_tree_parity_branch1(eagle_setup, rule):
    """A branch-1 'tree' is a chain: under greedy verification both
    topologies must commit identical tokens through the shared session."""
    cfg, tgt, t_params, e_params = eagle_setup
    drafter = EagleDrafter(tgt, k=K, temperature=0.0)
    B, S, NEW = 2, 8, 16
    prompt = jax.random.randint(jax.random.PRNGKey(3), (B, S), 3,
                                cfg.vocab_size)
    plen = jnp.full((B,), S, jnp.int32)

    outs = {}
    for topology in ("chain", "tree"):
        gen = make_generate_fn(
            tgt, drafter,
            EngineConfig(k=K, rule=rule, mode="greedy", temperature=0.0,
                         topology=topology, branch=1))
        outs[topology] = gen(t_params, e_params, prompt, plen,
                             jax.random.PRNGKey(9), max_new=NEW)

    for b in range(B):
        n = S + NEW
        np.testing.assert_array_equal(
            np.asarray(outs["chain"]["tokens"])[b, :n],
            np.asarray(outs["tree"]["tokens"])[b, :n])


def test_server_serves_tree_drafts(eagle_setup):
    """EngineConfig(topology='tree') must serve end-to-end through the
    continuous-batching scheduler (more requests than slots)."""
    cfg, tgt, t_params, e_params = eagle_setup
    server = SpecServer(
        tgt, EagleDrafter(tgt, k=K, temperature=0.0), t_params, e_params,
        EngineConfig(k=K, rule="mars", mode="greedy", temperature=0.0,
                     topology="tree", branch=2),
        ServerConfig(slots=2, max_len=96, max_prompt_len=12))
    rng = np.random.default_rng(0)
    n = 3
    for i in range(n):
        server.submit(Request(
            uid=i,
            prompt=rng.integers(3, cfg.vocab_size, size=6).astype(np.int32),
            params=SamplingParams(max_tokens=8)))
    resps = server.run()
    assert sorted(r.uid for r in resps) == list(range(n))
    for r in resps:
        assert len(r.tokens) >= 8
        assert r.n_cycles >= 1
        assert 1.0 <= r.tau <= K + 2


def test_server_tree_matches_offline_tree(eagle_setup):
    """Served tree generation must equal offline tree generation for the
    same prompt (greedy): the server shares the session's carry mechanics."""
    cfg, tgt, t_params, e_params = eagle_setup
    rng = np.random.default_rng(5)
    prompt = rng.integers(3, cfg.vocab_size, size=8).astype(np.int32)
    max_tokens = 10

    ecfg = EngineConfig(k=K, rule="strict", mode="greedy", temperature=0.0,
                        topology="tree", branch=2)
    server = SpecServer(
        tgt, EagleDrafter(tgt, k=K, temperature=0.0), t_params, e_params,
        ecfg, ServerConfig(slots=2, max_len=96, max_prompt_len=12))
    server.submit(Request(uid=0, prompt=prompt,
                          params=SamplingParams(max_tokens=max_tokens)))
    served = {r.uid: r.tokens for r in server.run()}[0]

    gen = make_generate_fn(tgt, EagleDrafter(tgt, k=K, temperature=0.0), ecfg)
    out = gen(t_params, e_params, jnp.asarray(prompt)[None],
              jnp.asarray([len(prompt)], jnp.int32), jax.random.PRNGKey(0),
              max_new=max_tokens + K + 1)
    offline = np.asarray(out["tokens"])[0, len(prompt):]
    n = min(len(served), max_tokens)
    np.testing.assert_array_equal(served[:n], offline[:n])


def test_tree_kernel_matches_reference():
    """verify_tree must agree between the fused Pallas kernel (flattened
    (B*N, V) layout, interpret mode on CPU) and the reference path."""
    tpl = make_caterpillar(K, 2)
    n = len(tpl.depth)
    rng = np.random.default_rng(3)
    b, v = 2, 64
    node_logits = jnp.asarray(rng.standard_normal((b, n, v)) * 2, jnp.float32)
    node_tokens = jnp.asarray(rng.integers(0, v, (b, n)), jnp.int32)
    # plant an exact match and a near-tie relaxation candidate
    parent_logits = node_logits[:, np.maximum(tpl.parent, 0)]
    top = jax.lax.top_k(parent_logits, 2)[1]
    node_tokens = node_tokens.at[0, 1].set(top[0, 1, 0])   # chain d1 exact
    node_tokens = node_tokens.at[1, 1].set(top[1, 1, 1])   # chain d1 top-2

    key = jax.random.PRNGKey(0)
    ref = verify_tree(tpl, node_tokens, node_logits, rule="mars",
                      mode="greedy", theta=0.9, temperature=0.0, key=key,
                      use_kernel=False)
    ker = verify_tree(tpl, node_tokens, node_logits, rule="mars",
                      mode="greedy", theta=0.9, temperature=0.0, key=key,
                      use_kernel=True)
    for a, b_ in zip(ref, ker):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))


def test_chain_kernel_backend_generates(eagle_setup):
    """End-to-end chain generation with the fused verify kernel enabled
    (interpret mode on CPU) matches the reference backend."""
    cfg, tgt, t_params, e_params = eagle_setup
    drafter = EagleDrafter(tgt, k=K, temperature=0.0)
    B, S, NEW = 1, 8, 8
    prompt = jax.random.randint(jax.random.PRNGKey(3), (B, S), 3,
                                cfg.vocab_size)
    plen = jnp.full((B,), S, jnp.int32)
    outs = {}
    for use_kernel in (False, True):
        gen = make_generate_fn(
            tgt, drafter,
            EngineConfig(k=K, rule="mars", mode="greedy", temperature=0.0,
                         use_kernel=use_kernel))
        outs[use_kernel] = gen(t_params, e_params, prompt, plen,
                               jax.random.PRNGKey(9), max_new=NEW)
    np.testing.assert_array_equal(np.asarray(outs[False]["tokens"]),
                                  np.asarray(outs[True]["tokens"]))
