"""Pipelined-tick tests: overlap, admission ring, prefill worker.

The pipelined tick (``docs/ARCHITECTURE.md``, "Pipelined tick") changes
WHEN work happens — groups double-buffered, slots refilled on device
mid-group, cold prompts prefilled by a detached worker program — but
must never change WHAT is produced: every configuration below is
checked token-identical against the serial tick (greedy).  The
remaining tests pin the host-visible wins: no device→host transfer in
``step()`` even with snapshots in flight, zero idle slot-ticks under a
saturated queue, harvest gathers skipped when no slot finished, and an
admission decode window that no longer widens for cold prompts.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.configs.base import ModelConfig
from repro.core import (EagleDrafter, EngineConfig, IndependentDrafter,
                        init_eagle_params)
from repro.models import build_model
from repro.serving import Request, SamplingParams, ServerConfig, SpecServer


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_smoke("granite-8b"), dtype="float32")
    tgt = build_model(cfg)
    d_cfg = ModelConfig(name="d", family="dense", n_layers=1, d_model=64,
                        n_heads=2, n_kv_heads=2, d_ff=128,
                        vocab_size=cfg.vocab_size, dtype="float32")
    drf = build_model(d_cfg)
    return (cfg, tgt, drf, tgt.init(jax.random.PRNGKey(1)),
            drf.init(jax.random.PRNGKey(2)))


def _requests(cfg, n, seed=17, budgets=(3, 7, 13), plen_hi=13):
    """Mixed prompts with budgets % (K+1) != 0, so slots finish mid-cycle
    and the commit rollback (index rewind) runs on every path."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(4, plen_hi))
        reqs.append(Request(
            uid=i,
            prompt=rng.integers(3, cfg.vocab_size,
                                size=plen).astype(np.int32),
            params=SamplingParams(max_tokens=int(budgets[i % len(budgets)]))))
    return reqs


def _serve(setup, reqs, *, topology="chain", k=3, slots=2,
           max_prompt_len=12, **scfg):
    cfg, tgt, drf, tp, dp = setup
    if topology == "tree":
        drafter = EagleDrafter(tgt, k=k, temperature=0.0)
        dp = init_eagle_params(cfg, jax.random.PRNGKey(2))
    else:
        drafter = IndependentDrafter(drf, k=k, temperature=0.0)
    server = SpecServer(
        tgt, drafter, tp, dp,
        EngineConfig(k=k, rule="mars", mode="greedy", temperature=0.0,
                     topology=topology),
        ServerConfig(slots=slots, max_len=96,
                     max_prompt_len=max_prompt_len, steps_per_sync=3,
                     **scfg))
    for r in reqs:
        server.submit(r)
    out = {r.uid: r for r in server.run()}
    assert sorted(out) == sorted(r.uid for r in reqs)
    return server, out


def _assert_parity(piped, serial):
    for uid in sorted(serial):
        np.testing.assert_array_equal(piped[uid].tokens, serial[uid].tokens,
                                      err_msg=f"req {uid}")


@pytest.mark.parametrize("variant", [
    pytest.param(dict(topology="chain"), id="chain-dense"),
    pytest.param(dict(topology="chain", cache="paged"), id="chain-paged"),
    pytest.param(dict(topology="chain", cache="paged", kv_dtype="int8"),
                 id="chain-paged-int8"),
    pytest.param(dict(topology="tree", cache="paged"), id="tree-paged"),
])
def test_overlap_ring_matches_serial(setup, variant):
    """Double-buffered overlap + device-side ring refill vs the serial
    tick: token-identical per request on dense, paged, quantized-paged,
    and tree-topology configurations (greedy)."""
    reqs = _requests(setup[0], 8)
    _, serial = _serve(setup, reqs, **variant)
    srv, piped = _serve(setup, reqs, overlap=True, ring_depth=3, **variant)
    _assert_parity(piped, serial)
    assert srv.ring_refills > 0          # the ring actually carried admits


def test_step_transfer_free_under_overlap(setup):
    """With double-buffering on, ``step()`` must still perform zero
    device→host transfers: the harvest snapshot is dispatched and held as
    device handles, never read inside the tick."""
    cfg = setup[0]
    reqs = _requests(cfg, 10, seed=23)
    _, serial = _serve(setup, reqs, slots=2)

    cfg_, tgt, drf, tp, dp = setup
    server = SpecServer(
        tgt, IndependentDrafter(drf, k=3, temperature=0.0), tp, dp,
        EngineConfig(k=3, rule="mars", mode="greedy", temperature=0.0),
        ServerConfig(slots=2, max_len=96, max_prompt_len=12,
                     steps_per_sync=3, overlap=True, ring_depth=3))
    for r in reqs:
        server.submit(r)

    real_device_get = jax.device_get

    def forbidden(*a, **kw):
        raise AssertionError("device→host transfer inside step()")

    for _ in range(10_000):
        if (not server.queue and all(r is None for r in server.slot_req)
                and not server._pending and not server._ring_staged):
            break
        server._admit()
        syncs_before = server.host_syncs
        jax.device_get = forbidden
        try:
            with jax.transfer_guard_device_to_host("disallow"):
                server.step()
        finally:
            jax.device_get = real_device_get
        assert server.host_syncs == syncs_before
        server.sync()
    if server._pending:
        server.sync(flush=True)
    piped = {r.uid: r for r in server.run()}
    _assert_parity(piped, serial)


def test_ring_saturation_no_idle_slots(setup):
    """16 requests over 4 slots with the ring staged ahead: every slot
    freed mid-group is refilled by the device in the same group, so no
    tick ever runs with an empty slot while work is queued — and the
    small mixed budgets exercise rollback-after-refill (a refilled slot
    rewinds its fresh cache indices on rejected drafts)."""
    reqs = _requests(setup[0], 16, seed=31)
    _, serial = _serve(setup, reqs, slots=4)
    srv, piped = _serve(setup, reqs, slots=4, overlap=True, ring_depth=4)
    _assert_parity(piped, serial)
    assert srv.ring_refills > 0
    assert srv.slot_idle_ticks == 0
    assert srv.stats["slot_idle_ticks"] == 0


def test_prefill_worker_handoff_parity(setup):
    """Disaggregated prefill: the worker fills pool blocks off the decode
    path and hands the warm table to admission like a cached prefix.
    Tokens must match the serial no-worker server exactly, every cold
    admit must route through the worker, and the batched admission decode
    window must be NARROWER than the no-worker run (it covers only the
    pending tail, not the whole cold prompt)."""
    cfg = setup[0]
    rng = np.random.default_rng(41)
    reqs = [Request(uid=i,
                    prompt=rng.integers(3, cfg.vocab_size,
                                        size=40).astype(np.int32),
                    params=SamplingParams(max_tokens=8))
            for i in range(2)]
    base_srv, base = _serve(setup, reqs, cache="paged", max_prompt_len=48)
    wrk_srv, out = _serve(setup, reqs, cache="paged", max_prompt_len=48,
                          prefill_worker=True)
    _assert_parity(out, base)
    assert wrk_srv.worker is not None
    assert wrk_srv.worker.stats["fills"] == len(reqs)
    assert wrk_srv.worker.stats["filled_tokens"] > 0
    # same admissions, narrower window: the worker took the prompt body
    # off the batched pass
    assert wrk_srv.prefill_window_tokens < base_srv.prefill_window_tokens


def test_worker_rejected_off_paged(setup):
    """The worker hands off physical pool blocks; a dense cache has none,
    so the config must be rejected at construction, not at runtime."""
    cfg, tgt, drf, tp, dp = setup
    with pytest.raises(ValueError, match="prefill"):
        SpecServer(
            tgt, IndependentDrafter(drf, k=3, temperature=0.0), tp, dp,
            EngineConfig(k=3, rule="mars", mode="greedy", temperature=0.0),
            ServerConfig(slots=2, max_len=96, max_prompt_len=12,
                         prefill_worker=True))


def test_gather_only_when_finished(setup):
    """Regression for the unconditional-harvest transfer: ``sync`` must
    dispatch the full-row gather only when the poll shows >= 1 finished
    occupant.  A no-finisher sync pays the poll alone."""
    cfg, tgt, drf, tp, dp = setup
    server = SpecServer(
        tgt, IndependentDrafter(drf, k=3, temperature=0.0), tp, dp,
        EngineConfig(k=3, rule="mars", mode="greedy", temperature=0.0,
                     eos_token=1),       # caps groups at steps_per_sync
        ServerConfig(slots=2, max_len=96, max_prompt_len=12,
                     steps_per_sync=2))
    for r in _requests(cfg, 4, seed=53, budgets=(13, 9)):
        server.submit(r)
    n_syncs = harvesting_syncs = 0
    for _ in range(10_000):
        if not server.queue and all(r is None for r in server.slot_req):
            break
        server._admit()
        server.step()
        before_gather = server.gather_calls
        before_resp = len(server._responses)
        server.sync()
        n_syncs += 1
        grew = len(server._responses) > before_resp
        # the gather runs exactly when the sync harvested something
        assert (server.gather_calls - before_gather) == (1 if grew else 0)
        harvesting_syncs += int(grew)
    assert server.gather_calls == harvesting_syncs
    # groups are EOS-capped below the budget bound, so some syncs MUST
    # have polled without harvesting — i.e. the gather was skipped
    assert server.gather_calls < n_syncs
    assert len(server.run()) == 4
