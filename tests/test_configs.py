import pytest

from repro.configs import SHAPES, get_config, get_smoke, get_shape, list_archs

EXPECTED = {
    "zamba2-2.7b": ("hybrid", 54, 2560),
    "dbrx-132b": ("moe", 40, 6144),
    "chatglm3-6b": ("dense", 28, 4096),
    "deepseek-67b": ("dense", 95, 8192),
    "starcoder2-15b": ("dense", 40, 6144),
    "granite-8b": ("dense", 36, 4096),
    "whisper-large-v3": ("audio", 32, 1280),
    "granite-moe-3b-a800m": ("moe", 32, 1536),
    "chameleon-34b": ("vlm", 48, 8192),
    "xlstm-1.3b": ("ssm", 48, 2048),
}


def test_registry_complete():
    assert sorted(list_archs()) == sorted(EXPECTED)


@pytest.mark.parametrize("arch", list(EXPECTED))
def test_full_config_matches_assignment(arch):
    fam, layers, d = EXPECTED[arch]
    cfg = get_config(arch)
    assert cfg.family == fam
    assert cfg.n_layers == layers
    assert cfg.d_model == d
    assert cfg.source


@pytest.mark.parametrize("arch", list(EXPECTED))
def test_smoke_config_reduced(arch):
    cfg = get_smoke(arch)
    assert cfg.n_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4
    assert cfg.family == EXPECTED[arch][0]


def test_param_counts_scale():
    # published ballparks (±40%: our counter is approximate by design)
    # xlstm omitted: our mLSTM block (projection factor 2 + full-width
    # q/k/v) is intentionally heavier than the published 1.3B (DESIGN.md §7)
    approx = {
        "deepseek-67b": 67e9, "granite-8b": 8e9, "chatglm3-6b": 6e9,
        "starcoder2-15b": 15e9, "chameleon-34b": 34e9, "zamba2-2.7b": 2.7e9,
        "dbrx-132b": 132e9,
    }
    for arch, target in approx.items():
        n = get_config(arch).param_count()
        assert 0.6 * target < n < 1.5 * target, (arch, n, target)


def test_moe_active_params():
    cfg = get_config("dbrx-132b")
    act = cfg.active_param_count()
    tot = cfg.param_count()
    assert act < 0.45 * tot  # 4/16 experts + dense share


def test_shapes():
    assert [s.name for s in SHAPES] == [
        "train_4k", "prefill_32k", "decode_32k", "long_500k"]
    assert get_shape("long_500k").seq_len == 524_288
