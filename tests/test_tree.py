"""Tree-draft speculative decoding tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import EagleDrafter, init_eagle_params, make_ar_generate_fn
from repro.core.tree import (TreeEngineConfig, make_caterpillar,
                             make_tree_generate_fn, verify_tree)
from repro.models import build_model


def test_caterpillar_template():
    tpl = make_caterpillar(k=3, branch=2)
    assert len(tpl.depth) == 1 + 3 * 2
    # root attends only itself
    assert tpl.mask[0].sum() == 1
    # chain node at depth 3 attends root + chain(1,2) + self = 4
    chain3 = int(np.where((tpl.depth == 3) & tpl.is_chain)[0][0])
    assert tpl.mask[chain3].sum() == 4
    # siblings never appear in anyone else's mask column
    sib = int(np.where((tpl.depth == 1) & ~tpl.is_chain)[0][0])
    assert tpl.mask[:, sib].sum() == 1  # only itself


def test_verify_tree_sibling_rescue():
    """Chain rejected at depth 1, but a sibling matches top-1 -> rescued."""
    tpl = make_caterpillar(k=2, branch=2)
    v = 16
    b = 1
    n = len(tpl.depth)
    # node tokens: root=0, chain d1=5, sib d1=7, chain d2=9, sib d2=11
    node_tokens = jnp.asarray([[0, 5, 7, 9, 11]], jnp.int32)
    logits = np.full((b, n, v), -5.0, np.float32)
    logits[0, 0, 7] = 5.0           # root's successor: top1 = 7 (not 5!)
    # sibling 7's successor: top1 = 3
    sib1 = 2
    logits[0, sib1, 3] = 5.0
    out, n_commit, n_accept, n_rel, _margin = verify_tree(
        tpl, node_tokens, jnp.asarray(logits), rule="strict", mode="greedy",
        theta=0.9, temperature=0.0, key=jax.random.PRNGKey(0))
    assert int(n_accept[0]) == 1          # the rescued sibling
    assert int(n_commit[0]) == 2
    np.testing.assert_array_equal(np.asarray(out[0, :2]), [7, 3])


def test_verify_tree_mars_relaxes_sibling():
    tpl = make_caterpillar(k=1, branch=2)
    v = 16
    node_tokens = jnp.asarray([[0, 5, 7]], jnp.int32)   # root, chain, sib
    logits = np.full((1, 3, v), -5.0, np.float32)
    logits[0, 0, 2] = 5.0      # top1 = 2 (chain 5 rejected strictly)
    logits[0, 0, 7] = 4.8      # top2 = 7 = sibling, ratio 0.96 > 0.9
    logits[0, 2, 1] = 5.0      # sibling successor top1 = 1
    strict = verify_tree(tpl, node_tokens, jnp.asarray(logits),
                         rule="strict", mode="greedy", theta=0.9,
                         temperature=0.0, key=jax.random.PRNGKey(0))
    mars = verify_tree(tpl, node_tokens, jnp.asarray(logits),
                       rule="mars", mode="greedy", theta=0.9,
                       temperature=0.0, key=jax.random.PRNGKey(0))
    assert int(strict[2][0]) == 0
    assert int(mars[2][0]) == 1           # sibling rescued via relaxation
    assert int(mars[3][0]) == 1           # counted as relaxed
    np.testing.assert_array_equal(np.asarray(mars[0][0, :2]), [7, 1])


@pytest.mark.parametrize("arch", ["granite-8b", "dbrx-132b"])
def test_tree_strict_greedy_equals_ar(arch, rng):
    """With strict greedy verification the tree engine must still reproduce
    the AR output exactly (sibling rescue == the correction token)."""
    cfg = dataclasses.replace(get_smoke(arch), dtype="float32")
    tgt = build_model(cfg)
    t_params = tgt.init(jax.random.PRNGKey(1))
    e_params = init_eagle_params(cfg, jax.random.PRNGKey(7))
    drafter = EagleDrafter(tgt, k=3, temperature=0.0)

    B, S, NEW = 2, 8, 16
    prompt = jax.random.randint(jax.random.PRNGKey(3), (B, S), 3,
                                cfg.vocab_size)
    plen = jnp.full((B,), S, jnp.int32)

    ar = make_ar_generate_fn(tgt, temperature=0.0)
    out_ar = ar(t_params, prompt, plen, jax.random.PRNGKey(9), max_new=NEW)

    gen = make_tree_generate_fn(
        tgt, drafter, TreeEngineConfig(k=3, branch=2, rule="strict",
                                       mode="greedy", temperature=0.0))
    out = gen(t_params, e_params, prompt, plen, jax.random.PRNGKey(9),
              max_new=NEW)
    for b in range(B):
        n = S + NEW
        np.testing.assert_array_equal(
            np.asarray(out_ar["tokens"])[b, :n],
            np.asarray(out["tokens"])[b, :n])


def test_tree_mars_runs_and_counts(rng):
    cfg = dataclasses.replace(get_smoke("granite-8b"), dtype="float32")
    tgt = build_model(cfg)
    t_params = tgt.init(jax.random.PRNGKey(1))
    e_params = init_eagle_params(cfg, jax.random.PRNGKey(7))
    drafter = EagleDrafter(tgt, k=3, temperature=0.0)
    gen = make_tree_generate_fn(
        tgt, drafter, TreeEngineConfig(k=3, branch=3, rule="mars",
                                       mode="greedy", temperature=0.0))
    B, S = 2, 8
    prompt = jax.random.randint(jax.random.PRNGKey(3), (B, S), 3,
                                cfg.vocab_size)
    plen = jnp.full((B,), S, jnp.int32)
    out = gen(t_params, e_params, prompt, plen, jax.random.PRNGKey(0),
              max_new=12)
    st = out["stats"]
    assert (np.asarray(st["commits"]) == np.asarray(out["lengths"] - plen)).all()
    assert (np.asarray(st["relaxed"]) <= np.asarray(st["accepts"])).all()
