"""Subprocess payload for the multi-device serving parity test.

Run by ``tests/test_mesh_serving.py`` with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` in the environment
(host-platform devices must be forced before jax is imported, which is why
this lives in its own process instead of a fixture).

Asserts, for dense AND paged caches (prefix cache off and on) on real
≥2-device meshes:

* the mesh-partitioned ``SpecServer`` produces token-identical greedy
  output to single-device offline ``DecodeSession.generate`` per request
  — including an int8-quantized paged case, whose offline reference
  decodes through the same quantized pool (scale pools shard like their
  parent pools: blocks on ``data``, KV heads on ``model``), a hybrid
  target (attention sub-cache paged; mamba leaves stay dense, sharded
  with the carry), and a sliding-window target whose 2-block ring wraps
  repeatedly under the mesh;
* ``step()`` performs zero device→host transfers under the mesh (the
  PR 2 sync-free contract is mesh-invariant) — guarded by patching
  ``jax.device_get``, checking the server's transfer counter, and running
  the tick under ``jax.transfer_guard_device_to_host("disallow")``;
* paged block traffic stays shard-local: every block a slot's table maps —
  shared prefix blocks included — and the slot's trash block (the target
  of masked/unmapped writes) lie inside the pool partition of the data
  shard that owns the slot, so no paged gather or scatter crosses shards.

Prints ``MESH-PARITY-OK`` on success; any assertion kills the process.
"""
import os

assert "xla_force_host_platform_device_count" in os.environ.get(
    "XLA_FLAGS", ""), "run via tests/test_mesh_serving.py (forces devices)"

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.configs.base import ModelConfig
from repro.core import EngineConfig, IndependentDrafter
from repro.core.session import DecodeSession
from repro.models import build_model
from repro.serving import Request, SamplingParams, ServerConfig, SpecServer

K = 3
ECFG = EngineConfig(k=K, rule="mars", mode="greedy", temperature=0.0)


def make_setup(cfg):
    """Target + tiny drafter + params + offline session for one config."""
    tgt = build_model(cfg)
    d_cfg = ModelConfig(name="d", family="dense", n_layers=1, d_model=64,
                        n_heads=2, n_kv_heads=2, d_ff=128,
                        vocab_size=cfg.vocab_size, dtype="float32")
    drf = build_model(d_cfg)
    t_params = tgt.init(jax.random.PRNGKey(1))
    d_params = drf.init(jax.random.PRNGKey(2))
    session = DecodeSession(tgt, IndependentDrafter(drf, k=K,
                                                    temperature=0.0), ECFG)
    return tgt, drf, t_params, d_params, session


def make_requests(cfg, seed=17, n=6, shared_prefix=False):
    rng = np.random.default_rng(seed)
    reqs = []
    shared = (rng.integers(3, cfg.vocab_size, 8).astype(np.int32)
              if shared_prefix else None)
    for i in range(n):
        if shared_prefix:
            tail = rng.integers(3, cfg.vocab_size, 4).astype(np.int32)
            prompt = np.concatenate([shared, tail])
        else:
            plen = int(rng.integers(4, 13))
            prompt = rng.integers(3, cfg.vocab_size, plen).astype(np.int32)
        reqs.append(Request(
            uid=i, prompt=prompt,
            params=SamplingParams(max_tokens=[3, 7, 13][i % 3],
                                  temperature=0.0)))
    return reqs


def offline_ref(setup, case_reqs, paged=None):
    """Single-device offline reference, fixed prompt width (fewer
    compiles)."""
    _, _, t_params, d_params, session = setup
    out = {}
    for req in case_reqs:
        plen, mt = len(req.prompt), req.params.max_tokens
        padded = np.zeros((12,), np.int32)
        padded[:plen] = req.prompt
        o = session.generate(t_params, d_params,
                             jnp.asarray(padded)[None],
                             jnp.asarray([plen], jnp.int32), mt,
                             jax.random.PRNGKey(0), paged=paged)
        out[req.uid] = np.asarray(o["tokens"])[0, plen:plen + mt]
    return out


def run_case(setup, mesh, cache, prefix, kv, case_reqs, ref, extra,
             label=""):
    tgt, drf, t_params, d_params, _ = setup
    real_device_get = jax.device_get

    def forbidden(*a, **kw):
        raise AssertionError("device→host transfer inside step() on mesh")

    server = SpecServer(
        tgt, IndependentDrafter(drf, k=K, temperature=0.0),
        t_params, d_params, ECFG,
        ServerConfig(slots=4, max_len=96, max_prompt_len=12,
                     steps_per_sync=3, cache=cache, mesh=mesh,
                     prefix_cache=prefix, block_size=4, kv_dtype=kv,
                     **extra))
    for r in case_reqs:
        server.submit(dataclasses.replace(r))
    for _ in range(10_000):
        if not server.queue and all(r is None for r in server.slot_req):
            break
        server._admit()
        if server.controller is not None:
            # exercise the sharded retune entry point directly (the
            # clamped controller's own updates are no-ops and skip the
            # dispatch): writing the SAME thetas must preserve parity
            server.state = server._set_theta(
                server.state, server.slot_theta.astype(np.float32))
        if server.pool is not None:
            # no cross-shard paged traffic: every mapped block (shared
            # prefix blocks included) and every trash target lives in
            # the owning shard's pool partition
            per = server.pool.per_shard
            for s, blks in enumerate(server.slot_blocks):
                sh = s // server._slots_per_shard
                assert server.trash_ids[s] == sh * per, (mesh, cache, s)
                assert all(sh * per <= blk < (sh + 1) * per
                           for blk in blks), (mesh, cache, s, blks)
        syncs_before = server.host_syncs
        jax.device_get = forbidden
        try:
            with jax.transfer_guard_device_to_host("disallow"):
                server.step()
        finally:
            jax.device_get = real_device_get
        assert server.host_syncs == syncs_before, (mesh, cache)
        server.sync()
    resps = {r.uid: r for r in server.run()}
    assert sorted(resps) == list(range(len(case_reqs))), (mesh, cache)
    for req in case_reqs:
        got = np.asarray(resps[req.uid].tokens)
        np.testing.assert_array_equal(
            got, ref[req.uid],
            err_msg=f"mesh={mesh} cache={cache} prefix={prefix} "
                    f"kv={kv} {label} req {req.uid}: sharded != offline")
    note = f" [{label}]" if label else ""
    if prefix == "on":
        s = server.prefix.summary()
        assert s["hits"] >= 1, s     # shared blocks actually rode in
        note += (f", prefix hit rate {s['hit_rate']:.0%} "
                 f"({s['blocks_shared']} shared mappings)")
    if server.controller is not None:
        assert (server.slot_theta == 0.9).all(), server.slot_theta
        note += ", adaptive(theta clamped)"
    print(f"  mesh={mesh} cache={cache} prefix={prefix} kv={kv}: "
          f"token-identical, 0 in-tick syncs "
          f"({server.host_syncs} at sync points){note}")
    return server


def main():
    assert len(jax.devices()) >= 8, jax.devices()
    cfg = dataclasses.replace(get_smoke("granite-8b"), dtype="float32")
    setup = make_setup(cfg)

    reqs = make_requests(cfg)
    # prefix-cache case: 6 requests sharing one 8-token system prefix, so
    # later admissions map published blocks of earlier ones (per shard)
    shared_reqs = make_requests(cfg, shared_prefix=True)

    offline = offline_ref(setup, reqs)
    offline_shared = offline_ref(setup, shared_reqs)
    # the int8 reference must itself decode through an int8 pool: quantized
    # serving is token-identical to quantized offline, not to f32 offline
    from repro.models.paging import PagedCacheConfig
    offline_int8 = offline_ref(setup, reqs,
                               paged=PagedCacheConfig(4, kv_dtype="int8"))

    # the adaptive case pins mesh-invariance of per-slot theta: a clamped
    # controller (theta_min == theta_max == EngineConfig.theta) can never
    # move theta, so greedy output must still match the offline reference
    # while the controller machinery (clamp at admission, stats in the sync
    # poll, the sharded theta-row dispatch) runs for real
    adaptive = {"theta_mode": "adaptive", "theta_min": 0.9, "theta_max": 0.9}
    cases = [((2, 1), "dense", "off", "bf16", reqs, offline, {}),
             ((2, 1), "paged", "off", "bf16", reqs, offline, {}),
             ((2, 2), "paged", "off", "bf16", reqs, offline, {}),
             ((2, 2), "paged", "off", "int8", reqs, offline_int8, {}),
             ((2, 2), "paged", "on", "bf16", shared_reqs, offline_shared, {}),
             ((2, 2), "paged", "off", "bf16", reqs, offline, adaptive),
             ((4, 2), "dense", "off", "bf16", reqs, offline, {})]
    for mesh, cache, prefix, kv, case_reqs, ref, extra in cases:
        run_case(setup, mesh, cache, prefix, kv, case_reqs, ref, extra)

    # the full pipelined tick on the full mesh: double-buffered overlap,
    # replicated admission ring (entries bound to one data shard each),
    # and the disaggregated prefill worker, all under the same zero-
    # transfer guard and offline parity bar as the serial cases
    pipelined = {"overlap": True, "ring_depth": 4, "prefill_worker": True}
    pipe_srv = run_case(setup, (2, 2), "paged", "on", "bf16", shared_reqs,
                        offline_shared, pipelined, label="pipelined")
    assert pipe_srv.ring_refills >= 1, pipe_srv.ring_refills
    assert pipe_srv.worker.stats["fills"] >= 1, pipe_srv.worker.stats

    # every-family paging on the full (2,2) mesh: the hybrid pages only
    # its attention sub-cache (mamba leaves stay dense, sharded with the
    # carry) and the sliding-window target wraps a window-bounded ring —
    # ceil(8/4) = 2 blocks per slot instead of ceil(96/4) = 24
    hyb_cfg = dataclasses.replace(get_smoke("zamba2-2.7b"), dtype="float32")
    win_cfg = dataclasses.replace(get_smoke("granite-8b"), dtype="float32",
                                  sliding_window=8)
    for label, acfg in (("hybrid", hyb_cfg), ("sliding-window", win_cfg)):
        asetup = make_setup(acfg)
        areqs = make_requests(acfg, seed=23)
        aref = offline_ref(asetup, areqs)
        server = run_case(asetup, (2, 2), "paged", "off", "bf16", areqs,
                          aref, {}, label=label)
        if label == "sliding-window":
            assert server.max_blocks == 2, server.max_blocks

    print("MESH-PARITY-OK")


if __name__ == "__main__":
    main()
