"""Subprocess payload for the multi-device serving parity test.

Run by ``tests/test_mesh_serving.py`` with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` in the environment
(host-platform devices must be forced before jax is imported, which is why
this lives in its own process instead of a fixture).

Asserts, for dense AND paged caches on real ≥2-device meshes:

* the mesh-partitioned ``SpecServer`` produces token-identical greedy
  output to single-device offline ``DecodeSession.generate`` per request;
* ``step()`` performs zero device→host transfers under the mesh (the
  PR 2 sync-free contract is mesh-invariant) — guarded by patching
  ``jax.device_get``, checking the server's transfer counter, and running
  the tick under ``jax.transfer_guard_device_to_host("disallow")``.

Prints ``MESH-PARITY-OK`` on success; any assertion kills the process.
"""
import os

assert "xla_force_host_platform_device_count" in os.environ.get(
    "XLA_FLAGS", ""), "run via tests/test_mesh_serving.py (forces devices)"

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.configs.base import ModelConfig
from repro.core import EngineConfig, IndependentDrafter
from repro.core.session import DecodeSession
from repro.models import build_model
from repro.serving import Request, SamplingParams, ServerConfig, SpecServer


def main():
    assert len(jax.devices()) >= 8, jax.devices()
    cfg = dataclasses.replace(get_smoke("granite-8b"), dtype="float32")
    tgt = build_model(cfg)
    d_cfg = ModelConfig(name="d", family="dense", n_layers=1, d_model=64,
                        n_heads=2, n_kv_heads=2, d_ff=128,
                        vocab_size=cfg.vocab_size, dtype="float32")
    drf = build_model(d_cfg)
    t_params = tgt.init(jax.random.PRNGKey(1))
    d_params = drf.init(jax.random.PRNGKey(2))
    k = 3
    ecfg = EngineConfig(k=k, rule="mars", mode="greedy", temperature=0.0)

    rng = np.random.default_rng(17)
    reqs = []
    for i in range(6):
        plen = int(rng.integers(4, 13))
        reqs.append(Request(
            uid=i,
            prompt=rng.integers(3, cfg.vocab_size, plen).astype(np.int32),
            params=SamplingParams(max_tokens=[3, 7, 13][i % 3],
                                  temperature=0.0)))

    # single-device offline reference, fixed prompt width (fewer compiles)
    session = DecodeSession(tgt, IndependentDrafter(drf, k=k,
                                                    temperature=0.0), ecfg)
    offline = {}
    for req in reqs:
        plen, mt = len(req.prompt), req.params.max_tokens
        padded = np.zeros((12,), np.int32)
        padded[:plen] = req.prompt
        out = session.generate(t_params, d_params, jnp.asarray(padded)[None],
                               jnp.asarray([plen], jnp.int32), mt,
                               jax.random.PRNGKey(0))
        offline[req.uid] = np.asarray(out["tokens"])[0, plen:plen + mt]

    real_device_get = jax.device_get

    def forbidden(*a, **kw):
        raise AssertionError("device→host transfer inside step() on mesh")

    for mesh, cache in [((2, 1), "dense"), ((2, 1), "paged"),
                        ((2, 2), "paged"), ((4, 2), "dense")]:
        server = SpecServer(
            tgt, IndependentDrafter(drf, k=k, temperature=0.0),
            t_params, d_params, ecfg,
            ServerConfig(slots=4, max_len=96, max_prompt_len=12,
                         steps_per_sync=3, cache=cache, mesh=mesh))
        for r in reqs:
            server.submit(dataclasses.replace(r))
        for _ in range(10_000):
            if not server.queue and all(r is None for r in server.slot_req):
                break
            server._admit()
            syncs_before = server.host_syncs
            jax.device_get = forbidden
            try:
                with jax.transfer_guard_device_to_host("disallow"):
                    server.step()
            finally:
                jax.device_get = real_device_get
            assert server.host_syncs == syncs_before, (mesh, cache)
            server.sync()
        resps = {r.uid: r for r in server.run()}
        assert sorted(resps) == list(range(len(reqs))), (mesh, cache)
        for req in reqs:
            got = np.asarray(resps[req.uid].tokens)
            np.testing.assert_array_equal(
                got, offline[req.uid],
                err_msg=f"mesh={mesh} cache={cache} req {req.uid}: "
                        f"sharded != offline")
        print(f"  mesh={mesh} cache={cache}: token-identical, "
              f"0 in-tick syncs ({server.host_syncs} at sync points)")

    print("MESH-PARITY-OK")


if __name__ == "__main__":
    main()
