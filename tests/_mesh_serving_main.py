"""Subprocess payload for the multi-device serving parity test.

Run by ``tests/test_mesh_serving.py`` with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` in the environment
(host-platform devices must be forced before jax is imported, which is why
this lives in its own process instead of a fixture).

Asserts, for dense AND paged caches (prefix cache off and on) on real
≥2-device meshes:

* the mesh-partitioned ``SpecServer`` produces token-identical greedy
  output to single-device offline ``DecodeSession.generate`` per request
  — including an int8-quantized paged case, whose offline reference
  decodes through the same quantized pool (scale pools shard like their
  parent pools: blocks on ``data``, KV heads on ``model``);
* ``step()`` performs zero device→host transfers under the mesh (the
  PR 2 sync-free contract is mesh-invariant) — guarded by patching
  ``jax.device_get``, checking the server's transfer counter, and running
  the tick under ``jax.transfer_guard_device_to_host("disallow")``;
* paged block traffic stays shard-local: every block a slot's table maps —
  shared prefix blocks included — and the slot's trash block (the target
  of masked/unmapped writes) lie inside the pool partition of the data
  shard that owns the slot, so no paged gather or scatter crosses shards.

Prints ``MESH-PARITY-OK`` on success; any assertion kills the process.
"""
import os

assert "xla_force_host_platform_device_count" in os.environ.get(
    "XLA_FLAGS", ""), "run via tests/test_mesh_serving.py (forces devices)"

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.configs.base import ModelConfig
from repro.core import EngineConfig, IndependentDrafter
from repro.core.session import DecodeSession
from repro.models import build_model
from repro.serving import Request, SamplingParams, ServerConfig, SpecServer


def main():
    assert len(jax.devices()) >= 8, jax.devices()
    cfg = dataclasses.replace(get_smoke("granite-8b"), dtype="float32")
    tgt = build_model(cfg)
    d_cfg = ModelConfig(name="d", family="dense", n_layers=1, d_model=64,
                        n_heads=2, n_kv_heads=2, d_ff=128,
                        vocab_size=cfg.vocab_size, dtype="float32")
    drf = build_model(d_cfg)
    t_params = tgt.init(jax.random.PRNGKey(1))
    d_params = drf.init(jax.random.PRNGKey(2))
    k = 3
    ecfg = EngineConfig(k=k, rule="mars", mode="greedy", temperature=0.0)

    rng = np.random.default_rng(17)
    reqs = []
    for i in range(6):
        plen = int(rng.integers(4, 13))
        reqs.append(Request(
            uid=i,
            prompt=rng.integers(3, cfg.vocab_size, plen).astype(np.int32),
            params=SamplingParams(max_tokens=[3, 7, 13][i % 3],
                                  temperature=0.0)))
    # prefix-cache case: 6 requests sharing one 8-token system prefix, so
    # later admissions map published blocks of earlier ones (per shard)
    shared = rng.integers(3, cfg.vocab_size, 8).astype(np.int32)
    shared_reqs = []
    for i in range(6):
        tail = rng.integers(3, cfg.vocab_size, 4).astype(np.int32)
        shared_reqs.append(Request(
            uid=i, prompt=np.concatenate([shared, tail]),
            params=SamplingParams(max_tokens=[3, 7, 13][i % 3],
                                  temperature=0.0)))

    # single-device offline reference, fixed prompt width (fewer compiles)
    session = DecodeSession(tgt, IndependentDrafter(drf, k=k,
                                                    temperature=0.0), ecfg)

    def offline_ref(case_reqs, paged=None):
        out = {}
        for req in case_reqs:
            plen, mt = len(req.prompt), req.params.max_tokens
            padded = np.zeros((12,), np.int32)
            padded[:plen] = req.prompt
            o = session.generate(t_params, d_params,
                                 jnp.asarray(padded)[None],
                                 jnp.asarray([plen], jnp.int32), mt,
                                 jax.random.PRNGKey(0), paged=paged)
            out[req.uid] = np.asarray(o["tokens"])[0, plen:plen + mt]
        return out

    offline = offline_ref(reqs)
    offline_shared = offline_ref(shared_reqs)
    # the int8 reference must itself decode through an int8 pool: quantized
    # serving is token-identical to quantized offline, not to f32 offline
    from repro.models.paging import PagedCacheConfig
    offline_int8 = offline_ref(reqs,
                               paged=PagedCacheConfig(4, kv_dtype="int8"))

    real_device_get = jax.device_get

    def forbidden(*a, **kw):
        raise AssertionError("device→host transfer inside step() on mesh")

    # the adaptive case pins mesh-invariance of per-slot theta: a clamped
    # controller (theta_min == theta_max == EngineConfig.theta) can never
    # move theta, so greedy output must still match the offline reference
    # while the controller machinery (clamp at admission, stats in the sync
    # poll, the sharded theta-row dispatch) runs for real
    adaptive = {"theta_mode": "adaptive", "theta_min": 0.9, "theta_max": 0.9}
    cases = [((2, 1), "dense", "off", "bf16", reqs, offline, {}),
             ((2, 1), "paged", "off", "bf16", reqs, offline, {}),
             ((2, 2), "paged", "off", "bf16", reqs, offline, {}),
             ((2, 2), "paged", "off", "int8", reqs, offline_int8, {}),
             ((2, 2), "paged", "on", "bf16", shared_reqs, offline_shared, {}),
             ((2, 2), "paged", "off", "bf16", reqs, offline, adaptive),
             ((4, 2), "dense", "off", "bf16", reqs, offline, {})]
    for mesh, cache, prefix, kv, case_reqs, ref, extra in cases:
        server = SpecServer(
            tgt, IndependentDrafter(drf, k=k, temperature=0.0),
            t_params, d_params, ecfg,
            ServerConfig(slots=4, max_len=96, max_prompt_len=12,
                         steps_per_sync=3, cache=cache, mesh=mesh,
                         prefix_cache=prefix, block_size=4, kv_dtype=kv,
                         **extra))
        for r in case_reqs:
            server.submit(dataclasses.replace(r))
        for _ in range(10_000):
            if not server.queue and all(r is None for r in server.slot_req):
                break
            server._admit()
            if server.controller is not None:
                # exercise the sharded retune entry point directly (the
                # clamped controller's own updates are no-ops and skip the
                # dispatch): writing the SAME thetas must preserve parity
                server.state = server._set_theta(
                    server.state, server.slot_theta.astype(np.float32))
            if server.pool is not None:
                # no cross-shard paged traffic: every mapped block (shared
                # prefix blocks included) and every trash target lives in
                # the owning shard's pool partition
                per = server.pool.per_shard
                for s, blks in enumerate(server.slot_blocks):
                    sh = s // server._slots_per_shard
                    assert server.trash_ids[s] == sh * per, (mesh, cache, s)
                    assert all(sh * per <= blk < (sh + 1) * per
                               for blk in blks), (mesh, cache, s, blks)
            syncs_before = server.host_syncs
            jax.device_get = forbidden
            try:
                with jax.transfer_guard_device_to_host("disallow"):
                    server.step()
            finally:
                jax.device_get = real_device_get
            assert server.host_syncs == syncs_before, (mesh, cache)
            server.sync()
        resps = {r.uid: r for r in server.run()}
        assert sorted(resps) == list(range(len(case_reqs))), (mesh, cache)
        for req in case_reqs:
            got = np.asarray(resps[req.uid].tokens)
            np.testing.assert_array_equal(
                got, ref[req.uid],
                err_msg=f"mesh={mesh} cache={cache} prefix={prefix} "
                        f"kv={kv} req {req.uid}: sharded != offline")
        note = ""
        if prefix == "on":
            s = server.prefix.summary()
            assert s["hits"] >= 1, s     # shared blocks actually rode in
            note = (f", prefix hit rate {s['hit_rate']:.0%} "
                    f"({s['blocks_shared']} shared mappings)")
        if server.controller is not None:
            assert (server.slot_theta == 0.9).all(), server.slot_theta
            note += ", adaptive(theta clamped)"
        print(f"  mesh={mesh} cache={cache} prefix={prefix} kv={kv}: "
              f"token-identical, 0 in-tick syncs "
              f"({server.host_syncs} at sync points){note}")

    print("MESH-PARITY-OK")


if __name__ == "__main__":
    main()
