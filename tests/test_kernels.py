"""Pallas kernels vs pure-jnp oracles, with hypothesis shape/dtype sweeps.

Kernels run in interpret mode on CPU: the kernel body semantics (BlockSpec
tiling, revisited accumulators, masking) are what is being validated.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

SET = dict(max_examples=12, deadline=None)


# ---------------------------------------------------------------------------
# mars_verify
# ---------------------------------------------------------------------------

@settings(**SET)
@given(
    t=st.integers(1, 17),
    v=st.sampled_from([40, 127, 2048, 4099]),
    theta=st.sampled_from([0.8, 0.9, 0.97]),
    seed=st.integers(0, 2**31 - 1),
)
def test_mars_verify_matches_ref(t, v, theta, seed):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.standard_normal((1, t, v)) * 3, jnp.float32)
    draft = jnp.asarray(rng.integers(0, v, (1, t)), jnp.int32)
    # plant exact and near-tie cases
    vals, idx = jax.lax.top_k(logits, 2)
    draft = draft.at[0, 0].set(idx[0, 0, 0])
    if t > 1:
        draft = draft.at[0, 1].set(idx[0, 1, 1])
    e, r, t1, t2 = ops.mars_verify(draft, logits, theta)
    er, rr, t1r, t2r = jax.vmap(
        lambda d, l: ref.mars_verify_ref(d, l, theta))(draft, logits)
    np.testing.assert_array_equal(np.asarray(e), np.asarray(er))
    np.testing.assert_array_equal(np.asarray(r), np.asarray(rr))
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t1r))


def test_mars_verify_bf16_logits():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.standard_normal((1, 4, 512)), jnp.bfloat16)
    draft = jnp.asarray(rng.integers(0, 512, (1, 4)), jnp.int32)
    e, r, t1, t2 = ops.mars_verify(draft, logits, 0.9)
    er, rr, t1r, _ = jax.vmap(
        lambda d, l: ref.mars_verify_ref(d, l, 0.9))(draft, logits)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t1r))


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@settings(**SET)
@given(
    b=st.integers(1, 3),
    hkv=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2, 4]),
    d=st.sampled_from([32, 64, 128]),
    l=st.sampled_from([63, 256, 700]),
    window=st.sampled_from([0, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_attention_matches_ref(b, hkv, g, d, l, window, seed):
    rng = np.random.default_rng(seed)
    h = hkv * g
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, l, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, l, hkv, d)), jnp.float32)
    kpos = jnp.tile(jnp.arange(l)[None], (b, 1))
    qpos = jnp.asarray(rng.integers(l // 2, l, (b,)), jnp.int32)
    out = ops.decode_attention(q, k, v, kpos, qpos, window=window,
                               block_len=128)
    out_r = ref.decode_attention_ref(q, k, v, kpos, qpos, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_r),
                               rtol=3e-5, atol=3e-5)


def test_decode_attention_invalid_slots_ignored():
    b, h, d, l = 1, 2, 32, 64
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, l, h, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, l, h, d)), jnp.float32)
    kpos = jnp.tile(jnp.arange(l)[None], (b, 1))
    # poison half the slots
    k2 = k.at[:, ::2].set(1e4)
    v2 = v.at[:, ::2].set(1e4)
    kpos2 = kpos.at[:, ::2].set(-1)
    qpos = jnp.asarray([l - 1], jnp.int32)
    a = ops.decode_attention(q, k2, v2, kpos2, qpos, block_len=32)
    bref = ref.decode_attention_ref(q, k2, v2, kpos2, qpos)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bref), rtol=3e-5,
                               atol=3e-5)


# ---------------------------------------------------------------------------
# ssd chunk
# ---------------------------------------------------------------------------

@settings(**SET)
@given(
    b=st.integers(1, 2),
    q=st.sampled_from([32, 64, 128]),
    h=st.integers(1, 3),
    n=st.sampled_from([16, 64]),
    p=st.sampled_from([32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ssd_chunk_matches_ref(b, q, h, n, p, seed):
    rng = np.random.default_rng(seed)
    c = jnp.asarray(rng.standard_normal((b, q, h, n)), jnp.float32)
    bm = jnp.asarray(rng.standard_normal((b, q, h, n)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, q, h, p)), jnp.float32)
    cum = jnp.cumsum(
        -jnp.abs(jnp.asarray(rng.standard_normal((b, q, h)), jnp.float32))
        * 0.1, axis=1)
    scale = jnp.abs(jnp.asarray(rng.standard_normal((b, q, h)), jnp.float32))
    h0 = jnp.asarray(rng.standard_normal((b, h, n, p)), jnp.float32)
    y, s = ops.ssd_chunk(c, bm, v, cum, scale, h0)
    yr, sr = ref.ssd_chunk_ref(c, bm, v, cum, scale, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=3e-4,
                               atol=3e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=3e-4,
                               atol=3e-4)


def test_ssd_chunk_consistent_with_model_recurrence():
    """The kernel's chunk math must agree with the model's
    chunked_linear_recurrence for a single chunk."""
    from repro.models.ssm import chunked_linear_recurrence
    rng = np.random.default_rng(3)
    b, q, h, n, p = 1, 32, 2, 8, 16
    c = jnp.asarray(rng.standard_normal((b, q, h, n)), jnp.float32)
    bm = jnp.asarray(rng.standard_normal((b, q, h, n)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, q, h, p)), jnp.float32)
    log_decay = -jnp.abs(
        jnp.asarray(rng.standard_normal((b, q, h)), jnp.float32)) * 0.1
    scale = jnp.abs(jnp.asarray(rng.standard_normal((b, q, h)), jnp.float32))
    cum = jnp.cumsum(log_decay, axis=1)
    h0 = jnp.zeros((b, h, n, p), jnp.float32)

    y_k, s_k = ops.ssd_chunk(c, bm, v, cum, scale, h0)
    y_m, s_m = chunked_linear_recurrence(c, bm, v, log_decay, scale,
                                         chunk=q)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_m), rtol=2e-4,
                               atol=2e-4)
