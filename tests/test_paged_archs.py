"""Cross-architecture paged-serving parity matrix.

Every config in ``src/repro/configs/`` must serve under ``--cache paged``
token-identically to the dense offline ``DecodeSession.generate`` path
(greedy): dense families page their full KV, hybrids page only the
attention sub-cache (conv/ssm leaves stay dense in the carry),
sliding-window layers get a window-bounded ring of blocks with a wrapped
rewind, audio targets carry their dense cross-KV alongside the paged
self-KV, and pure-ssm configs route through the server on the zero-block
layout (admission gated on slots only — there is no pool).

Per family the matrix also pins:

* rollback correctness — a random drafter rejects most drafts, so every
  run rewinds constantly; parity with offline generate proves the rewind
  (wrapped or not) restores exactly the committed history;
* no pool leaks — after the last harvest every allocated block is back in
  the free list (``free + cached == capacity``; trivially true for the
  zero-block ssm layout, asserted as ``pool is None``);
* window-bounded pools — a sliding-window config's per-slot table is
  sized by ``min(max_len, window)``, not the context length, and wraps
  mid-block when the window is not block-aligned.

MoE capacity depends on tokens-per-call, so ``capacity_factor`` is raised
until nothing drops — the offline reference decodes one request at a time
while the server batches slots (see tests/test_models_smoke.py for the
same idiom).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke, list_archs
from repro.configs.base import ModelConfig
from repro.core import EngineConfig, IndependentDrafter
from repro.core.session import DecodeSession
from repro.models import build_model
from repro.serving import Request, SamplingParams, ServerConfig, SpecServer

K = 2
MAX_PROMPT = 8


def _tiny_drafter(cfg):
    d_cfg = ModelConfig(name="d", family="dense", n_layers=1, d_model=64,
                        n_heads=2, n_kv_heads=2, d_ff=128,
                        vocab_size=cfg.vocab_size, dtype="float32")
    return build_model(d_cfg)


def _requests(cfg, n=3):
    rng = np.random.default_rng(7)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(4, MAX_PROMPT + 1))
        reqs.append(Request(
            uid=i,
            prompt=rng.integers(3, cfg.vocab_size, plen).astype(np.int32),
            params=SamplingParams(max_tokens=[4, 6, 8][i % 3],
                                  temperature=0.0)))
    return reqs


def _offline_ref(session, t_params, d_params, reqs):
    out = {}
    for req in reqs:
        plen, mt = len(req.prompt), req.params.max_tokens
        padded = np.zeros((MAX_PROMPT,), np.int32)
        padded[:plen] = req.prompt
        o = session.generate(t_params, d_params, jnp.asarray(padded)[None],
                             jnp.asarray([plen], jnp.int32), mt,
                             jax.random.PRNGKey(0))
        out[req.uid] = np.asarray(o["tokens"])[0, plen:plen + mt]
    return out


@pytest.fixture(scope="module", params=list_archs())
def arch_run(request):
    """One paged serving run + its dense offline reference per config."""
    arch = request.param
    cfg = dataclasses.replace(get_smoke(arch), dtype="float32",
                              capacity_factor=8.0)
    tgt = build_model(cfg)
    drf = _tiny_drafter(cfg)
    t_params = tgt.init(jax.random.PRNGKey(1))
    d_params = drf.init(jax.random.PRNGKey(2))
    ecfg = EngineConfig(k=K, rule="mars", mode="greedy", temperature=0.0)
    reqs = _requests(cfg)

    # offline dense reference: one request at a time, no paging anywhere
    # (whisper runs encoder-free on both sides: the server never feeds
    # encoder frames, so the reference must not either)
    session = DecodeSession(tgt, IndependentDrafter(drf, k=K,
                                                    temperature=0.0), ecfg)
    offline = _offline_ref(session, t_params, d_params, reqs)

    server = SpecServer(
        tgt, IndependentDrafter(drf, k=K, temperature=0.0),
        t_params, d_params, ecfg,
        ServerConfig(slots=2, max_len=48, max_prompt_len=MAX_PROMPT,
                     cache="paged", block_size=8))
    for r in reqs:
        server.submit(r)
    resps = {r.uid: r for r in server.run()}
    return dict(arch=arch, cfg=cfg, server=server, offline=offline,
                resps=resps)


def test_paged_server_matches_dense_offline(arch_run):
    """The prize assertion: paged serving is bit-for-bit the dense offline
    decode on every architecture family."""
    offline, resps = arch_run["offline"], arch_run["resps"]
    assert sorted(resps) == sorted(offline)
    for uid in offline:
        np.testing.assert_array_equal(
            np.asarray(resps[uid].tokens), offline[uid],
            err_msg=f"{arch_run['arch']} req {uid}: paged != dense offline")


def test_rollback_exercised(arch_run):
    """Parity is only meaningful if the rewind path actually ran: the
    random drafter must have had drafts rejected (fewer than K accepted
    draft tokens per cycle), forcing a rollback — index rewind for paged
    attention (wrapped under a window), recompute for recurrent families
    — in every serving run."""
    resps = arch_run["resps"].values()
    assert any(r.n_accepted < K * r.n_cycles for r in resps), (
        arch_run["arch"],
        [(r.n_accepted, r.n_cycles) for r in resps])


def test_pool_drains_after_harvest(arch_run):
    """No leaked blocks: after the last harvest the free list holds every
    allocatable block again.  Pure-ssm runs have no pool at all — the
    zero-block layout admits on slots only."""
    server, cfg = arch_run["server"], arch_run["cfg"]
    if cfg.family == "ssm":
        assert server.pool is None
        assert server.paged is None
        assert all(not blks for blks in server.slot_blocks)
    else:
        assert server.pool is not None
        assert server.pool.available == server.pool.n_blocks - 1


def test_windowed_table_bounded_by_window(arch_run):
    """A sliding-window config's block table is sized by the window, not
    max_len; everyone else gets the full-context table."""
    server, cfg = arch_run["server"], arch_run["cfg"]
    if cfg.family == "ssm":
        pytest.skip("zero-block layout has no table")
    bs = 8
    ring = min(48, cfg.sliding_window) if cfg.sliding_window else 48
    assert server.max_blocks == -(-ring // bs)


# ---------------------------------------------------------------------------
# Wrapped rewind for real: a window small enough to wrap many times
# ---------------------------------------------------------------------------

def test_wrapping_window_serves_token_identical():
    """A window far below the generated length forces the block ring to
    wrap repeatedly — with window % block_size != 0, so the ring wraps
    mid-block (the exact-ring contract, not the block-rounded one) — and
    paged serving must still match the dense ring offline."""
    cfg = dataclasses.replace(get_smoke("granite-8b"), dtype="float32",
                              sliding_window=10)
    tgt = build_model(cfg)
    drf = _tiny_drafter(cfg)
    t_params = tgt.init(jax.random.PRNGKey(1))
    d_params = drf.init(jax.random.PRNGKey(2))
    ecfg = EngineConfig(k=K, rule="mars", mode="greedy", temperature=0.0)
    rng = np.random.default_rng(3)
    reqs = [Request(uid=i,
                    prompt=rng.integers(3, cfg.vocab_size, 6).astype(np.int32),
                    params=SamplingParams(max_tokens=20, temperature=0.0))
            for i in range(2)]

    session = DecodeSession(tgt, IndependentDrafter(drf, k=K,
                                                    temperature=0.0), ecfg)
    offline = {}
    for req in reqs:
        o = session.generate(t_params, d_params,
                             jnp.asarray(req.prompt)[None],
                             jnp.asarray([6], jnp.int32), 20,
                             jax.random.PRNGKey(0))
        offline[req.uid] = np.asarray(o["tokens"])[0, 6:26]

    server = SpecServer(
        tgt, IndependentDrafter(drf, k=K, temperature=0.0),
        t_params, d_params, ecfg,
        ServerConfig(slots=2, max_len=96, max_prompt_len=8,
                     cache="paged", block_size=4))
    # the ring is ceil(10/4) = 3 blocks per slot, not ceil(96/4) = 24
    assert server.max_blocks == 3
    for r in reqs:
        server.submit(r)
    resps = {r.uid: np.asarray(r.tokens) for r in server.run()}
    for uid in offline:
        np.testing.assert_array_equal(resps[uid], offline[uid],
                                      err_msg=f"wrap req {uid}")
    assert server.pool.available == server.pool.n_blocks - 1
