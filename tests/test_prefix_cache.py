"""Prefix cache over the paged pool: refcounted block sharing, COW, and
cached-prefix admission.

The lifecycle under test (docs/ARCHITECTURE.md "Prefix cache"): blocks now
outlive the requests that wrote them — published full blocks are mapped
read-only into later slots, a partially matching tail block is
copy-on-write cloned before the first write, and speculative rollback never
touches a shared block.  Every sharing path must be byte-identical to the
cold cache, and the pool must drain leak-free.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.configs.base import ModelConfig
from repro.core import EngineConfig, IndependentDrafter, make_generate_fn
from repro.models import build_model
from repro.models.paging import BlockPool, ShardedBlockPool
from repro.serving import (PrefixCache, Request, SamplingParams,
                           ServerConfig, SpecServer)

BS = 8                                   # block size used throughout


# ---------------------------------------------------------------------------
# Pool refcounts + reclaimable LRU (host side, no devices)
# ---------------------------------------------------------------------------

def test_pool_refcount_share_and_release():
    pool = BlockPool(9)
    a = pool.alloc(3)
    pool.acquire(a)                       # a second slot maps them
    assert all(pool.refcount(b) == 2 for b in a)
    pool.free(a)                          # first slot done
    assert pool.available == 5            # still referenced: not reusable
    pool.free(a)                          # second slot done
    assert pool.available == 8
    with pytest.raises(ValueError, match="double free"):
        pool.free(a[:1])


def test_pool_retained_blocks_are_reclaimable_lru():
    pool = BlockPool(9)
    retained, evicted = set(), []
    pool.retain_cb = lambda b: b in retained
    pool.evict_cb = evicted.append
    a = pool.alloc(4)
    retained.update(a[:2])
    pool.free(a)
    # 2 blocks parked in the LRU, 2 went straight back to the free list
    assert pool.n_cached == 2 and pool.available == 8
    got = pool.alloc(8)                   # forces eviction of both
    assert sorted(got) == list(range(1, 9))
    assert sorted(evicted) == sorted(a[:2])
    pool.free(got)
    assert pool.available == 8


def test_sharded_pool_refcount_and_lru_stay_shard_local():
    pool = ShardedBlockPool(16, n_shards=2)
    retained = set()
    pool.retain_cb = lambda b: b in retained
    a = pool.alloc(3, shard=0)
    b = pool.alloc(3, shard=1)
    retained.update(a + b)
    pool.free(a + b)
    assert pool.n_cached(0) == 3 and pool.n_cached(1) == 3
    assert pool.available(0) == 7
    got = pool.alloc(7, shard=0)          # evicts shard 0's cached only
    assert all(blk < 8 for blk in got)
    assert pool.n_cached(1) == 3          # shard 1 untouched
    pool.free(got)
    pool.evict_all_cached()
    assert pool.available(0) == pool.available(1) == 7


def test_pool_cached_size_cap_evicts_oldest_first():
    """max_cached bounds the parked LRU: insertion past the cap reclaims
    oldest-first (to the free list, index notified), never live blocks."""
    pool = BlockPool(12, max_cached=2)
    evicted = []
    pool.retain_cb = lambda b: True
    pool.evict_cb = evicted.append
    a = pool.alloc(4)
    pool.free(a)                          # parks a[0..3]; cap forces 2 out
    assert pool.n_cached == 2
    assert evicted == a[:2]               # oldest (first-freed) went first
    assert pool.available == 11           # reclaimed blocks are free again
    got = pool.alloc(11)                  # the survivors still evict on
    assert sorted(got) == list(range(1, 12))   # allocation pressure
    assert sorted(evicted) == sorted(a)


def test_pool_cached_ttl_expires_unused_blocks():
    """ttl_s reclaims parked blocks that sat unused too long; the sweep
    runs inside alloc() so no extra host hook is needed."""
    t = [0.0]
    pool = BlockPool(9, ttl_s=10.0, time_fn=lambda: t[0])
    evicted = []
    pool.retain_cb = lambda b: True
    pool.evict_cb = evicted.append
    a = pool.alloc(3)
    pool.free(a)                          # parked at t=0
    t[0] = 5.0
    assert pool.sweep_expired() == 0      # young: survives
    assert pool.n_cached == 3
    t[0] = 11.0
    got = pool.alloc(1)                   # alloc sweeps the expired first
    assert pool.n_cached == 0 and sorted(evicted) == sorted(a)
    pool.free(got)
    assert pool.available == 8


def test_sharded_pool_caps_split_per_shard():
    pool = ShardedBlockPool(16, n_shards=2, max_cached=2)
    pool.retain_cb = lambda b: True
    a = pool.alloc(3, shard=0)
    b = pool.alloc(3, shard=1)
    pool.free(a + b)
    # global cap of 2 splits to 1 per shard (ceil), enforced shard-locally
    assert pool.n_cached(0) == 1 and pool.n_cached(1) == 1


# ---------------------------------------------------------------------------
# PrefixCache index (host side)
# ---------------------------------------------------------------------------

def _published_cache(pool=None, toks=None, blocks=(5, 6, 7)):
    pool = pool or BlockPool(32)
    pc = PrefixCache(pool, BS)
    toks = np.arange(100, 100 + 3 * BS, dtype=np.int32) if toks is None else toks
    taken = pool.alloc(len(blocks))
    pc.publish(toks, taken)
    pool.free(taken)                      # published -> parked in LRU
    return pc, pool, toks, taken


def test_match_walks_full_blocks_and_partial_tail():
    pc, pool, toks, taken = _published_cache()
    # full match of all 3 blocks
    m = pc.match(np.concatenate([toks, [7, 7]]), usable=3 * BS)
    assert m.blocks == taken and m.cow is None and m.tokens == 3 * BS
    # divergence mid-block 1: full match of block 0, partial tail of block 1
    q = toks.copy()
    q[BS + 3:] = 9
    m = pc.match(q, usable=len(q))
    assert m.blocks == taken[:1]
    assert m.cow == (taken[1], 3) and m.tokens == BS + 3
    # no common prefix: miss
    m = pc.match(np.full(20, 3, np.int32), usable=20)
    assert not m.hit


def test_min_match_blocks_gates_small_hits():
    pc, pool, toks, taken = _published_cache()
    pc.min_match_blocks = 2
    m = pc.match(np.concatenate([toks[:BS], [9] * BS]), usable=2 * BS)
    assert not m.hit                      # 1 matched block < floor of 2
    m = pc.match(toks, usable=3 * BS)
    assert m.hit and len(m.blocks) == 3


def test_eviction_drops_index_entries():
    pc, pool, toks, taken = _published_cache()
    assert pc.n_indexed == 3
    grab = pool.alloc(pool.available)     # evicts all three cached blocks
    assert pc.n_indexed == 0 and pc.stats.evictions == 3
    assert not pc.match(toks, usable=3 * BS).hit
    pool.free(grab)
    assert pool.available == pool.n_blocks - 1   # refcount-leak free


def test_duplicate_publish_keeps_first_block():
    pc, pool, toks, taken = _published_cache()
    dup = pool.alloc(3)
    assert pc.publish(toks, dup) == 0     # chain already indexed
    pool.free(dup)                        # not retained: straight to free
    assert pool.n_cached == 3


def test_kv_dtype_keys_never_alias():
    """Keys are kv-dtype-aware: an int8 pool's block bytes are not a bf16
    pool's block bytes for the same tokens, so indexes built at different
    storage dtypes must never return each other's chains — the dtype is
    hashed into the key root, not bolted onto the query."""
    toks = np.arange(100, 100 + 3 * BS, dtype=np.int32)
    chains = {}
    for kv in ("bf16", "int8", "fp8"):
        pool = BlockPool(32)
        pc = PrefixCache(pool, BS, kv_dtype=kv)
        taken = pool.alloc(3)
        pc.publish(toks, taken)
        pool.free(taken)
        chains[kv] = (pc, taken)
        # each index still matches its own publications...
        m = pc.match(toks, usable=3 * BS)
        assert m.hit and m.blocks == taken, kv
    # ...and the key roots differ per dtype, so the first-block keys (and
    # every chained key after them) can never collide across indexes
    roots = {kv: pc._root for kv, (pc, _) in chains.items()}
    assert len(set(roots.values())) == 3, roots
    from repro.serving.prefix_cache import _chain_key
    first = {kv: _chain_key(root, toks[:BS]) for kv, root in roots.items()}
    assert len(set(first.values())) == 3, first


# ---------------------------------------------------------------------------
# Serving lifecycle (device): parity, COW, rollback safety, leak checks
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_smoke("granite-8b"), dtype="float32")
    tgt = build_model(cfg)
    d_cfg = ModelConfig(name="d", family="dense", n_layers=1, d_model=64,
                        n_heads=2, n_kv_heads=2, d_ff=128,
                        vocab_size=cfg.vocab_size, dtype="float32")
    drf = build_model(d_cfg)
    return (cfg, tgt, drf, tgt.init(jax.random.PRNGKey(1)),
            drf.init(jax.random.PRNGKey(2)))


def _server(setup, prefix="on", *, slots=4, pool_blocks=0, max_prompt=48,
            max_len=96, k=3, **extra):
    cfg, tgt, drf, t_params, d_params = setup
    return SpecServer(
        tgt, IndependentDrafter(drf, k=k, temperature=0.0),
        t_params, d_params,
        EngineConfig(k=k, rule="strict", mode="greedy", temperature=0.0),
        ServerConfig(slots=slots, max_len=max_len, max_prompt_len=max_prompt,
                     cache="paged", block_size=BS, pool_blocks=pool_blocks,
                     prefix_cache=prefix, **extra))


def _serve(server, reqs):
    for r in reqs:
        server.submit(dataclasses.replace(r))
    return {r.uid: np.asarray(r.tokens) for r in server.run()}


def _reqs(cfg, shared_len=24, n=8, suffix=6, max_tokens=10, seed=3):
    rng = np.random.default_rng(seed)
    system = rng.integers(3, cfg.vocab_size, shared_len).astype(np.int32)
    out = []
    for i in range(n):
        tail = rng.integers(3, cfg.vocab_size, suffix).astype(np.int32)
        out.append(Request(uid=i, prompt=np.concatenate([system, tail]),
                           params=SamplingParams(max_tokens=max_tokens,
                                                 temperature=0.0)))
    return out


def test_shared_prefix_token_identical_to_cold(setup):
    """Greedy outputs with block sharing on == cold-cache generate, per
    request, and the prefill work drops by more than half."""
    cfg, tgt, drf, t_params, d_params = setup
    reqs = _reqs(cfg)
    off_srv, on_srv = _server(setup, "off"), _server(setup, "on")
    off = _serve(off_srv, reqs)
    on = _serve(on_srv, reqs)
    assert sorted(off) == sorted(on)
    for uid in off:
        np.testing.assert_array_equal(on[uid], off[uid], err_msg=f"uid {uid}")
    # offline cold-cache reference for a couple of requests
    gen = make_generate_fn(
        tgt, IndependentDrafter(drf, k=3, temperature=0.0),
        EngineConfig(k=3, rule="strict", mode="greedy", temperature=0.0))
    for r in reqs[:2]:
        out = gen(t_params, d_params, jnp.asarray(r.prompt)[None],
                  jnp.asarray([len(r.prompt)], jnp.int32),
                  jax.random.PRNGKey(0), max_new=10)
        ref = np.asarray(out["tokens"])[0, len(r.prompt):len(r.prompt) + 10]
        np.testing.assert_array_equal(on[r.uid], ref)
    assert on_srv.prefix.stats.hits >= len(reqs) - 1 - 3  # slots-1 cold max
    assert on_srv.prefill_tokens < off_srv.prefill_tokens / 2


def test_cow_mid_block_divergence(setup):
    """A prompt diverging mid-block against a published sequence maps the
    partially matching block, COW-clones it, and still produces cold-cache
    output — while the publisher's cached content stays intact."""
    cfg = setup[0]
    rng = np.random.default_rng(5)
    base = rng.integers(3, cfg.vocab_size, 30).astype(np.int32)
    div = base.copy()
    div[19:] = rng.integers(3, cfg.vocab_size, 11).astype(np.int32)  # mid-blk 2
    reqs = [Request(uid=i, prompt=p,
                    params=SamplingParams(max_tokens=8, temperature=0.0))
            for i, p in enumerate([base, div, base])]
    cold = _serve(_server(setup, "off"), reqs)
    srv = _server(setup, "on", slots=1)    # serialised: publish then match
    warm = _serve(srv, reqs)
    for uid in cold:
        np.testing.assert_array_equal(warm[uid], cold[uid],
                                      err_msg=f"uid {uid}")
    s = srv.prefix.stats
    assert s.cow_clones >= 1               # uid 1 cloned blocks[2] rows 0..2
    assert s.hits >= 2                     # uid 1 (partial) and uid 2 (full)


def test_rollback_on_shared_blocks_never_corrupts_siblings(setup):
    """Concurrent slots share one prefix while speculating (drafts mostly
    rejected -> a rollback every cycle); afterwards the published blocks
    must still serve a fresh request with cold-identical output."""
    cfg = setup[0]
    reqs = _reqs(cfg, shared_len=24, n=6, max_tokens=12, seed=11)
    cold = _serve(_server(setup, "off"), reqs)
    srv = _server(setup, "on")
    warm = _serve(srv, reqs)               # 4 slots: concurrent sharing
    for uid in cold:
        np.testing.assert_array_equal(warm[uid], cold[uid],
                                      err_msg=f"uid {uid}")
    # the shared blocks survived every sibling's speculative rollback:
    # a late request re-using them still matches the cold cache
    late = [dataclasses.replace(reqs[0], uid=99)]
    out = _serve(srv, late)
    np.testing.assert_array_equal(out[99], cold[0])


def test_pool_leak_free_after_harvest_and_eviction(setup):
    """After all requests drain: every block is either free or a
    refcount-0 cached block; explicit eviction returns the pool to
    all-free (the refcount-leak check)."""
    cfg = setup[0]
    srv = _server(setup, "on")
    _serve(srv, _reqs(cfg))
    pool = srv.pool
    assert pool.available == pool.n_blocks - 1        # cached counted
    assert pool.n_cached == srv.prefix.n_indexed
    pool.evict_all_cached()
    assert srv.prefix.n_indexed == 0
    assert pool.available == pool.n_blocks - 1
    assert not pool._ref                              # zero live references


def test_prefix_cache_max_blocks_bounds_parked_lru(setup):
    """Serving with a parked-LRU cap: outputs stay cold-identical, the
    pool never parks more than the cap, and the index never disagrees
    with the pool about what is still cached."""
    cfg = setup[0]
    reqs = _reqs(cfg)
    cold = _serve(_server(setup, "off"), reqs)
    srv = _server(setup, "on", prefix_cache_max_blocks=2)
    warm = _serve(srv, reqs)
    for uid in cold:
        np.testing.assert_array_equal(warm[uid], cold[uid],
                                      err_msg=f"uid {uid}")
    assert srv.pool.n_cached <= 2
    assert srv.prefix.n_indexed == srv.pool.n_cached
    assert srv.prefix.stats.evictions > 0      # the cap actually bit


def test_prefix_flops_and_concurrency_acceptance(setup):
    """Scaled version of the acceptance criterion: with a shared system
    prompt, prefill positions <= 1/4 of off, and admitted concurrency at
    equal pool bytes >= 2x."""
    cfg = setup[0]
    reqs = _reqs(cfg, shared_len=32, n=12, suffix=4, max_tokens=6, seed=7)
    off_srv = _server(setup, "off", slots=6)
    on_srv = _server(setup, "on", slots=6)
    off = _serve(off_srv, reqs)
    on = _serve(on_srv, reqs)
    for uid in off:
        np.testing.assert_array_equal(on[uid], off[uid])
    assert on_srv.prefill_tokens <= off_srv.prefill_tokens / 4

    # equal pool bytes: room for ~2 cold requests
    need = off_srv._blocks_needed(36, 6)
    pool_blocks = 2 * need + 2

    def peak(prefix):
        srv = _server(setup, prefix, slots=6, pool_blocks=pool_blocks)
        for r in reqs:
            srv.submit(dataclasses.replace(r))
        peak = 0
        for _ in range(10_000):
            if not srv.queue and all(x is None for x in srv.slot_req):
                break
            srv._admit()
            peak = max(peak, sum(x is not None for x in srv.slot_req))
            srv.step()
            srv.sync()
        assert len(srv._responses) == len(reqs)
        return peak

    assert peak("on") >= 2 * peak("off")


def test_tree_topology_with_feature_drafter(setup):
    """Tree drafts (EAGLE-style, ``wants_features``) share prefixes too:
    the usable prefix is clamped to plen-2 so the drafter's grounding
    feature is always decoded live."""
    from repro.core import EagleDrafter, init_eagle_params
    cfg, tgt, _, t_params, _ = setup
    e_params = init_eagle_params(cfg, jax.random.PRNGKey(2))
    ecfg = EngineConfig(k=3, rule="strict", mode="greedy", temperature=0.0,
                        topology="tree", branch=2)
    reqs = _reqs(cfg, shared_len=24, n=4, max_tokens=8, seed=13)

    def serve(prefix):
        srv = SpecServer(
            tgt, EagleDrafter(tgt, k=3, temperature=0.0), t_params,
            e_params, ecfg,
            ServerConfig(slots=2, max_len=96, max_prompt_len=32,
                         cache="paged", block_size=BS,
                         prefix_cache=prefix))
        return _serve(srv, reqs), srv

    off, _ = serve("off")
    on, srv = serve("on")
    for uid in off:
        np.testing.assert_array_equal(on[uid], off[uid], err_msg=f"uid {uid}")
    assert srv.prefix.stats.hits >= 2
    # the grounding token was never swallowed by a cached prefix
    assert all(int(s) <= len(reqs[0].prompt) - 2
               for s in srv.slot_start)


def test_prefix_cache_requires_paged(setup):
    cfg, tgt, drf, t_params, d_params = setup
    with pytest.raises(ValueError, match="requires"):
        SpecServer(tgt, None, t_params, d_params, EngineConfig(k=2),
                   ServerConfig(slots=2, cache="dense", prefix_cache="on"))


def test_prefix_cache_rejects_recurrent(setup):
    """Hybrid targets can page their attention sub-cache, but their mamba
    state cannot be reconstructed from shared KV blocks."""
    cfg = ModelConfig(name="h", family="hybrid", n_layers=4,
                      hybrid_attn_every=2, d_model=64, n_heads=2,
                      n_kv_heads=2, d_ff=128, ssm_state=16, ssm_head_dim=32,
                      vocab_size=61, dtype="float32")
    tgt = build_model(cfg)
    with pytest.raises(ValueError, match="recurrent"):
        SpecServer(tgt, None, None, None, EngineConfig(k=2),
                   ServerConfig(slots=2, cache="paged", prefix_cache="on"))
