"""Per-slot adaptive verification: theta as device carry state + the
margin/acceptance controller.

Three layers under test (docs/ARCHITECTURE.md "Adaptive verification"):

* verify layer — ``theta`` may be a per-row ``(B,)`` vector anywhere a
  scalar was accepted (reference AND fused kernel paths), a uniform vector
  is bit-identical to the scalar it splats, and rows never interact;
* controller — the pure host policy is monotone (pressure relaxes, relaxed
  overshoot tightens) and always clamped;
* server — ``theta_mode="adaptive"`` keeps the sync-free tick contract
  (zero device→host transfers inside ``step()``) and a clamped controller
  (theta_min == theta_max) reproduces fixed-mode output token for token.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.configs.base import ModelConfig
from repro.core import EngineConfig, IndependentDrafter
from repro.core import verify as V
from repro.core.tree import make_caterpillar, verify_tree
from repro.kernels import ops, ref
from repro.models import build_model
from repro.serving import (ControllerConfig, Request, SamplingParams,
                           ServerConfig, SpecServer, ThetaController)


# ---------------------------------------------------------------------------
# Kernel layer: per-row theta
# ---------------------------------------------------------------------------

def test_kernel_per_row_theta_matches_ref():
    rng = np.random.default_rng(0)
    b, k, v = 5, 4, 257
    logits = jnp.asarray(rng.standard_normal((b, k, v)) * 3, jnp.float32)
    draft = jnp.asarray(rng.integers(0, v, (b, k)), jnp.int32)
    # plant exact matches and near-ties so both masks have signal
    _, idx = jax.lax.top_k(logits, 2)
    draft = draft.at[:, 0].set(idx[:, 0, 0]).at[:, 1].set(idx[:, 1, 1])
    thetas = jnp.asarray([0.5, 0.8, 0.9, 0.97, 0.999], jnp.float32)
    e, r, _, _, z1, z2 = ops.mars_verify_stats(draft, logits, thetas)
    for i in range(b):
        er, rr, _, _ = ref.mars_verify_ref(draft[i], logits[i],
                                           float(thetas[i]))
        np.testing.assert_array_equal(np.asarray(e[i]), np.asarray(er),
                                      err_msg=f"row {i} exact")
        np.testing.assert_array_equal(np.asarray(r[i]), np.asarray(rr),
                                      err_msg=f"row {i} relax")
    # z1/z2 are the true top-2 (the margin stats the carry accumulates)
    vals, _ = jax.lax.top_k(logits, 2)
    np.testing.assert_allclose(np.asarray(z1), np.asarray(vals[..., 0]),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(z2), np.asarray(vals[..., 1]),
                               rtol=1e-6)


def test_kernel_uniform_vector_theta_equals_scalar():
    rng = np.random.default_rng(1)
    b, k, v = 3, 5, 127
    logits = jnp.asarray(rng.standard_normal((b, k, v)) * 3, jnp.float32)
    draft = jnp.asarray(rng.integers(0, v, (b, k)), jnp.int32)
    _, idx = jax.lax.top_k(logits, 2)
    draft = draft.at[:, 0].set(idx[:, 0, 1])      # near-tie candidates
    a = ops.mars_verify_stats(draft, logits, 0.9)
    bvec = ops.mars_verify_stats(draft, logits, jnp.full((b,), 0.9))
    for x, y in zip(a, bvec):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# Verify layer: chain + tree, vector theta
# ---------------------------------------------------------------------------

def _chain_case(seed=2, b=4, k=3, v=61):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.standard_normal((b, k + 1, v)) * 2, jnp.float32)
    # drafts: mix of top-1 (exact), top-2 (relaxable), and garbage
    _, idx = jax.lax.top_k(logits[:, :k], 2)
    draft = jnp.asarray(rng.integers(0, v, (b, k)), jnp.int32)
    draft = draft.at[:, 0].set(idx[:, 0, 0]).at[:, 1].set(idx[:, 1, 1])
    return draft, logits


@pytest.mark.parametrize("use_kernel", [False, True])
def test_chain_uniform_vector_theta_bitwise(use_kernel):
    draft, logits = _chain_case()
    b = draft.shape[0]
    kw = dict(rule="mars", mode="greedy", temperature=0.0,
              key=jax.random.PRNGKey(0), use_kernel=use_kernel)
    r_scalar = V.verify_chain(draft, logits, theta=0.9, **kw)
    r_vec = V.verify_chain(draft, logits, theta=jnp.full((b,), 0.9), **kw)
    for f in r_scalar._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(r_scalar, f)), np.asarray(getattr(r_vec, f)),
            err_msg=f"field {f}")


def test_tree_uniform_vector_theta_bitwise():
    tpl = make_caterpillar(k=2, branch=2)
    v, b = 31, 3
    n = len(tpl.depth)
    rng = np.random.default_rng(4)
    node_tokens = jnp.asarray(rng.integers(0, v, (b, n)), jnp.int32)
    logits = jnp.asarray(rng.standard_normal((b, n, v)) * 2, jnp.float32)
    kw = dict(rule="mars", mode="greedy", temperature=0.0,
              key=jax.random.PRNGKey(1))
    r_scalar = verify_tree(tpl, node_tokens, logits, theta=0.85, **kw)
    r_vec = verify_tree(tpl, node_tokens, logits,
                        theta=jnp.full((b,), 0.85), **kw)
    for i, (x, y) in enumerate(zip(r_scalar, r_vec)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"output {i}")


@pytest.mark.parametrize("use_kernel", [False, True])
def test_mixed_theta_rows_are_independent(use_kernel):
    """A batch row verified at theta_i must equal the same row verified
    alone at theta_i — neighbours' thresholds can never leak across rows."""
    draft, logits = _chain_case(seed=5)
    b = draft.shape[0]
    thetas = jnp.asarray([0.55, 0.8, 0.92, 0.99], jnp.float32)
    kw = dict(rule="mars", mode="greedy", temperature=0.0,
              key=jax.random.PRNGKey(0), use_kernel=use_kernel)
    mixed = V.verify_chain(draft, logits, theta=thetas, **kw)
    for i in range(b):
        solo = V.verify_chain(draft[i:i + 1], logits[i:i + 1],
                              theta=float(thetas[i]), **kw)
        for f in mixed._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(mixed, f))[i:i + 1],
                np.asarray(getattr(solo, f)),
                err_msg=f"row {i} field {f}")


def test_margin_sample_at_first_rejection():
    """The per-cycle margin sample is the top-2 ratio at the first rejected
    position, and -1 (no sample) on fully accepted rows."""
    v = 16
    logits = np.full((2, 3, v), -5.0, np.float32)
    # row 0: draft rejected at pos 0 with a clean ratio 4.0/5.0 = 0.8
    logits[0, 0, 3] = 5.0
    logits[0, 0, 7] = 4.0
    # row 1: both drafts are the argmax -> full accept
    logits[1, 0, 2] = 5.0
    logits[1, 1, 4] = 5.0
    logits[1, 2, 1] = 5.0
    draft = jnp.asarray([[9, 9], [2, 4]], jnp.int32)
    res = V.verify_chain(draft, jnp.asarray(logits), rule="mars",
                         mode="greedy", theta=0.95, temperature=0.0,
                         key=jax.random.PRNGKey(0))
    assert np.isclose(float(res.margin[0]), 0.8, atol=1e-6)
    assert float(res.margin[1]) == -1.0


# ---------------------------------------------------------------------------
# Controller: monotone + clamped
# ---------------------------------------------------------------------------

def test_controller_pressure_monotone_and_clamped():
    ctl = ThetaController(ControllerConfig(theta_min=0.6, theta_max=0.99))
    theta = np.asarray([0.9, 0.8, 0.7])
    share = np.asarray([0.25, 0.25, 0.25])      # exactly on budget
    ema = np.zeros(3)                           # no margin signal
    prev = ctl.update(theta, share, ema, pressure=0.0)
    for p in (0.5, 1.0, 2.0, 10.0, 1000.0):
        cur = ctl.update(theta, share, ema, pressure=p)
        assert (cur <= prev + 1e-12).all(), f"pressure {p} raised theta"
        assert (cur >= 0.6 - 1e-12).all() and (cur <= 0.99 + 1e-12).all()
        prev = cur
    # unbounded pressure pins every slot at the floor, never below
    np.testing.assert_allclose(ctl.update(theta, share, ema, 1e6),
                               np.full(3, 0.6))


def test_controller_relaxed_overshoot_tightens():
    ctl = ThetaController(ControllerConfig(relax_budget=0.25))
    theta = np.full(4, 0.8)
    ema = np.zeros(4)
    lo = ctl.update(theta, np.full(4, 0.05), ema, pressure=0.0)
    hi = ctl.update(theta, np.full(4, 0.9), ema, pressure=0.0)
    assert (hi > lo).all()                      # overshoot => stricter
    assert (hi > theta).all() and (lo < theta).all()


def test_controller_margin_pull_and_validation():
    ctl = ThetaController(ControllerConfig())
    theta = np.asarray([0.9, 0.9])
    share = np.asarray([0.25, 0.25])
    # slot 0 sees near-ties at ratio 0.7: theta is pulled down toward it;
    # slot 1 has no sample (EMA sentinel 0) and stays put
    out = ctl.update(theta, share, np.asarray([0.7, 0.0]), pressure=0.0)
    assert out[0] < theta[0] and np.isclose(out[1], 0.9)
    with pytest.raises(ValueError, match="theta_min"):
        ThetaController(ControllerConfig(theta_min=0.9, theta_max=0.8))


# ---------------------------------------------------------------------------
# Server: adaptive mode keeps the device-resident contract
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def server_setup():
    cfg = dataclasses.replace(get_smoke("granite-8b"), dtype="float32")
    tgt = build_model(cfg)
    d_cfg = ModelConfig(name="d", family="dense", n_layers=1, d_model=64,
                        n_heads=2, n_kv_heads=2, d_ff=128,
                        vocab_size=cfg.vocab_size, dtype="float32")
    drf = build_model(d_cfg)
    return (cfg, tgt, drf, tgt.init(jax.random.PRNGKey(1)),
            drf.init(jax.random.PRNGKey(2)))


def _server(setup, **scfg):
    cfg, tgt, drf, t_params, d_params = setup
    return SpecServer(
        tgt, IndependentDrafter(drf, k=3, temperature=0.0),
        t_params, d_params,
        EngineConfig(k=3, rule="mars", mode="greedy", temperature=0.0,
                     theta=0.9, guard="margin"),
        ServerConfig(slots=2, max_len=96, max_prompt_len=12, **scfg))


def _reqs(cfg, n=6, max_tokens=10, theta=None, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(3, cfg.vocab_size, 6).astype(np.int32),
                    params=SamplingParams(max_tokens=max_tokens,
                                          temperature=0.0, theta=theta))
            for i in range(n)]


def test_per_request_theta_lands_in_carry(server_setup):
    cfg = server_setup[0]
    srv = _server(server_setup)
    reqs = _reqs(cfg, n=2)
    reqs[0].params.theta = 0.7
    reqs[1].params.theta = 0.95
    for r in reqs:
        srv.submit(r)
    srv._admit()
    carried = np.asarray(jax.device_get(srv.state.theta))
    slots = {srv.slot_req[s].uid: s for s in range(2)}
    assert np.isclose(carried[slots[0]], 0.7)
    assert np.isclose(carried[slots[1]], 0.95)
    srv.run()                                   # drain cleanly


def test_adaptive_step_stays_sync_free(server_setup):
    """With the controller on and a queue deeper than the slots (sustained
    pressure -> real retunes), step() still performs zero device→host
    transfers; the controller rides the sync-point poll only."""
    cfg = server_setup[0]
    srv = _server(server_setup, theta_mode="adaptive", theta_min=0.6,
                  theta_max=0.99)
    for r in _reqs(cfg, n=8, max_tokens=16):
        srv.submit(r)

    real_device_get = jax.device_get

    def forbidden(*a, **kw):
        raise AssertionError("device→host transfer inside step()")

    for _ in range(10_000):
        if not srv.queue and all(r is None for r in srv.slot_req):
            break
        srv._admit()
        syncs_before = srv.host_syncs
        jax.device_get = forbidden
        try:
            with jax.transfer_guard_device_to_host("disallow"):
                srv.step()
        finally:
            jax.device_get = real_device_get
        assert srv.host_syncs == syncs_before
        srv.sync()
    resps = srv.run()
    assert sorted(r.uid for r in resps) == list(range(8))
    assert srv.theta_retunes > 0                # the controller actually ran
    assert (srv.slot_theta >= 0.6 - 1e-9).all()
    assert (srv.slot_theta <= 0.99 + 1e-9).all()


def test_adaptive_clamped_equals_fixed(server_setup):
    """theta_min == theta_max == EngineConfig.theta: the controller runs
    (its retune path is exercised) but can never move theta, so outputs
    must be token-identical to fixed mode."""
    cfg = server_setup[0]

    def serve(mode):
        kw = (dict(theta_mode="adaptive", theta_min=0.9, theta_max=0.9)
              if mode == "adaptive" else {})
        srv = _server(server_setup, **kw)
        for r in _reqs(cfg, n=5, max_tokens=12, seed=3):
            srv.submit(r)
        return {r.uid: np.asarray(r.tokens) for r in srv.run()}

    fixed = serve("fixed")
    adaptive = serve("adaptive")
    assert sorted(fixed) == sorted(adaptive)
    for uid in fixed:
        np.testing.assert_array_equal(adaptive[uid], fixed[uid],
                                      err_msg=f"uid {uid}")


def test_adaptive_k_width_buckets(server_setup):
    """adaptive_k pre-jits a half-K program; with a random drafter (low
    acceptance) the controller drops to the short bucket and the run still
    completes every request exactly."""
    cfg = server_setup[0]
    srv = _server(server_setup, theta_mode="adaptive", adaptive_k=True)
    assert srv.session_short is not None
    assert srv.session_short.topology.commit_width == srv._k_short + 1
    for r in _reqs(cfg, n=4, max_tokens=10, seed=5):
        srv.submit(r)
    resps = srv.run()
    assert sorted(r.uid for r in resps) == list(range(4))
    for r in resps:
        assert len(r.tokens) == 10
    # a random drafter keeps tau low -> the short bucket was selected
    assert srv._k_bucket == srv._k_short


def test_adaptive_k_requires_adaptive_chain(server_setup):
    cfg, tgt, drf, t_params, d_params = server_setup
    with pytest.raises(ValueError, match="adaptive"):
        SpecServer(tgt, IndependentDrafter(drf, k=3), t_params, d_params,
                   EngineConfig(k=3),
                   ServerConfig(slots=2, adaptive_k=True))
