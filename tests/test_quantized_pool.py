"""Quantized paged KV pool: int8/fp8 block storage with per-token-head
scales in a parallel pool.

The invariants under test (docs/ARCHITECTURE.md §Quantized pool):

* quantize-on-write round-trips within the storage dtype's rounding error,
* the paged Pallas kernel's in-gather dequant matches the reference
  attention over an explicitly dequantized dense view (shuffled tables),
* COW clones and prefix publish/acquire move block bytes + scale rows as a
  unit — so quantized serving with sharing on equals sharing off, and both
  equal the offline quantized generate,
* rollback stays an index rewind: rewinding over junk drafts and rewriting
  leaves the pool byte-identical to never having speculated (per-write
  quantization is deterministic).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.configs.base import ModelConfig
from repro.core import EngineConfig, IndependentDrafter, make_generate_fn
from repro.models import build_model
from repro.models.paging import (PagedCacheConfig, cow_clone_blocks,
                                 dequantize_kv, full_tables,
                                 kv_dtype_unsupported_reason,
                                 paged_cache_write, pool_block_bytes,
                                 quantize_kv)
from repro.serving import Request, SamplingParams, ServerConfig, SpecServer

FP8 = hasattr(jnp, "float8_e4m3fn")
fp8_only = pytest.mark.skipif(not FP8, reason="no float8_e4m3fn in this jax")

# observed worst case on N(0,1) is ~0.015 (int8) / ~0.10 (fp8); the bound
# is the storage dtype's relative step times the per-row amax
TOL = {"int8": 0.05, "fp8": 0.35}


# ---------------------------------------------------------------------------
# Quantize/dequantize round-trip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_dtype", ["int8"] + (["fp8"] if FP8 else []))
def test_roundtrip_error_bounds(kv_dtype):
    store = jnp.int8 if kv_dtype == "int8" else jnp.float8_e4m3fn
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 16, 2, 32)),
                    jnp.float32)
    q, scale = quantize_kv(x, store)
    assert q.dtype == store and scale.dtype == jnp.float16
    err = np.max(np.abs(np.asarray(dequantize_kv(q, scale)) - np.asarray(x)))
    assert err < TOL[kv_dtype], f"{kv_dtype} round-trip err {err}"


def test_roundtrip_zero_rows_and_outliers():
    # all-zero rows take scale 1.0 (no 0/0) and round-trip exactly
    z = jnp.zeros((2, 4, 8), jnp.float32)
    q, scale = quantize_kv(z, jnp.int8)
    np.testing.assert_array_equal(np.asarray(scale), 1.0)
    np.testing.assert_array_equal(np.asarray(dequantize_kv(q, scale)), 0.0)
    # a per-row outlier widens only its own row's step, not its neighbours'
    x = np.random.default_rng(1).normal(size=(2, 4, 8)).astype(np.float32)
    x[0, 0, 0] = 100.0
    q, scale = quantize_kv(jnp.asarray(x), jnp.int8)
    err = np.abs(np.asarray(dequantize_kv(q, scale)) - x)
    assert err[0, 0].max() < 100.0 / 127 + 1e-3     # outlier row: wide step
    assert err[1].max() < TOL["int8"]               # clean rows unaffected


def test_pool_block_bytes_equal_hbm_arithmetic():
    """The admission criterion rides on this arithmetic: at head_dim 64 a
    bf16 token-head costs 128 bytes, an int8 one 64+2 (payload + fp16
    scale) — the same HBM buys >= 1.9x the blocks."""
    cfg = ModelConfig(name="b", family="dense", n_layers=2, d_model=256,
                      n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=64,
                      dtype="bfloat16")
    b16, i8 = (pool_block_bytes(cfg, 16, d) for d in ("bf16", "int8"))
    assert b16 / i8 >= 1.9
    with pytest.raises(ValueError, match="unknown kv_dtype"):
        pool_block_bytes(cfg, 16, "int4")
    assert kv_dtype_unsupported_reason("bf16") is None
    assert "unknown" in kv_dtype_unsupported_reason("fp4")


# ---------------------------------------------------------------------------
# Cache write / gather / kernel parity (model-free, raw pools)
# ---------------------------------------------------------------------------

def _quantized_cache(rng, *, B=3, Hkv=2, D=16, bs=8, MB=4, kv_dtype="int8"):
    """A written quantized cache over SHUFFLED tables + the f32 original."""
    cfg = ModelConfig(name="q", family="dense", n_layers=1, d_model=64,
                      n_heads=2, n_kv_heads=Hkv, d_ff=64, vocab_size=61,
                      dtype="float32")
    pc = PagedCacheConfig(bs, 1 + B * MB, kv_dtype=kv_dtype)
    L = MB * bs
    table = np.array(full_tables(B, MB))
    rng.shuffle(table.reshape(-1))
    table = jnp.asarray(table)
    store = pc.storage_dtype(cfg)
    cache = {"k_pool": jnp.zeros((pc.n_blocks, bs, Hkv, D), store),
             "v_pool": jnp.zeros((pc.n_blocks, bs, Hkv, D), store),
             "k_scale": jnp.zeros((pc.n_blocks, bs, Hkv), jnp.float16),
             "v_scale": jnp.zeros((pc.n_blocks, bs, Hkv), jnp.float16),
             "pos": jnp.full((B, L), -(1 << 30), jnp.int32),
             "table": table}
    k = jnp.asarray(rng.normal(size=(B, L, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, L, Hkv, D)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32)[None], (B, L))
    return paged_cache_write(cache, k, v, pos), k, v


@pytest.mark.parametrize("kv_dtype", ["int8"] + (["fp8"] if FP8 else []))
def test_write_then_gather_roundtrips(kv_dtype):
    from repro.models.paging import gather_dense_view
    cache, k, v = _quantized_cache(np.random.default_rng(2),
                                   kv_dtype=kv_dtype)
    got = gather_dense_view(cache)
    assert got["k"].dtype == jnp.float32          # dequantized view
    for name, want in (("k", k), ("v", v)):
        err = np.max(np.abs(np.asarray(got[name]) - np.asarray(want)))
        assert err < TOL[kv_dtype], f"{name} gather err {err}"


def test_paged_kernel_int8_parity_shuffled_tables():
    """The Pallas kernel's scale-row prefetch + in-gather dequant must
    match the reference attention fed the explicitly dequantized dense
    view — same quantized content, so the comparison is exact up to
    float accumulation order."""
    from repro.kernels import ops, ref
    from repro.models.paging import gather_dense_view
    B, H, D = 3, 4, 16
    cache, _, _ = _quantized_cache(np.random.default_rng(3), B=B, D=D)
    lens = jnp.asarray([5, 20, 32])
    L = cache["pos"].shape[1]
    k_pos = jnp.where(jnp.arange(L)[None] < lens[:, None],
                      jnp.arange(L)[None], -(1 << 30)).astype(jnp.int32)
    cache = {**cache, "pos": k_pos}
    q = jnp.asarray(np.random.default_rng(4).normal(size=(B, H, D)),
                    jnp.float32)
    q_pos = (lens - 1).astype(jnp.int32)
    out = ops.paged_decode_attention(
        q, cache["k_pool"], cache["v_pool"], cache["table"], k_pos, q_pos,
        k_scale=cache["k_scale"], v_scale=cache["v_scale"])
    dense = gather_dense_view(cache)
    want = ref.decode_attention_ref(q, dense["k"], dense["v"], dense["pos"],
                                    q_pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_cow_clone_moves_payload_and_scales_as_a_unit():
    """COW must copy the quantized bytes AND the scale rows bit-exactly —
    requantizing on clone would drift shared history."""
    cache, _, _ = _quantized_cache(np.random.default_rng(5))
    src = jnp.asarray(np.asarray(cache["table"])[0, :2])
    dst = jnp.asarray(np.asarray(cache["table"])[1, 2:4])
    out = cow_clone_blocks(cache, src, dst)
    for leaf in ("k_pool", "v_pool", "k_scale", "v_scale"):
        np.testing.assert_array_equal(
            np.asarray(out[leaf])[np.asarray(dst)],
            np.asarray(cache[leaf])[np.asarray(src)], err_msg=leaf)
        np.testing.assert_array_equal(          # source rows untouched
            np.asarray(out[leaf])[np.asarray(src)],
            np.asarray(cache[leaf])[np.asarray(src)], err_msg=leaf)


# ---------------------------------------------------------------------------
# Model-level: rollback on a quantized cache is still an index rewind
# ---------------------------------------------------------------------------

def test_quantized_rollback_rewind_is_bytewise_clean():
    """Write junk drafts, rewind the index, rewrite the committed tokens:
    pools AND scale pools must equal a cache that never speculated —
    per-write quantization is deterministic, so equality is exact."""
    cfg = dataclasses.replace(get_smoke("granite-8b"), dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, bs = 2, 8
    pc = PagedCacheConfig(bs, 1 + B * (-(-32 // bs)), kv_dtype="int8")

    def fresh():
        cache = model.init_cache(params, B, 32, paged=pc)
        return model.assign_blocks(cache, jnp.ones((B,), bool),
                                   full_tables(B, pc.max_blocks(32)))

    committed = jax.random.randint(jax.random.PRNGKey(1), (B, 12), 3,
                                   cfg.vocab_size)
    junk = jax.random.randint(jax.random.PRNGKey(2), (B, 4), 3,
                              cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(8, 12, dtype=jnp.int32)[None], (B, 4))

    _, spec = model.prefill(params, committed[:, :8], fresh())
    _, spec = model.decode(params, junk, pos, spec)
    spec = dict(spec)
    spec["index"] = jnp.full((B,), 8, jnp.int32)          # rollback
    lg_spec, spec = model.decode(params, committed[:, 8:12], pos, spec)

    _, clean = model.prefill(params, committed[:, :8], fresh())
    lg_clean, clean = model.decode(params, committed[:, 8:12], pos, clean)
    np.testing.assert_array_equal(np.asarray(lg_spec), np.asarray(lg_clean))

    def pool_leaves(cache):
        return {jax.tree_util.keystr(p): leaf for p, leaf in
                jax.tree_util.tree_flatten_with_path(cache)[0]
                if any(t in jax.tree_util.keystr(p) for t in
                       ("k_pool", "v_pool", "k_scale", "v_scale"))}

    s_leaves, c_leaves = pool_leaves(spec), pool_leaves(clean)
    assert len(s_leaves) >= 4 and sorted(s_leaves) == sorted(c_leaves)
    for key in s_leaves:
        np.testing.assert_array_equal(np.asarray(s_leaves[key]),
                                      np.asarray(c_leaves[key]),
                                      err_msg=key)


# ---------------------------------------------------------------------------
# Serving: validation, sharing, offline parity
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_smoke("granite-8b"), dtype="float32")
    tgt = build_model(cfg)
    d_cfg = ModelConfig(name="d", family="dense", n_layers=1, d_model=64,
                        n_heads=2, n_kv_heads=2, d_ff=128,
                        vocab_size=cfg.vocab_size, dtype="float32")
    drf = build_model(d_cfg)
    return (cfg, tgt, drf, tgt.init(jax.random.PRNGKey(1)),
            drf.init(jax.random.PRNGKey(2)))


def _server(setup, *, kv_dtype="int8", cache="paged", prefix="off",
            slots=4, k=3):
    cfg, tgt, drf, t_params, d_params = setup
    return SpecServer(
        tgt, IndependentDrafter(drf, k=k, temperature=0.0),
        t_params, d_params,
        EngineConfig(k=k, rule="strict", mode="greedy", temperature=0.0),
        ServerConfig(slots=slots, max_len=96, max_prompt_len=48,
                     cache=cache, block_size=8, kv_dtype=kv_dtype,
                     prefix_cache=prefix))


def test_server_config_validation(setup):
    with pytest.raises(ValueError, match="requires.*paged"):
        _server(setup, cache="dense")
    with pytest.raises(ValueError, match="unknown kv_dtype"):
        _server(setup, kv_dtype="int4")
    if not FP8:
        with pytest.raises(ValueError, match="float8_e4m3fn"):
            _server(setup, kv_dtype="fp8")


def test_quantized_serving_matches_offline_generate(setup):
    """int8 server outputs == offline generate through the SAME quantized
    pool layout (token-identical: one quantization story end to end)."""
    cfg, tgt, drf, t_params, d_params = setup
    rng = np.random.default_rng(7)
    prompts = rng.integers(3, cfg.vocab_size, size=(5, 8)).astype(np.int32)
    srv = _server(setup)
    for i in range(5):
        srv.submit(Request(uid=i, prompt=prompts[i],
                           params=SamplingParams(max_tokens=10,
                                                 temperature=0.0)))
    got = {r.uid: np.asarray(r.tokens) for r in srv.run()}
    gen = make_generate_fn(
        tgt, IndependentDrafter(drf, k=3, temperature=0.0),
        EngineConfig(k=3, rule="strict", mode="greedy", temperature=0.0),
        paged=PagedCacheConfig(8, kv_dtype="int8"))
    out = gen(t_params, d_params, jnp.asarray(prompts),
              jnp.full((5,), 8, jnp.int32), jax.random.PRNGKey(0),
              max_new=10)
    offline = np.asarray(out["tokens"])[:, 8:18]
    for uid in got:
        np.testing.assert_array_equal(got[uid], offline[uid],
                                      err_msg=f"uid {uid}")
    # harvest returned every block: no leak through the quantized path
    assert srv.pool.available == srv.pool.n_blocks - 1


def test_prefix_sharing_and_cow_on_quantized_blocks(setup):
    """Prefix publish/acquire + COW on int8 blocks: sharing on == sharing
    off per request, shared rows are byte-identical in pool and scale
    pool, and the publisher's content survives follower divergence."""
    cfg = setup[0]
    rng = np.random.default_rng(9)
    system = rng.integers(3, cfg.vocab_size, 24).astype(np.int32)
    reqs = []
    for i in range(6):
        tail = rng.integers(3, cfg.vocab_size, 6).astype(np.int32)
        reqs.append(Request(uid=i, prompt=np.concatenate([system, tail]),
                            params=SamplingParams(max_tokens=10,
                                                  temperature=0.0)))

    def serve(srv, rs):
        for r in rs:
            srv.submit(dataclasses.replace(r))
        return {r.uid: np.asarray(r.tokens) for r in srv.run()}

    cold = serve(_server(setup, prefix="off"), reqs)
    srv = _server(setup, prefix="on")
    warm = serve(srv, reqs)
    for uid in cold:
        np.testing.assert_array_equal(warm[uid], cold[uid],
                                      err_msg=f"uid {uid}")
    s = srv.prefix.summary()
    assert s["blocks_shared"] >= 1
    # publisher content intact after every follower's COW + rollback: a
    # late request re-using the published quantized blocks still matches
    late = serve(srv, [dataclasses.replace(reqs[0], uid=99)])
    np.testing.assert_array_equal(late[99], cold[0])
    assert srv.prefix.summary()["hits"] > s["hits"]
