"""Unit tests for the MARS verification rule (paper Algorithm 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import verify as V


def make_logits(rows):
    """rows: list of dicts {token: logit}; vocab inferred."""
    v = max(max(r) for r in rows) + 1
    out = np.full((len(rows), v), -5.0, np.float32)
    for i, r in enumerate(rows):
        for tok, z in r.items():
            out[i, tok] = z
    return jnp.asarray(out)


def test_exact_match_all_accept():
    # drafts equal the target argmax everywhere -> all accepted + bonus
    logits = make_logits([{3: 5.0}, {4: 5.0}, {1: 5.0}])  # K=2 + bonus row
    draft = jnp.asarray([[3, 4]])
    res = V.verify_chain(draft, logits[None], rule="strict", mode="greedy")
    assert int(res.n_accept[0]) == 2
    assert int(res.n_commit[0]) == 3
    np.testing.assert_array_equal(np.asarray(res.out_tokens[0]), [3, 4, 1])


def test_first_mismatch_truncates():
    logits = make_logits([{3: 5.0}, {4: 5.0}, {1: 5.0}])
    draft = jnp.asarray([[9, 4]])          # first token wrong
    res = V.verify_chain(draft, logits[None], rule="strict", mode="greedy")
    assert int(res.n_accept[0]) == 0
    np.testing.assert_array_equal(np.asarray(res.out_tokens[0, :1]), [3])
    assert int(res.n_commit[0]) == 1


def test_mars_relaxes_low_margin_top2():
    # z1=5.0, z2=4.8 -> ratio 0.96 > 0.9: draft == top2 accepted via MARS
    logits = make_logits([{3: 5.0, 7: 4.8}, {4: 5.0}, {1: 5.0}])
    draft = jnp.asarray([[7, 4]])
    strict = V.verify_chain(draft, logits[None], rule="strict", mode="greedy")
    mars = V.verify_chain(draft, logits[None], rule="mars", mode="greedy",
                          theta=0.9)
    assert int(strict.n_accept[0]) == 0
    assert int(mars.n_accept[0]) == 2
    assert int(mars.n_relaxed[0]) == 1
    np.testing.assert_array_equal(np.asarray(mars.out_tokens[0]), [7, 4, 1])


def test_mars_respects_theta():
    # ratio = 4.0/5.0 = 0.8 < 0.9 -> still rejected (high margin)
    logits = make_logits([{3: 5.0, 7: 4.0}, {4: 5.0}, {1: 5.0}])
    draft = jnp.asarray([[7, 4]])
    mars = V.verify_chain(draft, logits[None], rule="mars", mode="greedy",
                          theta=0.9)
    assert int(mars.n_accept[0]) == 0
    # but a permissive theta accepts it
    mars_lo = V.verify_chain(draft, logits[None], rule="mars", mode="greedy",
                             theta=0.75)
    assert int(mars_lo.n_accept[0]) == 2


def test_mars_positivity_guard():
    # top-2 logits negative: ratio undefined regime -> no relaxation even
    # though z2/z1 = (-1)/(-0.9)... guard requires z1>0, z2>0
    logits = make_logits([{3: 0, 7: 0}, {4: 5.0}, {1: 5.0}])
    logits = logits.at[0, 3].set(-0.9).at[0, 7].set(-1.0)
    draft = jnp.asarray([[7, 4]])
    mars = V.verify_chain(draft, logits[None], rule="mars", mode="greedy")
    assert int(mars.n_relaxed[0]) == 0


def test_top2_ratio_bounds():
    logits = jnp.asarray(np.random.default_rng(0).standard_normal((32, 50)),
                         jnp.float32) * 3
    _, _, ratio, valid = V.top2_and_ratio(logits)
    r = np.asarray(ratio)[np.asarray(valid)]
    assert ((r > 0) & (r <= 1.0)).all()


def test_mars_kernel_path_matches_reference():
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.standard_normal((2, 8, 64)) * 2, jnp.float32)
    draft = jnp.asarray(rng.integers(0, 64, (2, 8)), jnp.int32)
    key = jax.random.PRNGKey(0)
    a = V.verify_chain(draft, jnp.pad(logits, ((0, 0), (0, 1), (0, 0))),
                       rule="mars", mode="greedy", use_kernel=False, key=key)
    b = V.verify_chain(draft, jnp.pad(logits, ((0, 0), (0, 1), (0, 0))),
                       rule="mars", mode="greedy", use_kernel=True, key=key)
    np.testing.assert_array_equal(np.asarray(a.out_tokens),
                                  np.asarray(b.out_tokens))
    np.testing.assert_array_equal(np.asarray(a.n_relaxed),
                                  np.asarray(b.n_relaxed))


def test_strict_sampling_preserves_target_distribution():
    """Monte-Carlo check of the Leviathan residual scheme: the first emitted
    token's marginal must equal the target distribution, regardless of the
    draft distribution."""
    key = jax.random.PRNGKey(0)
    v = 5
    t_logits = jnp.asarray([0.5, 1.5, -0.3, 0.9, 0.1], jnp.float32)
    q_probs = jnp.asarray([0.5, 0.1, 0.1, 0.2, 0.1], jnp.float32)
    p = np.asarray(jax.nn.softmax(t_logits))

    n = 6000
    keys = jax.random.split(key, n)

    def one(k):
        kd, kv = jax.random.split(k)
        d = jax.random.categorical(kd, jnp.log(q_probs))
        draft = d[None, None]                       # (1, 1)
        logits = jnp.stack([t_logits, t_logits])[None]  # (1, 2, V)
        res = V.verify_chain(
            draft, logits, rule="strict", mode="sample", temperature=1.0,
            key=kv, draft_token_probs=q_probs[d][None, None],
            draft_full_probs=q_probs[None, None, :])
        return res.out_tokens[0, 0]

    toks = np.asarray(jax.vmap(one)(keys))
    emp = np.bincount(toks, minlength=v) / n
    assert np.abs(emp - p).max() < 0.03, (emp, p)
