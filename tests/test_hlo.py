"""HLO collective-accounting parser tests (synthetic HLO text)."""
from repro.utils.hlo import (collective_bytes, collective_bytes_loop_aware,
                             duplicate_collectives)

HLO_FLAT = """
ENTRY %main (p0: f32[16]) -> f32[16] {
  %ag = f32[64,128]{1,0} all-gather(%x), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar = bf16[32,32]{1,0} all-reduce(%y), replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add
}
"""

HLO_LOOP = """
%cond.1 (arg: (s32[])) -> pred[] {
  %c = s32[] constant(12)
  ROOT %cmp = pred[] compare(%i, %c), direction=LT
}

%body.2 (arg: (s32[])) -> (s32[]) {
  %ar2 = f32[8,8]{1,0} all-reduce(%z), replica_groups={{0,1}}, to_apply=%add
}

ENTRY %main (p0: f32[16]) -> f32[16] {
  %w = (s32[]) while(%init), condition=%cond.1, body=%body.2
  %ag = f32[4,4]{1,0} all-gather(%x), replica_groups={{0,1,2,3}}, dimensions={0}
}
"""


def test_flat_bytes():
    b, c = collective_bytes(HLO_FLAT)
    # all-gather: 64*128*4 bytes * 3/4
    assert b["all-gather"] == int(64 * 128 * 4 * 3 / 4)
    # all-reduce: 2 * 32*32*2 * 7/8
    assert b["all-reduce"] == int(2 * 32 * 32 * 2 * 7 / 8)
    assert c == {"all-gather": 1, "all-reduce": 1}


def test_loop_aware_multiplies_body():
    b, c = collective_bytes_loop_aware(HLO_LOOP)
    one_ar = int(2 * 8 * 8 * 4 * 1 / 2)
    assert b["all-reduce"] == 12 * one_ar            # trip count 12
    assert c["all-reduce"] == 12
    assert c["all-gather"] == 1                      # entry not multiplied


def test_loop_aware_equals_flat_when_no_loops():
    b1, c1 = collective_bytes(HLO_FLAT)
    b2, c2 = collective_bytes_loop_aware(HLO_FLAT)
    assert b1 == b2 and c1 == c2


def test_duplicate_collectives_counts():
    txt = HLO_FLAT + HLO_FLAT.replace("%ag", "%ag2").replace("%ar", "%ar2")
    assert duplicate_collectives(txt) >= 1
