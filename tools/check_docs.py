#!/usr/bin/env python
"""Docs reference checker: docs can't rot silently.

Scans ``docs/*.md`` and ``README.md`` for

* repository file paths (``src/repro/core/session.py``, ``docs/`` …) —
  each must exist relative to the repo root;
* dotted ``repro.*`` / ``benchmarks.*`` symbols
  (``repro.core.session.DecodeSession.cycle`` …) — each must resolve: the
  longest importable module prefix is imported and the remainder walked
  with ``getattr``.

Exit code 0 when every reference resolves, 1 otherwise (CI docs job).

    PYTHONPATH=src python tools/check_docs.py
"""
from __future__ import annotations

import glob
import importlib
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# a path-ish token: contains '/', and either names a file with a known
# extension or is an explicit directory reference ending in '/' (the
# lookahead keeps prose like "top1/top2" or "dense/paged" out)
PATH_RE = re.compile(
    r"(?<![\w./-])((?:[\w.-]+/)+[\w.-]+\.(?:py|md|yml|yaml|txt)"
    r"|(?:[\w.-]+/)+(?![\w.-]))"
)
SYMBOL_RE = re.compile(r"\b((?:repro|benchmarks)(?:\.[A-Za-z_]\w*)+)\b")


def check_path(token: str) -> bool:
    p = os.path.join(ROOT, token)
    return os.path.isdir(p) if token.endswith("/") else os.path.isfile(p)


def check_symbol(dotted: str) -> bool:
    parts = dotted.split(".")
    for cut in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:cut]))
        except ImportError:
            continue
        try:
            for attr in parts[cut:]:
                obj = getattr(obj, attr)
        except AttributeError:
            return False
        return True
    return False


def main() -> int:
    sys.path.insert(0, os.path.join(ROOT, "src"))
    sys.path.insert(0, ROOT)               # benchmarks.* package
    files = sorted(glob.glob(os.path.join(ROOT, "docs", "*.md")))
    files.append(os.path.join(ROOT, "README.md"))

    failures = []
    n_paths = n_symbols = 0
    for fname in files:
        rel = os.path.relpath(fname, ROOT)
        with open(fname) as f:
            text = f.read()
        for m in PATH_RE.finditer(text):
            tok = m.group(1)
            if "://" in text[max(0, m.start() - 8):m.start() + 4]:
                continue                   # URL, not a repo path
            n_paths += 1
            if not check_path(tok):
                failures.append(f"{rel}: missing path {tok!r}")
        for m in SYMBOL_RE.finditer(text):
            n_symbols += 1
            if not check_symbol(m.group(1)):
                failures.append(f"{rel}: unresolvable symbol {m.group(1)!r}")

    print(f"checked {n_paths} path refs + {n_symbols} symbol refs "
          f"across {len(files)} docs")
    for f in failures:
        print(f"FAIL  {f}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
