#!/usr/bin/env python
"""Telemetry artifact checker: the exported observability files must parse.

Validates the three artifacts ``repro.obs.ServerTelemetry.write`` emits
(and the serving launchers / benchmark expose via ``--metrics-out`` /
``--trace-out`` / ``--events-out``):

* ``--metrics``: Prometheus text exposition 0.0.4 — every sample line must
  belong to a declared ``# TYPE``, histogram series must carry cumulative
  ``_bucket{le=...}`` rows ending in ``+Inf`` with ``_sum``/``_count``,
  and counter/gauge values must be finite numbers.
* ``--trace``: Chrome trace-event JSON (the format Perfetto loads) — a
  ``traceEvents`` list whose ``ph: "X"`` spans have numeric ``ts``/``dur``
  and whose required span names (``--require-spans``) all appear.
* ``--events``: per-request lifecycle JSONL — every line valid JSON with
  ``event``/``uid``/``t_s``, exactly one ``finish`` per uid, and finish
  events carrying ``ttft_s``/``latency_s``.

Exit code 0 when every provided artifact validates, 1 otherwise (CI
telemetry smoke leg).  Functions are importable for tests.

    python tools/check_trace.py --metrics m.prom --trace t.json \
        --events e.jsonl --require-spans admit,dispatch,harvest,retune
"""
from __future__ import annotations

import argparse
import json
import math
import re
import sys

SAMPLE_RE = re.compile(
    r"^(?P<name>[A-Za-z_:][A-Za-z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?\s+(?P<value>\S+)$")


def _parse_value(tok: str) -> float:
    if tok == "+Inf":
        return math.inf
    if tok == "-Inf":
        return -math.inf
    return float(tok)


def check_prometheus(text: str) -> list:
    """Return a list of violation strings (empty = valid)."""
    errs = []
    types = {}          # metric name -> declared type
    seen = {}           # metric name -> sample count
    hist_buckets = {}   # histogram name -> list of (le, cumulative count)
    hist_tail = {}      # histogram name -> {"sum": v, "count": v}
    for ln, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# HELP "):
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4 or parts[3] not in ("counter", "gauge",
                                                   "histogram"):
                errs.append(f"line {ln}: malformed TYPE line {line!r}")
            else:
                types[parts[2]] = parts[3]
            continue
        if line.startswith("#"):
            errs.append(f"line {ln}: unknown comment {line!r}")
            continue
        m = SAMPLE_RE.match(line)
        if m is None:
            errs.append(f"line {ln}: unparsable sample {line!r}")
            continue
        name = m.group("name")
        base = re.sub(r"_(bucket|sum|count)$", "", name)
        family = base if base in types else name
        if family not in types:
            errs.append(f"line {ln}: sample {name!r} has no TYPE declaration")
            continue
        try:
            val = _parse_value(m.group("value"))
        except ValueError:
            errs.append(f"line {ln}: non-numeric value {m.group('value')!r}")
            continue
        seen[family] = seen.get(family, 0) + 1
        if types[family] == "counter" and val < 0:
            errs.append(f"line {ln}: counter {name} is negative ({val})")
        if types[family] == "histogram":
            if name.endswith("_bucket"):
                labels = m.group("labels") or ""
                le = re.search(r'le="([^"]+)"', labels)
                if le is None:
                    errs.append(f"line {ln}: bucket without le label")
                else:
                    hist_buckets.setdefault(family, []).append(
                        (_parse_value(le.group(1)), val))
            elif name.endswith(("_sum", "_count")):
                hist_tail.setdefault(family, {})[name.rsplit("_", 1)[1]] = val
    for fam, typ in types.items():
        if typ != "histogram":
            if not seen.get(fam):
                errs.append(f"metric {fam}: TYPE declared but no samples")
            continue
        buckets = hist_buckets.get(fam, [])
        if not buckets or buckets[-1][0] != math.inf:
            errs.append(f"histogram {fam}: missing +Inf bucket")
        counts = [c for _, c in buckets]
        if counts != sorted(counts):
            errs.append(f"histogram {fam}: bucket counts not cumulative")
        tail = hist_tail.get(fam, {})
        if "sum" not in tail or "count" not in tail:
            errs.append(f"histogram {fam}: missing _sum/_count")
        elif buckets and tail["count"] != buckets[-1][1]:
            errs.append(f"histogram {fam}: _count {tail['count']} != +Inf "
                        f"bucket {buckets[-1][1]}")
    return errs


def check_chrome_trace(doc: dict, require_spans=()) -> list:
    """Validate the Chrome trace-event JSON ``ServerTelemetry`` writes."""
    errs = []
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    span_names = set()
    for i, ev in enumerate(events):
        if not isinstance(ev, dict) or "ph" not in ev:
            errs.append(f"event {i}: not an object with 'ph'")
            continue
        ph = ev["ph"]
        if ph == "X":
            for field in ("name", "ts", "dur", "pid", "tid"):
                if field not in ev:
                    errs.append(f"event {i}: span missing {field!r}")
            if not isinstance(ev.get("ts"), (int, float)) or \
                    not isinstance(ev.get("dur"), (int, float)):
                errs.append(f"event {i}: non-numeric ts/dur")
            elif ev["dur"] < 0:
                errs.append(f"event {i}: negative dur {ev['dur']}")
            else:
                span_names.add(ev.get("name"))
        elif ph == "C":
            if not isinstance(ev.get("args"), dict):
                errs.append(f"event {i}: counter without args")
        elif ph not in ("M", "i", "I"):
            errs.append(f"event {i}: unexpected phase {ph!r}")
    for name in require_spans:
        if name not in span_names:
            errs.append(f"required span {name!r} absent "
                        f"(saw {sorted(span_names)})")
    return errs


def check_events_jsonl(lines) -> list:
    """Validate the lifecycle JSONL: one finish per uid, honest fields."""
    errs = []
    finishes = {}
    for ln, line in enumerate(lines, 1):
        if not line.strip():
            continue
        try:
            ev = json.loads(line)
        except ValueError:
            errs.append(f"line {ln}: invalid JSON")
            continue
        for field in ("event", "uid", "t_s"):
            if field not in ev:
                errs.append(f"line {ln}: missing {field!r}")
        if ev.get("event") == "finish":
            uid = ev.get("uid")
            finishes[uid] = finishes.get(uid, 0) + 1
            for field in ("ttft_s", "latency_s", "n_tokens"):
                if field not in ev:
                    errs.append(f"line {ln}: finish missing {field!r}")
    if not finishes:
        errs.append("no finish events at all")
    for uid, n in sorted(finishes.items()):
        if n != 1:
            errs.append(f"uid {uid}: {n} finish events (want exactly 1)")
    return errs


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--metrics", default=None,
                    help="Prometheus text file to validate")
    ap.add_argument("--trace", default=None,
                    help="Chrome trace-event JSON file to validate")
    ap.add_argument("--events", default=None,
                    help="lifecycle JSONL file to validate")
    ap.add_argument("--require-spans", default="",
                    help="comma-separated span names the trace must contain "
                         "(e.g. admit,dispatch,harvest,retune)")
    args = ap.parse_args()
    if not (args.metrics or args.trace or args.events):
        ap.error("nothing to check: pass --metrics/--trace/--events")

    failures = []
    if args.metrics:
        with open(args.metrics) as f:
            errs = check_prometheus(f.read())
        print(f"{args.metrics}: {'OK' if not errs else 'FAIL'}")
        failures += [f"{args.metrics}: {e}" for e in errs]
    if args.trace:
        with open(args.trace) as f:
            doc = json.load(f)
        spans = [s for s in args.require_spans.split(",") if s]
        errs = check_chrome_trace(doc, require_spans=spans)
        n = len(doc.get("traceEvents", []))
        print(f"{args.trace}: {'OK' if not errs else 'FAIL'} ({n} events)")
        failures += [f"{args.trace}: {e}" for e in errs]
    if args.events:
        with open(args.events) as f:
            errs = check_events_jsonl(f)
        print(f"{args.events}: {'OK' if not errs else 'FAIL'}")
        failures += [f"{args.events}: {e}" for e in errs]
    for f in failures:
        print(f"FAIL  {f}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
