"""Paper Table 1: speedup and τ across drafting methods, strict vs MARS.

Methods: vanilla AR (1.00x), SpS (independent draft LM), PLD, Medusa-lite,
EAGLE-lite — each verified strictly AND with MARS (θ=0.9).  The paper's
headline claim is that MARS beats strict verification for EVERY drafter
(τ↑, speedup↑) at near-lossless quality; that is the trend validated here.
"""
from __future__ import annotations

import jax

from benchmarks import common as C
from repro.core import (EngineConfig, EagleDrafter, IndependentDrafter,
                        MedusaDrafter, PLDrafter)

K = 4
T = 1.0


def run(max_new=96, n_prompts=6):
    target, t_params, draft, d_params = C.get_pair()
    e_params = C.train_eagle_head(target, t_params)
    m_params = C.train_medusa_heads(target, t_params, n_heads=K)

    _, ar_time, ar_nll, ar_cnll = C.eval_ar(target, t_params,
                                            max_new=max_new,
                                            n_prompts=n_prompts,
                                            temperature=T)
    print(f"{'AR baseline':24s} tau= 1.00 speedup(meas)=1.00x "
          f"nll={ar_nll:.3f} corpus_nll={ar_cnll:.3f}  ({ar_time:.2f}s)")

    drafters = [
        ("SpS", IndependentDrafter(draft, k=K, temperature=T), d_params),
        ("PLD", PLDrafter(k=K, ngram=2), None),
        ("Medusa", MedusaDrafter(target, k=K, temperature=T), m_params),
        ("EAGLE", EagleDrafter(target, k=K, temperature=T), e_params),
    ]
    rows = []
    for name, drafter, dp in drafters:
        for rule in ("strict", "mars"):
            ecfg = EngineConfig(k=K, rule=rule, mode="sample", temperature=T, guard="margin")
            r = C.eval_engine(f"{name}+{rule}", target, t_params, drafter,
                              dp, ecfg, max_new=max_new, n_prompts=n_prompts,
                              ar_time=ar_time)
            print(r.row())
            rows.append(r)
    return rows


if __name__ == "__main__":
    run()
