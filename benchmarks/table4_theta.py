"""Paper Table 4 / Figure 3: logit-ratio threshold θ sweep.

Expected trends (paper §4.3): speedup decreases monotonically in θ; quality
degrades for small θ and is preserved near θ=0.9 — the balanced default.

Run in greedy mode: at T=1 with an exact-residual, well-calibrated chain
drafter, Leviathan sampling already accepts near-ties probabilistically, so
the relaxation margin is only visible under deterministic verification
(see EXPERIMENTS.md §Paper-validation for the discussion).
"""
from __future__ import annotations

from benchmarks import common as C
from repro.core import EngineConfig, IndependentDrafter

K = 4
T = 0.0
THETAS = [0.80, 0.84, 0.88, 0.90, 0.92, 0.96, 0.99]


def run(max_new=96, n_prompts=6, kv_dtype="bf16"):
    """``kv_dtype`` != "bf16" sweeps θ with the engine's KV held in a
    quantized paged pool — the per-θ speedup/quality trends should match
    the bf16 sweep within noise (wide-margin accepts are robust to mild
    cache quantization error)."""
    target, t_params, draft, d_params = C.get_pair()
    paged = None
    if kv_dtype != "bf16":
        from repro.models.paging import PagedCacheConfig
        paged = PagedCacheConfig(block_size=16, kv_dtype=kv_dtype)
    _, ar_time, ar_nll, ar_cnll = C.eval_ar(target, t_params,
                                            max_new=max_new,
                                            n_prompts=n_prompts,
                                            temperature=T)
    print(f"AR: nll={ar_nll:.3f} corpus_nll={ar_cnll:.3f}")
    drafter = IndependentDrafter(draft, k=K, temperature=T)
    ecfg = EngineConfig(k=K, rule="mars", mode="greedy", temperature=T, guard="margin")
    rows = []
    for th in THETAS:
        r = C.eval_engine(f"theta={th:.2f}", target, t_params, drafter,
                          d_params, ecfg, max_new=max_new,
                          n_prompts=n_prompts, theta=th, ar_time=ar_time,
                          paged=paged)
        print(r.row())
        rows.append((th, r))
    # strict reference
    strict = C.eval_engine("strict", target, t_params, drafter, d_params,
                           EngineConfig(k=K, rule="strict", mode="greedy",
                                        temperature=T, guard="margin"),
                           max_new=max_new, n_prompts=n_prompts,
                           ar_time=ar_time, paged=paged)
    print(strict.row())
    return rows, strict


if __name__ == "__main__":
    run()
