"""Paper Table 4 / Figure 3: logit-ratio threshold θ sweep.

Expected trends (paper §4.3): speedup decreases monotonically in θ; quality
degrades for small θ and is preserved near θ=0.9 — the balanced default.

Run in greedy mode: at T=1 with an exact-residual, well-calibrated chain
drafter, Leviathan sampling already accepts near-ties probabilistically, so
the relaxation margin is only visible under deterministic verification
(see EXPERIMENTS.md §Paper-validation for the discussion).

The per-θ margin column comes from the engine's on-device stats (the
``margin_ema`` field ``DecodeSession.cycle`` maintains — the first-rejection
top-2 ratio EMA the serving controller reads), not from a host-side logit
recompute.  ``theta_mode="adaptive"`` overlays, for each swept θ, the
operating point the serving ``ThetaController`` would converge to given
that run's observed margin EMA and relaxed share (zero queue pressure).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common as C
from repro.core import EngineConfig, IndependentDrafter

K = 4
T = 0.0
THETAS = [0.80, 0.84, 0.88, 0.90, 0.92, 0.96, 0.99]


def run(max_new=96, n_prompts=6, kv_dtype="bf16", theta_mode="fixed"):
    """``kv_dtype`` != "bf16" sweeps θ with the engine's KV held in a
    quantized paged pool — the per-θ speedup/quality trends should match
    the bf16 sweep within noise (wide-margin accepts are robust to mild
    cache quantization error)."""
    target, t_params, draft, d_params = C.get_pair()
    paged = None
    if kv_dtype != "bf16":
        from repro.models.paging import PagedCacheConfig
        paged = PagedCacheConfig(block_size=16, kv_dtype=kv_dtype)
    _, ar_time, ar_nll, ar_cnll = C.eval_ar(target, t_params,
                                            max_new=max_new,
                                            n_prompts=n_prompts,
                                            temperature=T)
    print(f"AR: nll={ar_nll:.3f} corpus_nll={ar_cnll:.3f}")
    drafter = IndependentDrafter(draft, k=K, temperature=T)
    ecfg = EngineConfig(k=K, rule="mars", mode="greedy", temperature=T, guard="margin")
    rows = []
    for th in THETAS:
        r = C.eval_engine(f"theta={th:.2f}", target, t_params, drafter,
                          d_params, ecfg, max_new=max_new,
                          n_prompts=n_prompts, theta=th, ar_time=ar_time,
                          paged=paged)
        print(r.row())
        rows.append((th, r))
    # strict reference
    strict = C.eval_engine("strict", target, t_params, drafter, d_params,
                           EngineConfig(k=K, rule="strict", mode="greedy",
                                        temperature=T, guard="margin"),
                           max_new=max_new, n_prompts=n_prompts,
                           ar_time=ar_time, paged=paged)
    print(strict.row())
    if theta_mode == "adaptive":
        overlay_controller(rows)
    return rows, strict


def overlay_controller(rows):
    """For each swept θ, iterate the serving controller's update law to its
    fixed point under that run's on-device margin EMA and relaxed share —
    where an adaptive server on this workload would operate (no pressure)."""
    from repro.serving import ControllerConfig, ThetaController

    ctl = ThetaController(ControllerConfig())
    print("controller operating points (zero queue pressure):")
    for th, r in rows:
        ema = r.margin_ema if r.margin_ema == r.margin_ema else 0.0
        theta = np.asarray([th])
        for _ in range(64):
            theta = ctl.update(theta, np.asarray([r.relax_frac]),
                               np.asarray([ema]), 0.0)
        print(f"  theta={th:.2f}: margin_ema={ema:.3f} "
              f"relax={r.relax_frac:.2f} -> operating point "
              f"{float(theta[0]):.3f}")


if __name__ == "__main__":
    run()
