"""Paper Table 3: segment-level fidelity (ROUGE-L analogue).

The paper reports ROUGE-L of MARS vs vanilla decoding on CNN/DailyMail and
finds differences within stochastic-decoding variance.  Here we measure the
LCS-F1 between spec-decoded continuations and vanilla AR continuations at the
same temperature/seed: strict sampling should sit near the self-agreement
noise floor, and MARS should stay within a small delta of it.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks import common as C
from repro.core import EngineConfig, IndependentDrafter, make_generate_fn

K = 4
T = 1.0


def lcs_f1(a: np.ndarray, b: np.ndarray) -> float:
    n, m = len(a), len(b)
    if n == 0 or m == 0:
        return 0.0
    dp = np.zeros((n + 1, m + 1), np.int32)
    for i in range(n):
        for j in range(m):
            dp[i + 1, j + 1] = (dp[i, j] + 1 if a[i] == b[j]
                                else max(dp[i, j + 1], dp[i + 1, j]))
    l = dp[n, m]
    p, r = l / m, l / n
    return 2 * p * r / max(p + r, 1e-9)


def run(max_new=64, n_prompts=4, kv_dtype="bf16"):
    """``kv_dtype`` != "bf16" routes the spec-decoded side through a
    quantized paged pool (the AR reference stays full precision), so the
    fidelity deltas measure quantization noise on top of verification."""
    target, t_params, draft, d_params = C.get_pair()
    p, plen = C.prompts(n_prompts)
    s = int(plen[0])
    paged = None
    if kv_dtype != "bf16":
        from repro.models.paging import PagedCacheConfig
        paged = PagedCacheConfig(block_size=16, kv_dtype=kv_dtype)

    out_ar, _, _, _ = C.eval_ar(target, t_params, max_new=max_new,
                                n_prompts=n_prompts, temperature=T, seed=0)
    out_ar2, _, _, _ = C.eval_ar(target, t_params, max_new=max_new,
                                 n_prompts=n_prompts, temperature=T, seed=1)
    ar = np.asarray(out_ar["tokens"])[:, s:s + max_new]
    ar2 = np.asarray(out_ar2["tokens"])[:, s:s + max_new]
    noise_floor = np.mean([lcs_f1(ar[i], ar2[i]) for i in range(n_prompts)])
    print(f"AR self-agreement (different seeds): LCS-F1={noise_floor:.3f}")

    drafter = IndependentDrafter(draft, k=K, temperature=T)
    scores = {}
    for rule in ("strict", "mars"):
        gen = make_generate_fn(target, drafter,
                               EngineConfig(k=K, rule=rule, mode="sample",
                                            temperature=T, guard="margin"),
                               paged=paged)
        out = gen(t_params, d_params, p, plen, jax.random.PRNGKey(0),
                  max_new=max_new)
        sd = np.asarray(out["tokens"])[:, s:s + max_new]
        f1 = np.mean([lcs_f1(ar[i], sd[i]) for i in range(n_prompts)])
        scores[rule] = f1
        print(f"{rule:6s} vs AR: LCS-F1={f1:.3f}")
    print("claim check: |mars - strict| should be within the noise floor "
          f"spread -> delta={abs(scores['mars'] - scores['strict']):.3f}")
    return noise_floor, scores


if __name__ == "__main__":
    run()
