"""Tree-draft vs chain-draft verification (beyond-paper measurement).

The paper notes MARS composes with tree verification (§2.3); this benchmark
measures what the tree adds on the trained bench pair: a caterpillar tree
with `branch` candidates per depth lets a rejected chain step be *rescued*
by an accepted sibling — under MARS, also by a relaxed low-margin sibling.

Both topologies run through the unified ``DecodeSession`` engine core —
the only difference between rows is ``EngineConfig(topology=...)``.  All
rows (tree included) now use the ``guard="margin"`` small-model extension
the chain rows always used, so chain-vs-tree is apples-to-apples; tree MARS
numbers therefore shift slightly vs the pre-unification benchmark, whose
tree path hard-coded the paper's positive-logit guard.

    PYTHONPATH=src python -m benchmarks.tree_vs_chain
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks import common as C
from repro.core import EagleDrafter, EngineConfig, make_generate_fn, metrics

K = 3


def run(max_new=64, n_prompts=4):
    target, t_params, _, _ = C.get_pair()
    e_params = C.train_eagle_head(target, t_params)
    drafter = EagleDrafter(target, k=K, temperature=0.0)
    p, plen = C.prompts(n_prompts)

    configs = [("chain", 1)] + [("tree", b) for b in (2, 3)]
    rows = []
    for topology, branch in configs:
        for rule in ("strict", "mars"):
            name = (f"chain/{rule}" if topology == "chain"
                    else f"tree-b{branch}/{rule}")
            gen = make_generate_fn(
                target, drafter,
                EngineConfig(k=K, rule=rule, mode="greedy", temperature=0.0,
                             guard="margin", topology=topology,
                             branch=branch))
            out = gen(t_params, e_params, p, plen, jax.random.PRNGKey(0),
                      max_new=max_new)
            rows.append((name, metrics.tau(out["stats"]),
                         metrics.relax_fraction(out["stats"])))

    for name, t, rf in rows:
        print(f"  {name:16s} tau={t:5.2f}  relax_frac={rf:.2f}")
    return rows


if __name__ == "__main__":
    run()
