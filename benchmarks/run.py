"""Benchmark entrypoint: one function per paper table/figure.

``python -m benchmarks.run``           — fast subset (τ + θ + margins)
``python -m benchmarks.run --full``    — every table (slower: trains heads,
                                          sweeps T×K)
``python -m benchmarks.run --roofline``— only the dry-run roofline report

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = measured CPU
wall-time per generate call on the tiny bench pair; derived = the headline
derived metric for that table).
"""
from __future__ import annotations

import argparse
import time

CSV_ROWS = []


def _csv(name: str, us: float, derived: str):
    CSV_ROWS.append(f"{name},{us:.1f},{derived}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--roofline", action="store_true")
    args = ap.parse_args()

    if args.roofline:
        from benchmarks import roofline
        roofline.main()
        return

    from benchmarks import table1_methods, table4_theta, fig1_margins

    print("== Table 1: methods × {strict, MARS} ==")
    rows = table1_methods.run()
    for r in rows:
        _csv(f"table1/{r.name}", r.wall_s * 1e6,
             f"tau={r.tau:.2f};speedup_v5e={r.speedup_v5e:.2f}")

    print("\n== Table 4 / Fig 3: theta sweep ==")
    sweep, strict = table4_theta.run()
    for th, r in sweep:
        _csv(f"table4/theta_{th:.2f}", r.wall_s * 1e6,
             f"tau={r.tau:.2f};nll={r.nll:.3f}")
    _csv("table4/strict", strict.wall_s * 1e6, f"tau={strict.tau:.2f}")

    print("\n== Fig 1/4: margin statistics ==")
    t0 = time.time()
    stats = fig1_margins.run()
    _csv("fig1/margins", (time.time() - t0) * 1e6,
         f"pos_frac={stats['top1_logit_positive_frac']:.3f};"
         f"zone={stats['relax_zone_frac(r>0.9)']:.3f}")

    if args.full:
        from benchmarks import table2_temp_k, table3_fidelity, table5_spd
        print("\n== Table 2: temperature × K ==")
        for (tk, r) in table2_temp_k.run():
            _csv(f"table2/T{tk[0]}_K{tk[1]}", r.wall_s * 1e6,
                 f"tau={r.tau:.2f}")
        print("\n== Table 5: SPD + MARS ==")
        for r in table5_spd.run():
            _csv(f"table5/{r.name}", r.wall_s * 1e6,
                 f"tau={r.tau:.2f};nll={r.nll:.3f}")
        print("\n== Table 3: segment fidelity (LCS-F1) ==")
        import time as _t
        t0 = _t.time()
        floor, sc = table3_fidelity.run()
        _csv("table3/fidelity", (_t.time() - t0) * 1e6,
             f"floor={floor:.3f};strict={sc['strict']:.3f};mars={sc['mars']:.3f}")

    print("\nname,us_per_call,derived")
    for row in CSV_ROWS:
        print(row)


if __name__ == "__main__":
    main()
