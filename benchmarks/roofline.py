"""Roofline report: aggregates experiments/dryrun/*.json into the §Roofline
table (all three terms, dominant bottleneck, MODEL_FLOPS ratio) plus the
§Perf variant comparison."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")
SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
               "long_500k": 3}


def load(dry_dir: str = DRYRUN_DIR) -> List[Dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(dry_dir, "*.json"))):
        with open(f) as fh:
            try:
                rows.append(json.load(fh))
            except Exception:
                pass
    return rows


def _is_baseline(r: Dict) -> bool:
    return not r.get("variant") and r.get("verify_tokens", 1) == 1


def table(rows: List[Dict], *, mesh: str = "16x16") -> str:
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | "
           "bottleneck | MODEL/HLO flops | arg+tmp GB/chip |")
    sep = "|" + "---|" * 8
    lines = [hdr, sep]
    sel = [r for r in rows if r.get("mesh") == mesh and _is_baseline(r)]
    sel.sort(key=lambda r: (r["arch"], SHAPE_ORDER.get(r["shape"], 9)))
    for r in sel:
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | "
                         f"FAILED | - | - |")
            continue
        rf = r["roofline"]
        ratio = r.get("flops_ratio")
        mem = r.get("memory", {})
        per_chip_gb = ((mem.get("argument_bytes") or 0)
                       + (mem.get("temp_bytes") or 0)) / r["chips"] / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3e} | "
            f"{rf['memory_s']:.3e} | {rf['collective_s']:.3e} | "
            f"{r['bottleneck'].replace('_s', '')} | "
            f"{ratio:.2f} | {per_chip_gb:.1f} |")
    return "\n".join(lines)


def perf_table(rows: List[Dict]) -> str:
    lines = ["| arch | shape | variant | t | compute_s | memory_s | "
             "collective_s | bottleneck |", "|" + "---|" * 8]
    sel = [r for r in rows if not _is_baseline(r) or r.get("variant")]
    sel += [r for r in rows if _is_baseline(r) and any(
        (v.get("arch"), v.get("shape")) == (r["arch"], r["shape"])
        for v in rows if v.get("variant"))]
    seen = set()
    for r in sorted(sel, key=lambda r: (r["arch"], r["shape"],
                                        str(r.get("variant")))):
        key = (r["arch"], r["shape"], r.get("variant"),
               r.get("verify_tokens", 1), r.get("mesh"))
        if key in seen or r.get("mesh") != "16x16":
            continue
        seen.add(key)
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | "
                         f"{r.get('variant') or 'baseline'} | "
                         f"{r.get('verify_tokens', 1)} | - | - | - | FAILED |")
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{r.get('variant') or 'baseline'} | {r.get('verify_tokens', 1)} "
            f"| {rf['compute_s']:.3e} | {rf['memory_s']:.3e} | "
            f"{rf['collective_s']:.3e} | {r['bottleneck'].replace('_s', '')} |")
    return "\n".join(lines)


def main():
    rows = load()
    base = [r for r in rows if _is_baseline(r)]
    ok = [r for r in base if r.get("ok")]
    fail = [r for r in base if not r.get("ok")]
    print(f"# baseline dry-runs: {len(ok)} ok / {len(fail)} failed "
          f"(40 pairs x 2 meshes expected)")
    print("\n## Single-pod (16x16) roofline\n")
    print(table(rows, mesh="16x16"))
    print("\n## Multi-pod (2x16x16) roofline\n")
    print(table(rows, mesh="2x16x16"))
    print("\n## §Perf variants (16x16)\n")
    print(perf_table(rows))
    if fail:
        print("\n## Failures\n")
        for r in fail:
            print(f"- {r['arch']} × {r['shape']} × {r['mesh']}: "
                  f"{r.get('error', '?')[:160]}")


if __name__ == "__main__":
    main()
