"""Paper Table 5: framework-decoupled verification — MARS plugged into plain
standard speculative decoding (SPD) with an independent drafter, no
target-coupled heads.  Claim: τ and speedup improve while quality holds.

Also validates the greedy (T=0) appendix-B setting: strict SPD at T=0 is
exactly lossless, and MARS trades a bounded NLL delta for τ.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common as C
from repro.core import EngineConfig, IndependentDrafter, make_ar_generate_fn
import jax

K = 4


def run(max_new=96, n_prompts=6):
    target, t_params, draft, d_params = C.get_pair()
    rows = []
    for temp, mode in ((1.0, "sample"), (0.0, "greedy")):
        out_ar, ar_time, ar_nll, ar_cnll = C.eval_ar(
            target, t_params, max_new=max_new, n_prompts=n_prompts,
            temperature=temp)
        print(f"AR(T={temp}): nll={ar_nll:.3f} corpus={ar_cnll:.3f}")
        drafter = IndependentDrafter(draft, k=K, temperature=temp)
        for rule in ("strict", "mars"):
            ecfg = EngineConfig(k=K, rule=rule, mode=mode, temperature=temp, guard="margin")
            r = C.eval_engine(f"SPD+{rule}(T={temp})", target, t_params,
                              drafter, d_params, ecfg, max_new=max_new,
                              n_prompts=n_prompts, ar_time=ar_time)
            if mode == "greedy":
                # greedy match vs the AR output
                p, plen = C.prompts(n_prompts)
                from repro.core import make_generate_fn
                g = make_generate_fn(target, drafter, ecfg)
                out = g(t_params, d_params, p, plen,
                        jax.random.PRNGKey(0), max_new=max_new)
                a = np.asarray(out_ar["tokens"])
                b = np.asarray(out["tokens"])
                s = int(plen[0])
                match = (a[:, s:s + max_new] == b[:, s:s + max_new]).mean()
                r.greedy_match = float(match)
            print(r.row() + (f" greedy_match={r.greedy_match:.3f}"
                             if mode == "greedy" else ""))
            rows.append(r)
    return rows


if __name__ == "__main__":
    run()
