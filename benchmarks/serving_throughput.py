"""Serving throughput: device-resident scheduler vs the legacy host-synced one.

Measures end-to-end tokens/s of the continuous-batching ``SpecServer``
(batched admission, donated carry, ``steps_per_sync`` fused cycles per
dispatch, harvest = one gathered ``device_get`` of finished rows) against a
faithful reimplementation of the pre-rewrite scheduler (one broadcast-to-B
prefill per request, one cycle per tick, host-computed budgets pushed back
into the carry with ``_replace``, per-slot harvest reads) — both running the
same ``DecodeSession`` engine core, so the difference is pure scheduling.

Also reports host-sync counts: the device-resident tick loop performs zero
device→host transfers per fused tick group; the legacy loop performs
several per cycle.

``--cache {dense,paged}`` selects the KV layout of the device-resident
server, and a long-context admission section compares the two layouts at
EQUAL device KV memory: the dense server reserves a worst-case ``max_len``
ring per slot, the paged server spends the same bytes on a shared block
pool — and admits several times more concurrent requests whose *actual*
usage is short, with outputs bit-identical to offline
``DecodeSession.generate`` (greedy).  Reported as
``serving/longctx_admission_*`` CSV rows.

    python -m benchmarks.serving_throughput            # trained tiny pair
    python -m benchmarks.serving_throughput --quick    # random weights (CI)
    python -m benchmarks.serving_throughput --quick --cache paged

Emits the same ``name,us_per_call,derived`` CSV rows as ``benchmarks/run.py``.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EngineConfig, IndependentDrafter, make_generate_fn
from repro.models import build_model
from repro.serving import Request, SamplingParams, ServerConfig, SpecServer


# ---------------------------------------------------------------------------
# Legacy scheduler (pre device-resident rewrite), kept here as the baseline
# ---------------------------------------------------------------------------

class LegacyServer:
    """The old host-synced slot scheduler, verbatim in behaviour: admission
    is one broadcast-to-B prefill per request, every tick runs ONE cycle and
    then round-trips ``lengths``/``finished`` through the host to enforce
    ``max_tokens`` (pushed back with ``_replace``), and harvest reads the
    carry per slot.  It also reproduces the old overshoot bug: responses
    exceed ``max_tokens`` by up to K tokens."""

    def __init__(self, target, drafter, t_params, d_params, engine_cfg,
                 cfg: ServerConfig):
        from repro.core.session import DecodeSession
        self.session = DecodeSession(target, drafter, engine_cfg)
        self.t_params, self.d_params = t_params, d_params
        self.cfg = cfg
        b = cfg.slots
        self.state = self.session.init_state(t_params, d_params, b,
                                             cfg.max_len)
        self.budget = np.zeros((b,), np.int64)
        self.queue = deque()
        self.slot_req = [None] * b
        self.slot_base_len = np.zeros((b,), np.int64)
        self._responses = []
        self.host_syncs = 0
        self.step_calls = 0

        self._cycle = jax.jit(lambda tp, dp, st: self.session.cycle(tp, dp, st))
        self._prefill = jax.jit(self._prefill_impl)

    def _prefill_impl(self, t_params, d_params, state, prompt, plen, slot):
        b = self.cfg.slots
        smask = jnp.arange(b) == slot
        prompt_b = jnp.broadcast_to(prompt[None], (b, prompt.shape[0]))
        plen_b = jnp.full((b,), plen, jnp.int32)
        return self.session.prefill(t_params, d_params, state, prompt_b,
                                    plen_b, slot_mask=smask)

    def submit(self, req):
        self.queue.append(req)

    def _host(self, x):
        self.host_syncs += 1
        return np.asarray(x)

    def _admit(self):
        finished = self._host(self.state.finished)
        for slot in range(self.cfg.slots):
            if not finished[slot]:
                continue
            if self.slot_req[slot] is not None:
                self._harvest(slot)
            if self.queue:
                req = self.queue.popleft()
                s = self.cfg.max_prompt_len
                prompt = np.zeros((s,), np.int32)
                plen = min(len(req.prompt), s)
                prompt[:plen] = req.prompt[:plen]
                self.state = self._prefill(
                    self.t_params, self.d_params, self.state,
                    jnp.asarray(prompt), jnp.int32(plen), jnp.int32(slot))
                self.slot_req[slot] = req
                self.slot_base_len[slot] = plen
                self.budget[slot] = req.params.max_tokens

    def _harvest(self, slot):
        req = self.slot_req[slot]
        toks = self._host(self.state.buf)[
            slot, :int(self._host(self.state.lengths)[slot])]
        cyc = int(self._host(self.state.stats["cycles"])[slot])
        com = int(self._host(self.state.stats["commits"])[slot])
        self._responses.append(Response_legacy(
            req.uid, toks[int(self.slot_base_len[slot]):], cyc, com))
        self.slot_req[slot] = None

    def step(self):
        self._admit()
        if all(r is None for r in self.slot_req):
            return
        self.step_calls += 1
        self.state = self._cycle(self.t_params, self.d_params, self.state)
        lengths = self._host(self.state.lengths)
        fin = self._host(self.state.finished).copy()
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            if lengths[slot] - self.slot_base_len[slot] >= self.budget[slot]:
                fin[slot] = True
        self.state = self.state._replace(finished=jnp.asarray(fin))

    def run(self, *, max_ticks=10_000):
        for _ in range(max_ticks):
            if not self.queue and all(r is None for r in self.slot_req):
                break
            self.step()
            finished = self._host(self.state.finished)
            for slot, req in enumerate(self.slot_req):
                if req is not None and finished[slot]:
                    self._harvest(slot)
        out, self._responses = self._responses, []
        return out


@dataclasses.dataclass
class Response_legacy:
    uid: int
    tokens: np.ndarray
    n_cycles: int
    n_committed: int


# ---------------------------------------------------------------------------
# Harness
# ---------------------------------------------------------------------------

def _requests(n, max_tokens, prompt_len, corpus, seed=0):
    prompts = corpus.sample_batch(n, prompt_len, seed=seed)
    return [Request(uid=i, prompt=np.asarray(prompts[i], np.int32),
                    params=SamplingParams(max_tokens=max_tokens,
                                          temperature=1.0))
            for i in range(n)]


def _serve_once(server, reqs, max_tokens):
    """One timed pass over the request list.  Useful tokens =
    min(len(resp), max_tokens) so the legacy overshoot bug doesn't inflate
    its own throughput."""
    server.host_syncs = 0
    server.step_calls = 0
    for r in reqs:
        server.submit(dataclasses.replace(r))
    t0 = time.time()
    resps = server.run()
    wall = time.time() - t0
    toks = sum(min(len(r.tokens), max_tokens) for r in resps)
    assert len(resps) == len(reqs)
    return {"tok_s": toks / wall, "wall_s": wall, "tokens": toks,
            "host_syncs": server.host_syncs, "ticks": server.step_calls,
            "syncs_per_tick": server.host_syncs / max(server.step_calls, 1)}


def _measure(servers, reqs, max_tokens, repeats=3):
    """Warm every server (compile pass), then interleave timed passes and
    keep each server's best — interleaving cancels machine-load drift that
    would otherwise bias whichever server ran in the quiet window."""
    for s in servers.values():
        _serve_once(s, reqs, max_tokens)
    best = {}
    for _ in range(repeats):
        for name, s in servers.items():
            res = _serve_once(s, reqs, max_tokens)
            if name not in best or res["wall_s"] < best[name]["wall_s"]:
                best[name] = res
    return best


# ---------------------------------------------------------------------------
# Long-context admission capacity at equal device KV memory
# ---------------------------------------------------------------------------

def _run_tracking_concurrency(server, reqs):
    """Drive the scheduler loop by hand, recording peak in-flight slots."""
    for r in reqs:
        server.submit(dataclasses.replace(r))
    peak = 0
    for _ in range(10_000):
        if not server.queue and all(x is None for x in server.slot_req):
            break
        server._admit()
        peak = max(peak, sum(x is not None for x in server.slot_req))
        server.step()
        server.sync()
    resps, server._responses = server._responses, []
    return resps, peak


def longctx_admission(target, t_params, draft, d_params, *, k=3):
    """Both layouts get the same device KV budget and must be able to hold a
    ``max_len``-token request per slot; the workload's ACTUAL usage is short
    (prompt + a small budget).  Dense admits one request per reserved ring;
    paged admits until pool headroom runs out.  Returns the CSV rows and
    asserts paged responses equal offline greedy generation."""
    from repro.models.layers import TRASH_SLOTS

    max_len, prompt_len, max_tokens, bs = 192, 8, 8, 16
    dense_slots = 2
    # equal K/V bytes: the dense rings' token capacity, re-spent on a pool
    kv_tokens = dense_slots * (max_len + TRASH_SLOTS)
    pool_blocks = kv_tokens // bs
    ecfg = EngineConfig(k=k, rule="strict", mode="greedy", temperature=0.0)

    def mk(cache, slots, pool=0):
        return SpecServer(
            target, IndependentDrafter(draft, k=k, temperature=0.0),
            t_params, d_params, ecfg,
            ServerConfig(slots=slots, max_len=max_len,
                         max_prompt_len=prompt_len, cache=cache,
                         block_size=bs, pool_blocks=pool))

    from repro.models.paging import PagedCacheConfig
    per_req = PagedCacheConfig(bs, pool_blocks).request_blocks(
        prompt_len, max_tokens, k + 2, max_len)   # chain buffer_margin = k+2
    paged_slots = (pool_blocks - 1) // per_req

    from benchmarks import common as C
    prompts = C.corpus().sample_batch(paged_slots, prompt_len, seed=7)
    reqs = [Request(uid=i, prompt=np.asarray(prompts[i], np.int32),
                    params=SamplingParams(max_tokens=max_tokens,
                                          temperature=0.0))
            for i in range(paged_slots)]

    d_resps, d_peak = _run_tracking_concurrency(mk("dense", dense_slots), reqs)
    p_resps, p_peak = _run_tracking_concurrency(
        mk("paged", paged_slots, pool_blocks), reqs)
    assert len(d_resps) == len(p_resps) == paged_slots

    # paged responses must equal offline greedy generation, per request
    gen = make_generate_fn(target, IndependentDrafter(draft, k=k,
                                                      temperature=0.0), ecfg)
    out = gen(t_params, d_params, jnp.asarray(prompts),
              jnp.full((paged_slots,), prompt_len, jnp.int32),
              jax.random.PRNGKey(0), max_new=max_tokens)
    offline = np.asarray(out["tokens"])[:, prompt_len:prompt_len + max_tokens]
    for r in p_resps:
        np.testing.assert_array_equal(np.asarray(r.tokens),
                                      offline[r.uid],
                                      err_msg=f"paged req {r.uid} != offline")

    ratio = p_peak / max(d_peak, 1)
    print(f"\nlong-context admission at equal KV memory "
          f"({kv_tokens} tokens/layer, max_len={max_len}):")
    print(f"  dense : {d_peak:3d} concurrent ({dense_slots} rings reserved)")
    print(f"  paged : {p_peak:3d} concurrent ({pool_blocks}-block pool, "
          f"{per_req} blocks/request)")
    print(f"  ratio : {ratio:.1f}x  (paged outputs == offline greedy)")
    return [
        ("serving/longctx_admission_dense", 0.0,
         f"concurrent={d_peak};kv_tokens={kv_tokens}"),
        ("serving/longctx_admission_paged", 0.0,
         f"concurrent={p_peak};kv_tokens={kv_tokens};block={bs}"),
        ("serving/longctx_admission_ratio", 0.0,
         f"x={ratio:.1f};outputs=offline_match"),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="random weights, small workload (CI smoke)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-tokens", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=128,
                    help="prompt-heavy serving (prompts >> outputs, the "
                         "common production regime): admission dominates")
    ap.add_argument("--steps-per-sync", type=int, default=4)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--cache", default="dense", choices=["dense", "paged"],
                    help="KV layout of the device-resident server (the "
                         "legacy baseline always runs dense)")
    args = ap.parse_args()

    from benchmarks import common as C
    if args.quick:
        target = build_model(C.TARGET_CFG)
        draft = build_model(C.DRAFT_CFG)
        t_params = target.init(jax.random.PRNGKey(0))
        d_params = draft.init(jax.random.PRNGKey(1))
        n_req, max_tokens = min(args.requests, 8), min(args.max_tokens, 8)
    else:
        target, t_params, draft, d_params = C.get_pair()
        n_req, max_tokens = args.requests, args.max_tokens

    ecfg = EngineConfig(k=args.k, rule="mars", mode="sample",
                        temperature=1.0, guard="margin")
    scfg = ServerConfig(slots=args.slots,
                        max_len=args.prompt_len + max_tokens + args.k + 4,
                        max_prompt_len=args.prompt_len,
                        steps_per_sync=args.steps_per_sync,
                        cache=args.cache)
    reqs = _requests(n_req, max_tokens, args.prompt_len, C.corpus())

    def new_server():
        return SpecServer(target, IndependentDrafter(draft, k=args.k),
                          t_params, d_params, ecfg, scfg)

    def old_server():
        return LegacyServer(target, IndependentDrafter(draft, k=args.k),
                            t_params, d_params, ecfg, scfg)

    print(f"workload: {n_req} requests x {max_tokens} tokens "
          f"(prompt {args.prompt_len}), {args.slots} slots, K={args.k}, "
          f"steps_per_sync={args.steps_per_sync}, cache={args.cache}")
    best = _measure({"new": new_server(), "old": old_server()},
                    reqs, max_tokens, repeats=2 if args.quick else 3)
    new, old = best["new"], best["old"]
    speedup = new["tok_s"] / old["tok_s"]

    print(f"device-resident: {new['tok_s']:8.1f} tok/s  "
          f"({new['tokens']} tok in {new['wall_s']:.2f}s, "
          f"{new['ticks']} tick groups, "
          f"{new['syncs_per_tick']:.2f} host syncs/group — all at harvest)")
    print(f"legacy         : {old['tok_s']:8.1f} tok/s  "
          f"({old['tokens']} tok in {old['wall_s']:.2f}s, "
          f"{old['ticks']} ticks, "
          f"{old['syncs_per_tick']:.2f} host syncs/tick)")
    print(f"speedup        : {speedup:.2f}x")

    rows = [
        ("serving/device_resident",
         new["wall_s"] / max(new["ticks"], 1) * 1e6,
         f"tok_s={new['tok_s']:.1f};cache={args.cache};"
         f"syncs_per_group={new['syncs_per_tick']:.2f}"),
        ("serving/legacy",
         old["wall_s"] / max(old["ticks"], 1) * 1e6,
         f"tok_s={old['tok_s']:.1f};syncs_per_tick={old['syncs_per_tick']:.2f}"),
        ("serving/speedup", 0.0, f"x={speedup:.2f}"),
    ]
    rows += longctx_admission(target, t_params, draft, d_params,
                              k=min(args.k, 3))
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return speedup


if __name__ == "__main__":
    main()
