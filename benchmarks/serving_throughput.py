"""Serving throughput: device-resident scheduler vs the legacy host-synced one.

Measures end-to-end tokens/s of the continuous-batching ``SpecServer``
(batched admission, donated carry, ``steps_per_sync`` fused cycles per
dispatch, harvest = one gathered ``device_get`` of finished rows) against a
faithful reimplementation of the pre-rewrite scheduler (one broadcast-to-B
prefill per request, one cycle per tick, host-computed budgets pushed back
into the carry with ``_replace``, per-slot harvest reads) — both running the
same ``DecodeSession`` engine core, so the difference is pure scheduling.

Also reports host-sync counts: the device-resident tick loop performs zero
device→host transfers per fused tick group; the legacy loop performs
several per cycle.

``--cache {dense,paged}`` selects the KV layout of the device-resident
server, and a long-context admission section compares the two layouts at
EQUAL device KV memory: the dense server reserves a worst-case ``max_len``
ring per slot, the paged server spends the same bytes on a shared block
pool — and admits several times more concurrent requests whose *actual*
usage is short, with outputs bit-identical to offline
``DecodeSession.generate`` (greedy).  Reported as
``serving/longctx_admission_*`` CSV rows.

``--mesh DATA,MODEL`` adds a mesh-sweep section: the same workload served by
a single-device server vs the mesh-partitioned one (slots sharded over
``data``, target tensor dims over ``model``), reporting tok/s scaling
against the 1-device baseline.  The flag transparently forces enough XLA
host-platform devices *before jax is imported*, so it works on plain CPU.

Every run also writes a machine-readable ``BENCH_serving.json`` summary
(tok/s, host syncs, admitted concurrency, mesh scaling) at the repo root —
the perf trajectory baseline future PRs diff against.

    python -m benchmarks.serving_throughput            # trained tiny pair
    python -m benchmarks.serving_throughput --quick    # random weights (CI)
    python -m benchmarks.serving_throughput --quick --cache paged
    python -m benchmarks.serving_throughput --quick --cache paged \
        --kv-dtype int8                    # + quantized-pool section
    python -m benchmarks.serving_throughput --quick --mesh 2,1

Emits the same ``name,us_per_call,derived`` CSV rows as ``benchmarks/run.py``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time
from collections import deque


def _force_host_devices_for_mesh(argv):
    """Read ``--mesh`` off argv and force enough XLA host-platform devices.
    MUST run before the jax import below — the flag is consumed at backend
    init and cannot be applied retroactively.  An already-present forcing
    flag is raised (never lowered) to the mesh size."""
    shape = None
    for i, a in enumerate(argv):
        if a == "--mesh" and i + 1 < len(argv):
            shape = argv[i + 1]
        elif a.startswith("--mesh="):
            shape = a.split("=", 1)[1]
    if not shape:
        return
    try:
        n = 1
        for x in shape.split(","):
            n *= int(x)
    except ValueError:
        return                          # argparse will reject it properly
    if n <= 1:
        return
    import re
    flags = os.environ.get("XLA_FLAGS", "")
    m = re.search(r"--xla_force_host_platform_device_count=(\d+)", flags)
    if m is None:
        flags += f" --xla_force_host_platform_device_count={n}"
    elif int(m.group(1)) < n:
        flags = (flags[:m.start(1)] + str(n) + flags[m.end(1):])
    os.environ["XLA_FLAGS"] = flags.strip()


_force_host_devices_for_mesh(sys.argv)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import EngineConfig, IndependentDrafter, make_generate_fn
from repro.models import build_model
from repro.serving import Request, SamplingParams, ServerConfig, SpecServer

BENCH_JSON = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                          "BENCH_serving.json")


# ---------------------------------------------------------------------------
# Legacy scheduler (pre device-resident rewrite), kept here as the baseline
# ---------------------------------------------------------------------------

class LegacyServer:
    """The old host-synced slot scheduler, verbatim in behaviour: admission
    is one broadcast-to-B prefill per request, every tick runs ONE cycle and
    then round-trips ``lengths``/``finished`` through the host to enforce
    ``max_tokens`` (pushed back with ``_replace``), and harvest reads the
    carry per slot.  It also reproduces the old overshoot bug: responses
    exceed ``max_tokens`` by up to K tokens."""

    def __init__(self, target, drafter, t_params, d_params, engine_cfg,
                 cfg: ServerConfig):
        from repro.core.session import DecodeSession
        self.session = DecodeSession(target, drafter, engine_cfg)
        self.t_params, self.d_params = t_params, d_params
        self.cfg = cfg
        b = cfg.slots
        self.state = self.session.init_state(t_params, d_params, b,
                                             cfg.max_len)
        self.budget = np.zeros((b,), np.int64)
        self.queue = deque()
        self.slot_req = [None] * b
        self.slot_base_len = np.zeros((b,), np.int64)
        self._responses = []
        self.host_syncs = 0
        self.step_calls = 0

        self._cycle = jax.jit(lambda tp, dp, st: self.session.cycle(tp, dp, st))
        self._prefill = jax.jit(self._prefill_impl)

    def _prefill_impl(self, t_params, d_params, state, prompt, plen, slot):
        b = self.cfg.slots
        smask = jnp.arange(b) == slot
        prompt_b = jnp.broadcast_to(prompt[None], (b, prompt.shape[0]))
        plen_b = jnp.full((b,), plen, jnp.int32)
        return self.session.prefill(t_params, d_params, state, prompt_b,
                                    plen_b, slot_mask=smask)

    def submit(self, req):
        self.queue.append(req)

    def _host(self, x):
        self.host_syncs += 1
        return np.asarray(x)

    def _admit(self):
        finished = self._host(self.state.finished)
        for slot in range(self.cfg.slots):
            if not finished[slot]:
                continue
            if self.slot_req[slot] is not None:
                self._harvest(slot)
            if self.queue:
                req = self.queue.popleft()
                s = self.cfg.max_prompt_len
                prompt = np.zeros((s,), np.int32)
                plen = min(len(req.prompt), s)
                prompt[:plen] = req.prompt[:plen]
                self.state = self._prefill(
                    self.t_params, self.d_params, self.state,
                    jnp.asarray(prompt), jnp.int32(plen), jnp.int32(slot))
                self.slot_req[slot] = req
                self.slot_base_len[slot] = plen
                self.budget[slot] = req.params.max_tokens

    def _harvest(self, slot):
        req = self.slot_req[slot]
        toks = self._host(self.state.buf)[
            slot, :int(self._host(self.state.lengths)[slot])]
        cyc = int(self._host(self.state.stats["cycles"])[slot])
        com = int(self._host(self.state.stats["commits"])[slot])
        self._responses.append(Response_legacy(
            req.uid, toks[int(self.slot_base_len[slot]):], cyc, com))
        self.slot_req[slot] = None

    def step(self):
        self._admit()
        if all(r is None for r in self.slot_req):
            return
        self.step_calls += 1
        self.state = self._cycle(self.t_params, self.d_params, self.state)
        lengths = self._host(self.state.lengths)
        fin = self._host(self.state.finished).copy()
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            if lengths[slot] - self.slot_base_len[slot] >= self.budget[slot]:
                fin[slot] = True
        self.state = self.state._replace(finished=jnp.asarray(fin))

    def run(self, *, max_ticks=10_000):
        for _ in range(max_ticks):
            if not self.queue and all(r is None for r in self.slot_req):
                break
            self.step()
            finished = self._host(self.state.finished)
            for slot, req in enumerate(self.slot_req):
                if req is not None and finished[slot]:
                    self._harvest(slot)
        out, self._responses = self._responses, []
        return out


@dataclasses.dataclass
class Response_legacy:
    uid: int
    tokens: np.ndarray
    n_cycles: int
    n_committed: int


# ---------------------------------------------------------------------------
# Harness
# ---------------------------------------------------------------------------

def _requests(n, max_tokens, prompt_len, corpus, seed=0):
    prompts = corpus.sample_batch(n, prompt_len, seed=seed)
    return [Request(uid=i, prompt=np.asarray(prompts[i], np.int32),
                    params=SamplingParams(max_tokens=max_tokens,
                                          temperature=1.0))
            for i in range(n)]


def _serve_once(server, reqs, max_tokens):
    """One timed pass over the request list.  Useful tokens =
    min(len(resp), max_tokens) so the legacy overshoot bug doesn't inflate
    its own throughput."""
    server.host_syncs = 0
    server.step_calls = 0
    for r in reqs:
        server.submit(dataclasses.replace(r))
    t0 = time.time()
    resps = server.run()
    wall = time.time() - t0
    toks = sum(min(len(r.tokens), max_tokens) for r in resps)
    assert len(resps) == len(reqs)
    return {"tok_s": toks / wall, "wall_s": wall, "tokens": toks,
            "host_syncs": server.host_syncs, "ticks": server.step_calls,
            "syncs_per_tick": server.host_syncs / max(server.step_calls, 1)}


def _measure(servers, reqs, max_tokens, repeats=3):
    """Warm every server (compile pass), then interleave timed passes and
    keep each server's best — interleaving cancels machine-load drift that
    would otherwise bias whichever server ran in the quiet window."""
    for s in servers.values():
        _serve_once(s, reqs, max_tokens)
    best = {}
    for _ in range(repeats):
        for name, s in servers.items():
            res = _serve_once(s, reqs, max_tokens)
            if name not in best or res["wall_s"] < best[name]["wall_s"]:
                best[name] = res
    return best


# ---------------------------------------------------------------------------
# Long-context admission capacity at equal device KV memory
# ---------------------------------------------------------------------------

def _run_tracking_concurrency(server, reqs):
    """Drive the scheduler loop by hand, recording peak in-flight slots."""
    for r in reqs:
        server.submit(dataclasses.replace(r))
    peak = 0
    for _ in range(10_000):
        if not server.queue and all(x is None for x in server.slot_req):
            break
        server._admit()
        peak = max(peak, sum(x is not None for x in server.slot_req))
        server.step()
        server.sync()
    resps, server._responses = server._responses, []
    return resps, peak


def longctx_admission(target, t_params, draft, d_params, *, k=3):
    """Both layouts get the same device KV budget and must be able to hold a
    ``max_len``-token request per slot; the workload's ACTUAL usage is short
    (prompt + a small budget).  Dense admits one request per reserved ring;
    paged admits until pool headroom runs out.  Returns the CSV rows and
    asserts paged responses equal offline greedy generation."""
    from repro.models.layers import TRASH_SLOTS

    max_len, prompt_len, max_tokens, bs = 192, 8, 8, 16
    dense_slots = 2
    # equal K/V bytes: the dense rings' token capacity, re-spent on a pool
    kv_tokens = dense_slots * (max_len + TRASH_SLOTS)
    pool_blocks = kv_tokens // bs
    ecfg = EngineConfig(k=k, rule="strict", mode="greedy", temperature=0.0)

    def mk(cache, slots, pool=0):
        return SpecServer(
            target, IndependentDrafter(draft, k=k, temperature=0.0),
            t_params, d_params, ecfg,
            ServerConfig(slots=slots, max_len=max_len,
                         max_prompt_len=prompt_len, cache=cache,
                         block_size=bs, pool_blocks=pool))

    from repro.models.paging import PagedCacheConfig
    per_req = PagedCacheConfig(bs, pool_blocks).request_blocks(
        prompt_len, max_tokens, k + 2, max_len)   # chain buffer_margin = k+2
    paged_slots = (pool_blocks - 1) // per_req

    from benchmarks import common as C
    prompts = C.corpus().sample_batch(paged_slots, prompt_len, seed=7)
    reqs = [Request(uid=i, prompt=np.asarray(prompts[i], np.int32),
                    params=SamplingParams(max_tokens=max_tokens,
                                          temperature=0.0))
            for i in range(paged_slots)]

    d_resps, d_peak = _run_tracking_concurrency(mk("dense", dense_slots), reqs)
    p_resps, p_peak = _run_tracking_concurrency(
        mk("paged", paged_slots, pool_blocks), reqs)
    assert len(d_resps) == len(p_resps) == paged_slots

    # paged responses must equal offline greedy generation, per request
    gen = make_generate_fn(target, IndependentDrafter(draft, k=k,
                                                      temperature=0.0), ecfg)
    out = gen(t_params, d_params, jnp.asarray(prompts),
              jnp.full((paged_slots,), prompt_len, jnp.int32),
              jax.random.PRNGKey(0), max_new=max_tokens)
    offline = np.asarray(out["tokens"])[:, prompt_len:prompt_len + max_tokens]
    for r in p_resps:
        np.testing.assert_array_equal(np.asarray(r.tokens),
                                      offline[r.uid],
                                      err_msg=f"paged req {r.uid} != offline")

    ratio = p_peak / max(d_peak, 1)
    print(f"\nlong-context admission at equal KV memory "
          f"({kv_tokens} tokens/layer, max_len={max_len}):")
    print(f"  dense : {d_peak:3d} concurrent ({dense_slots} rings reserved)")
    print(f"  paged : {p_peak:3d} concurrent ({pool_blocks}-block pool, "
          f"{per_req} blocks/request)")
    print(f"  ratio : {ratio:.1f}x  (paged outputs == offline greedy)")
    rows = [
        ("serving/longctx_admission_dense", 0.0,
         f"concurrent={d_peak};kv_tokens={kv_tokens}"),
        ("serving/longctx_admission_paged", 0.0,
         f"concurrent={p_peak};kv_tokens={kv_tokens};block={bs}"),
        ("serving/longctx_admission_ratio", 0.0,
         f"x={ratio:.1f};outputs=offline_match"),
    ]
    summary = {"kv_tokens_per_layer": kv_tokens,
               "dense_concurrent": int(d_peak),
               "paged_concurrent": int(p_peak),
               "admission_ratio": round(ratio, 2)}
    return rows, summary


# ---------------------------------------------------------------------------
# Prefix reuse: shared-system-prompt and multi-turn serving through the
# refcounted block-sharing prefix cache
# ---------------------------------------------------------------------------

def prefix_reuse(target, t_params, draft, d_params, *, quick, k=3):
    """Three measurements of ``prefix_cache="on"`` against ``"off"`` on the
    same paged pool:

    * **prefill FLOPs** — N requests share one long system prompt; with the
      cache on, only the first pays the cold prefill (per data shard) and
      every follower decodes its own suffix only.  Two counters: useful
      per-request positions decoded (``prefill_tokens`` — the KV work
      skipped) and batched-window positions (``prefill_window_tokens`` —
      the dispatched program's compute incl. masked rows; a cold admit
      sharing a pass with cached ones forces the full window on everyone,
      so this ratio is the honest lower bound on realised savings).
      Greedy outputs are asserted byte-identical to ``off``.
    * **admission concurrency at equal pool bytes** — shared blocks are
      counted once in pool headroom, so a pool sized for ~2 cold requests
      admits the whole shared-prefix batch concurrently.
    * **multi-turn** — each turn resends the conversation so far; the hit
      rate climbs as the cache absorbs the history.

    Returns CSV rows + the BENCH_serving.json summary dict."""
    sys_len = 64 if quick else 512
    n_req, suffix_len, max_tokens, bs, slots = 16, 8, 8, 16, 8
    plen = sys_len + suffix_len
    max_len = plen + max_tokens + k + 4
    ecfg = EngineConfig(k=k, rule="strict", mode="greedy", temperature=0.0)

    def mk(prefix, pool_blocks=0):
        return SpecServer(
            target, IndependentDrafter(draft, k=k, temperature=0.0),
            t_params, d_params, ecfg,
            ServerConfig(slots=slots, max_len=max_len, max_prompt_len=plen,
                         cache="paged", block_size=bs,
                         pool_blocks=pool_blocks, prefix_cache=prefix))

    from benchmarks import common as C
    cor = C.corpus()
    system = np.asarray(cor.sample_batch(1, sys_len, seed=11)[0], np.int32)
    reqs = []
    for i in range(n_req):
        tail = np.asarray(cor.sample_batch(1, suffix_len,
                                           seed=300 + i)[0], np.int32)
        reqs.append(Request(uid=i, prompt=np.concatenate([system, tail]),
                            params=SamplingParams(max_tokens=max_tokens,
                                                  temperature=0.0)))

    # -- prefill FLOPs + output parity: pass 1 (cold index) gives the
    # FLOPs counters and the parity assertion; pass 2 (warm compile cache
    # AND warm prefix index) gives steady-state tok/s
    print(f"\nprefix reuse ({n_req} requests, {sys_len}-token shared "
          f"system prompt, block {bs}):")
    outs, flops, win, toks_s = {}, {}, {}, {}
    hit = None
    for mode in ("off", "on"):
        server = mk(mode)
        for r in reqs:
            server.submit(dataclasses.replace(r))
        resps = server.run()
        outs[mode] = {r.uid: np.asarray(r.tokens) for r in resps}
        flops[mode] = server.prefill_tokens
        win[mode] = server.prefill_window_tokens
        if mode == "on":
            # cold-index baseline, BEFORE the warm pass inflates it
            hit = server.prefix.summary()
        for r in reqs:
            server.submit(dataclasses.replace(r))
        t0 = time.time()
        warm = server.run()
        wall = time.time() - t0
        toks_s[mode] = sum(len(r.tokens) for r in warm) / wall
        print(f"  prefix {mode:3s}: {toks_s[mode]:8.1f} tok/s steady-state, "
              f"{flops[mode]} cold useful prefill positions "
              f"({win[mode]} batched-window positions)")
    del server          # free pool buffers before the sections below
    for uid in outs["off"]:
        np.testing.assert_array_equal(
            outs["on"][uid], outs["off"][uid],
            err_msg=f"prefix-cache req {uid} diverged from cold cache")
    flops_ratio = flops["on"] / max(flops["off"], 1)
    window_ratio = win["on"] / max(win["off"], 1)

    # -- admission concurrency at equal pool bytes: pool holds ~2 cold
    # requests' worth of blocks
    from repro.models.paging import PagedCacheConfig
    per_req = PagedCacheConfig(bs, 8).request_blocks(
        plen, max_tokens, k + 2, max_len)
    pool_blocks = 2 * per_req + 2
    off_resps, off_peak = _run_tracking_concurrency(
        mk("off", pool_blocks), [dataclasses.replace(r) for r in reqs])
    on_resps, on_peak = _run_tracking_concurrency(
        mk("on", pool_blocks), [dataclasses.replace(r) for r in reqs])
    assert len(off_resps) == len(on_resps) == n_req
    conc_ratio = on_peak / max(off_peak, 1)

    # -- multi-turn: each turn resends prompt + response + a new user turn
    mt = mk("on")
    convo = np.asarray(cor.sample_batch(1, 16, seed=99)[0], np.int32)
    n_turns = 4
    for t in range(n_turns):
        mt.submit(Request(uid=t, prompt=convo.copy(),
                          params=SamplingParams(max_tokens=max_tokens,
                                                temperature=0.0)))
        resp = mt.run()[0]
        user = np.asarray(cor.sample_batch(1, 8, seed=500 + t)[0], np.int32)
        convo = np.concatenate([convo, np.asarray(resp.tokens, np.int32),
                                user])
        if len(convo) > plen - 1:
            break
    mt_hit = mt.prefix.summary()

    print(f"  prefill positions: on {flops['on']} vs off {flops['off']} "
          f"= {flops_ratio:.2f}x useful (hit rate {hit['hit_rate']:.0%}); "
          f"batched-window {window_ratio:.2f}x")
    print(f"  concurrency at a {pool_blocks}-block pool: "
          f"on {on_peak} vs off {off_peak} = {conc_ratio:.1f}x")
    print(f"  multi-turn: reuse rate {mt_hit['reuse_rate']:.0%} over "
          f"{mt_hit['lookups']} turns, {mt_hit['cow_clones']} COW clones")
    rows = [
        ("serving/prefix_tok_s", 0.0,
         f"on={toks_s['on']:.1f};off={toks_s['off']:.1f}"),
        ("serving/prefix_flops", 0.0,
         f"on={flops['on']};off={flops['off']};ratio={flops_ratio:.3f};"
         f"window_ratio={window_ratio:.3f}"),
        ("serving/prefix_hit_rate", 0.0,
         f"hit={hit['hit_rate']:.3f};reuse={hit['reuse_rate']:.3f}"),
        ("serving/prefix_concurrency", 0.0,
         f"on={on_peak};off={off_peak};x={conc_ratio:.1f}"),
        ("serving/prefix_multiturn", 0.0,
         f"reuse={mt_hit['reuse_rate']:.3f};cow={mt_hit['cow_clones']}"),
    ]
    summary = {
        "system_prompt_tokens": sys_len, "requests": n_req,
        "tok_s_on": round(toks_s["on"], 1),
        "tok_s_off": round(toks_s["off"], 1),
        "prefill_positions_on": int(flops["on"]),
        "prefill_positions_off": int(flops["off"]),
        "prefill_ratio": round(flops_ratio, 3),
        "prefill_window_ratio": round(window_ratio, 3),
        "hit_rate": hit["hit_rate"], "reuse_rate": hit["reuse_rate"],
        "blocks_shared": hit["blocks_shared"],
        "cow_clones": hit["cow_clones"],
        "concurrency_on": int(on_peak), "concurrency_off": int(off_peak),
        "concurrency_ratio": round(conc_ratio, 2),
        "multiturn_reuse_rate": mt_hit["reuse_rate"],
    }
    return rows, summary


# ---------------------------------------------------------------------------
# Quantized pool: equal-HBM admission, greedy fidelity, θ-sweep drift
# ---------------------------------------------------------------------------

def quantized_pool(target, t_params, draft, d_params, *, kv_dtype, k=3):
    """Three measurements of a ``kv_dtype`` (int8/fp8) pool against bf16:

    * **admission at equal HBM** — both pools get the same byte budget,
      priced honestly at bf16 rates for the baseline (the CPU harness
      stores f32, but a serving deployment would store bf16); quantized
      blocks cost ``head_dim`` bytes (int8) + 2 scale bytes per token-head
      vs ``2*head_dim`` for bf16, so the same bytes buy ~1.94x the blocks
      at head_dim=64 — measured as peak concurrent requests.
    * **greedy fidelity** — the same greedy MARS workload through both
      pools: exact-output agreement, token agreement, and the
      acceptance-rate delta, which must sit within the bf16 workload-noise
      tolerance (acceptance-rate spread across bf16 runs on resampled
      prompts of the same distribution).
    * **θ mini-sweep** — ``benchmarks.table4_theta``-style offline sweep
      through ``eval_engine(paged=...)`` at both dtypes: per-θ τ and
      acceptance-rate deltas (wide-margin accepts shrug off quantization
      noise; near-threshold ones may flip).

    Returns CSV rows + the BENCH_serving.json ``quantized`` summary."""
    from benchmarks import common as C
    from repro.models.paging import PagedCacheConfig, pool_block_bytes

    cfg = target.cfg
    prompt_len, max_tokens, bs = 8, 8, 16
    max_len = prompt_len + max_tokens + k + 4
    ecfg = EngineConfig(k=k, rule="mars", theta=0.9, mode="greedy",
                        temperature=0.0, guard="margin")
    per_req = PagedCacheConfig(bs, 8).request_blocks(
        prompt_len, max_tokens, k + 2, max_len)

    # equal-HBM sizing: a bf16 pool holding `conc` concurrent requests sets
    # the byte budget; the quantized pool refits the same bytes
    conc = 12
    bf16_cfg = dataclasses.replace(cfg, dtype="bfloat16")
    n_bf16 = conc * per_req + 1                       # +1: trash block
    budget = n_bf16 * pool_block_bytes(bf16_cfg, bs, "bf16")
    n_q = budget // pool_block_bytes(bf16_cfg, bs, kv_dtype)
    q_cap = (n_q - 1) // per_req
    slots = q_cap + 2
    n_req = q_cap + 2

    def mk(kv, pool, seed=7):
        server = SpecServer(
            target, IndependentDrafter(draft, k=k, temperature=0.0),
            t_params, d_params, ecfg,
            ServerConfig(slots=slots, max_len=max_len,
                         max_prompt_len=prompt_len, cache="paged",
                         block_size=bs, pool_blocks=pool, kv_dtype=kv))
        prompts = C.corpus().sample_batch(n_req, prompt_len, seed=seed)
        reqs = [Request(uid=i, prompt=np.asarray(prompts[i], np.int32),
                        params=SamplingParams(max_tokens=max_tokens,
                                              temperature=0.0))
                for i in range(n_req)]
        return server, reqs

    def accept_rate(resps):
        cyc = sum(r.n_cycles for r in resps)
        return sum(r.n_accepted for r in resps) / max(k * cyc, 1)

    print(f"\nquantized pool ({kv_dtype}, block {bs}, "
          f"budget {budget // 1024} KiB/layer at bf16 rates):")
    b_resps, b_peak = _run_tracking_concurrency(*mk("bf16", n_bf16))
    q_resps, q_peak = _run_tracking_concurrency(*mk(kv_dtype, n_q))
    assert len(b_resps) == len(q_resps) == n_req
    ratio = q_peak / max(b_peak, 1)
    print(f"  admission: bf16 {b_peak} concurrent ({n_bf16} blocks) vs "
          f"{kv_dtype} {q_peak} ({n_q} blocks) = {ratio:.2f}x at equal HBM")

    # greedy fidelity on the SAME requests; noise tolerance from a bf16 run
    # on resampled prompts of the same distribution
    b_out = {r.uid: np.asarray(r.tokens) for r in b_resps}
    q_out = {r.uid: np.asarray(r.tokens) for r in q_resps}
    exact = np.mean([np.array_equal(b_out[u], q_out[u]) for u in b_out])

    def _agree(a, b):
        n = min(len(a), len(b))
        return np.mean(a[:n] == b[:n]) if n else 1.0

    agree = np.mean([_agree(b_out[u], q_out[u]) for u in b_out])
    rate_b, rate_q = accept_rate(b_resps), accept_rate(q_resps)
    n_resps, _ = _run_tracking_concurrency(*mk("bf16", n_bf16, seed=8))
    noise = abs(accept_rate(n_resps) - rate_b)
    tol = max(2 * noise, 0.06)
    delta = rate_q - rate_b
    print(f"  fidelity : exact-output {exact:.0%}, token agreement "
          f"{agree:.1%}; accept rate {rate_b:.3f} -> {rate_q:.3f} "
          f"(delta {delta:+.3f}, bf16 noise tol {tol:.3f})")
    assert abs(delta) <= tol, (
        f"{kv_dtype} acceptance-rate delta {delta:+.3f} exceeds bf16 "
        f"noise tolerance {tol:.3f}")
    if kv_dtype == "int8":
        assert ratio >= 1.9, (
            f"int8 equal-HBM admission ratio {ratio:.2f} < 1.9")

    # θ mini-sweep: offline eval_engine through paged pools at both dtypes
    drafter = IndependentDrafter(draft, k=k, temperature=0.0)
    sweep = []
    for th in (0.85, 0.90, 0.95):
        rb = C.eval_engine(f"bf16@{th}", target, t_params, drafter,
                           d_params, ecfg, max_new=16, n_prompts=4,
                           theta=th, paged=PagedCacheConfig(bs))
        rq = C.eval_engine(f"{kv_dtype}@{th}", target, t_params, drafter,
                           d_params, ecfg, max_new=16, n_prompts=4,
                           theta=th,
                           paged=PagedCacheConfig(bs, kv_dtype=kv_dtype))
        sweep.append({"theta": th, "tau_bf16": round(rb.tau, 3),
                      f"tau_{kv_dtype}": round(rq.tau, 3),
                      "accept_bf16": round(rb.accept_rate, 3),
                      f"accept_{kv_dtype}": round(rq.accept_rate, 3),
                      "tau_delta": round(rq.tau - rb.tau, 3)})
        print(f"  theta={th:.2f}: tau {rb.tau:.2f} -> {rq.tau:.2f}, "
              f"accept {rb.accept_rate:.2f} -> {rq.accept_rate:.2f}")

    rows = [
        (f"serving/quantized_admission_{kv_dtype}", 0.0,
         f"concurrent={q_peak};bf16={b_peak};x={ratio:.2f};"
         f"budget_bytes={budget}"),
        (f"serving/quantized_fidelity_{kv_dtype}", 0.0,
         f"exact={exact:.3f};agree={agree:.3f};accept_delta={delta:+.3f};"
         f"tol={tol:.3f}"),
    ]
    summary = {
        "kv_dtype": kv_dtype, "block_size": bs,
        "equal_hbm_budget_bytes_per_layer": int(budget),
        "bf16_blocks": int(n_bf16), "quantized_blocks": int(n_q),
        "bf16_concurrent": int(b_peak),
        "quantized_concurrent": int(q_peak),
        "admission_ratio": round(ratio, 2),
        "greedy_exact_output_rate": round(float(exact), 3),
        "greedy_token_agreement": round(float(agree), 4),
        "accept_rate_bf16": round(rate_b, 4),
        "accept_rate_quantized": round(rate_q, 4),
        "accept_rate_delta": round(delta, 4),
        "bf16_noise_tolerance": round(tol, 4),
        "theta_sweep": sweep,
    }
    return rows, summary


# ---------------------------------------------------------------------------
# Multi-arch paged smoke: every attention family through the block pool
# ---------------------------------------------------------------------------

def multi_arch_paged(*, k=3):
    """Paged serving across attention families: a hybrid target (attention
    sub-cache paged, recurrent leaves dense in the carry) and a
    sliding-window target (window-bounded ring of blocks, wrapping), the
    latter also through an int8 pool.  Each case asserts token parity with
    the offline ``DecodeSession.generate`` reference for its own pool
    dtype (the full 10-config matrix lives in tests/test_paged_archs.py;
    this leg keeps tok/s and per-slot block counts on the perf
    trajectory).  Returns CSV rows + the ``multi_arch`` summary."""
    from repro.configs import get_smoke
    from repro.core.session import DecodeSession
    from repro.models.paging import PagedCacheConfig

    bs = 4
    win_cfg = dataclasses.replace(get_smoke("granite-8b"), dtype="float32",
                                  sliding_window=8)
    cases = [
        ("hybrid", dataclasses.replace(get_smoke("zamba2-2.7b"),
                                       dtype="float32"), "bf16"),
        ("sliding_window", win_cfg, "bf16"),
        ("sliding_window_int8", win_cfg, "int8"),
    ]
    n_req, prompt_len, max_tokens = 4, 6, 8
    rows, summary = [], {}
    print(f"\nmulti-arch paged smoke (block {bs}):")
    for name, cfg, kv in cases:
        target = build_model(cfg)
        d_cfg = ModelConfig(name="d", family="dense", n_layers=1,
                            d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
                            vocab_size=cfg.vocab_size, dtype="float32")
        draft = build_model(d_cfg)
        t_params = target.init(jax.random.PRNGKey(1))
        d_params = draft.init(jax.random.PRNGKey(2))
        ecfg = EngineConfig(k=k, rule="mars", mode="greedy",
                            temperature=0.0)
        rng = np.random.default_rng(5)
        reqs = [Request(uid=i,
                        prompt=rng.integers(3, cfg.vocab_size,
                                            prompt_len).astype(np.int32),
                        params=SamplingParams(max_tokens=max_tokens,
                                              temperature=0.0))
                for i in range(n_req)]
        server = SpecServer(
            target, IndependentDrafter(draft, k=k, temperature=0.0),
            t_params, d_params, ecfg,
            ServerConfig(slots=2, max_len=64, max_prompt_len=8,
                         cache="paged", block_size=bs, kv_dtype=kv))
        for r in reqs:
            server.submit(dataclasses.replace(r))
        t0 = time.time()
        resps = server.run()
        wall = time.time() - t0
        toks = sum(len(r.tokens) for r in resps)

        # parity against the offline reference through the SAME pool dtype
        session = DecodeSession(target,
                                IndependentDrafter(draft, k=k,
                                                   temperature=0.0), ecfg)
        paged_ref = (None if kv == "bf16"
                     else PagedCacheConfig(bs, kv_dtype=kv))
        for r in resps:
            req = reqs[r.uid]
            o = session.generate(t_params, d_params,
                                 jnp.asarray(req.prompt)[None],
                                 jnp.asarray([prompt_len], jnp.int32),
                                 max_tokens, jax.random.PRNGKey(0),
                                 paged=paged_ref)
            ref = np.asarray(o["tokens"])[0, prompt_len:
                                          prompt_len + max_tokens]
            np.testing.assert_array_equal(
                np.asarray(r.tokens), ref,
                err_msg=f"multi-arch {name} req {r.uid} != offline")
        if cfg.sliding_window:
            ring = min(64, cfg.sliding_window)
            assert server.max_blocks == -(-ring // bs), server.max_blocks
        assert server.pool.available == server.pool.n_blocks - 1
        print(f"  {name:19s} ({cfg.name}, kv={kv}): {toks / wall:8.1f} "
              f"tok/s, {server.max_blocks} blocks/slot, outputs=offline")
        rows.append((f"serving/multiarch_{name}", 0.0,
                     f"tok_s={toks / wall:.1f};arch={cfg.name};kv={kv};"
                     f"blocks_per_slot={server.max_blocks}"))
        summary[name] = {"arch": cfg.name, "kv_dtype": kv,
                         "tok_s": round(toks / wall, 1),
                         "blocks_per_slot": int(server.max_blocks),
                         "sliding_window": int(cfg.sliding_window or 0),
                         "outputs": "offline_match"}
    return rows, summary


# ---------------------------------------------------------------------------
# Adaptive verification under bursty load: fixed-theta sweep vs controller
# ---------------------------------------------------------------------------

def _serve_open_loop(server, reqs, arrivals):
    """Open-loop serving: requests arrive on their own (Poisson) schedule
    regardless of server progress — the production regime where a burst
    builds a real admission queue.  Returns per-uid submit→finish latency
    and the drained responses."""
    t0 = time.time()
    submit_t, finish_t, harvested = {}, {}, 0
    i = 0
    while True:
        now = time.time() - t0
        while i < len(reqs) and arrivals[i] <= now:
            submit_t[reqs[i].uid] = now
            server.submit(dataclasses.replace(reqs[i]))
            i += 1
        idle = not server.queue and all(x is None for x in server.slot_req)
        if i >= len(reqs) and idle:
            break
        if idle:
            time.sleep(max(arrivals[i] - now, 0.0))
            continue
        server._admit()
        server.step()
        server.sync()
        now = time.time() - t0
        for r in server._responses[harvested:]:
            finish_t[r.uid] = now
        harvested = len(server._responses)
    resps, server._responses = server._responses, []
    lat = np.asarray([finish_t[r.uid] - submit_t[r.uid] for r in resps])
    return resps, lat


def adaptive_serving(target, t_params, draft, d_params, *, quick, k=4):
    """Bursty open-loop comparison of fixed-theta serving against the
    margin/acceptance controller.

    Workload: two Poisson phases — calm (λ below the measured service rate)
    then a burst (λ ~2x the service rate), so the admission queue actually
    builds and the controller's pressure term engages.  Greedy MARS
    decoding throughout; per-config metrics:

    * p50/p99 submit→finish latency (queueing included);
    * greedy-token agreement against the strict-verification offline
      reference — the fidelity cost of relaxation (disagreement = tokens
      that differ from what strict greedy would have emitted).

    The sweep serves the same workload at several fixed thetas spanning
    [theta_min, theta_max]; the adaptive run starts at the strict end and
    lets the controller relax under pressure.  The summary lands in
    ``BENCH_serving.json`` under ``"adaptive"`` (merge-written)."""
    from benchmarks import common as C

    prompt_len, max_tokens = (8, 8) if quick else (32, 24)
    n_calm, n_burst = (6, 10) if quick else (16, 32)
    slots = 2 if quick else 4
    th_min, th_max = 0.6, 0.99
    fixed_thetas = [0.6, 0.9, 0.99] if quick else [0.6, 0.75, 0.9, 0.99]
    ecfg = EngineConfig(k=k, rule="mars", mode="greedy", temperature=0.0,
                        theta=0.9, guard="margin")
    n_req = n_calm + n_burst
    reqs = _requests(n_req, max_tokens, prompt_len, C.corpus(), seed=23)
    for r in reqs:
        r.params.temperature = 0.0

    # strict-verification offline reference (== AR greedy): the fidelity
    # yardstick every config's outputs are scored against
    gen = make_generate_fn(
        target, IndependentDrafter(draft, k=k, temperature=0.0),
        dataclasses.replace(ecfg, rule="strict"))
    prompts = np.stack([r.prompt for r in reqs])
    out = gen(t_params, d_params, jnp.asarray(prompts),
              jnp.full((n_req,), prompt_len, jnp.int32),
              jax.random.PRNGKey(0), max_new=max_tokens)
    strict_ref = np.asarray(out["tokens"])[:, prompt_len:
                                           prompt_len + max_tokens]

    def mk(mode, theta):
        kw = {}
        if mode == "adaptive":
            kw = dict(theta_mode="adaptive", theta_min=th_min,
                      theta_max=th_max)
        return SpecServer(
            target, IndependentDrafter(draft, k=k, temperature=0.0),
            t_params, d_params, dataclasses.replace(ecfg, theta=theta),
            ServerConfig(slots=slots,
                         max_len=prompt_len + max_tokens + k + 4,
                         max_prompt_len=prompt_len, **kw))

    # service rate from a closed-loop warm pass (also pays jit compile so
    # the open-loop latencies below are scheduling, not compilation)
    warm = mk("fixed", 0.9)
    res = _serve_once(warm, reqs, max_tokens)
    svc_rate = n_req / res["wall_s"]            # requests/s, closed loop
    rng = np.random.default_rng(31)
    gaps = np.concatenate([
        rng.exponential(1.0 / (0.7 * svc_rate), n_calm),   # calm phase
        rng.exponential(1.0 / (2.0 * svc_rate), n_burst)]) # burst: λ > μ
    arrivals = np.cumsum(gaps)

    def disagreement(resps):
        per = []
        for r in resps:
            ref = strict_ref[r.uid]
            n = min(len(r.tokens), len(ref))
            per.append(float(np.mean(np.asarray(r.tokens)[:n] != ref[:n])))
        return float(np.mean(per))

    print(f"\nadaptive verification under bursty load "
          f"({n_calm}+{n_burst} requests, Poisson 0.7x then 2.0x the "
          f"service rate, {slots} slots, K={k}):")
    results = {}
    for name, mode, theta in (
            [(f"fixed@{t:.2f}", "fixed", t) for t in fixed_thetas]
            + [("adaptive", "adaptive", th_max)]):
        server = mk(mode, theta)
        _serve_once(server, reqs[:2], max_tokens)      # compile pass
        resps, lat = _serve_open_loop(server, reqs, arrivals)
        assert len(resps) == n_req
        entry = {"p50_s": float(np.percentile(lat, 50)),
                 "p99_s": float(np.percentile(lat, 99)),
                 "disagreement": disagreement(resps)}
        if mode == "adaptive":
            entry["theta_retunes"] = int(server.theta_retunes)
            entry["final_thetas"] = [round(float(t), 3)
                                     for t in server.slot_theta]
        results[name] = entry
        extra = (f", {entry.get('theta_retunes', 0)} retunes"
                 if mode == "adaptive" else "")
        print(f"  {name:11s}: p50 {entry['p50_s']:6.3f}s  "
              f"p99 {entry['p99_s']:6.3f}s  "
              f"strict-disagreement {entry['disagreement']:.3f}{extra}")

    ad = results["adaptive"]
    fixed = {n: v for n, v in results.items() if n != "adaptive"}
    best_p99 = min(v["p99_s"] for v in fixed.values())
    relaxed_dis = results[f"fixed@{min(fixed_thetas):.2f}"]["disagreement"]
    print(f"  adaptive p99 vs best fixed: {ad['p99_s']:.3f}s / "
          f"{best_p99:.3f}s; disagreement vs most-relaxed fixed: "
          f"{ad['disagreement']:.3f} / {relaxed_dis:.3f}")

    rows = [(f"serving/adaptive_{name}", 0.0,
             f"p50={v['p50_s']:.3f};p99={v['p99_s']:.3f};"
             f"disagree={v['disagreement']:.3f}")
            for name, v in results.items()]
    summary = {
        "workload": {"calm": n_calm, "burst": n_burst,
                     "max_tokens": max_tokens, "slots": slots, "k": k,
                     "service_rate_rps": round(svc_rate, 2)},
        "theta_bounds": [th_min, th_max],
        "fixed": {n: {k2: round(v2, 4) if isinstance(v2, float) else v2
                      for k2, v2 in v.items()} for n, v in fixed.items()},
        "adaptive": {k2: round(v2, 4) if isinstance(v2, float) else v2
                     for k2, v2 in ad.items()},
        "p99_vs_best_fixed": round(ad["p99_s"] / max(best_p99, 1e-9), 3),
        "disagreement_vs_most_relaxed":
            round(ad["disagreement"] / max(relaxed_dis, 1e-9), 3)
            if relaxed_dis > 0 else None,
    }
    return rows, summary


# ---------------------------------------------------------------------------
# Pipelined tick: overlap + admission ring + prefill worker
# ---------------------------------------------------------------------------

def _serve_phased(server, reqs, max_tokens, *, fence):
    """One pass driven by hand with per-phase wall splits.

    ``fence=True`` inserts ``jax.block_until_ready`` after admission and
    after the group dispatch, serialising the phases so each bucket
    measures its own device time; ``fence=False`` times the pipelined
    schedule as-is — the difference between the two walls is the work the
    overlap actually hid."""
    for r in reqs:
        server.submit(dataclasses.replace(r))
    phases = {"admit": 0.0, "dispatch": 0.0, "harvest": 0.0}
    t_start = time.time()
    for _ in range(10_000):
        if (not server.queue and all(x is None for x in server.slot_req)
                and not server._pending
                and not (server._ring is not None and server._ring_staged)):
            break
        t0 = time.time()
        server._admit()
        if fence:
            jax.block_until_ready(server.state)
        t1 = time.time()
        server.step()
        if fence:
            jax.block_until_ready(server.state)
            if server._ring is not None:
                jax.block_until_ready(server._ring)
        t2 = time.time()
        server.sync()
        t3 = time.time()
        phases["admit"] += t1 - t0
        phases["dispatch"] += t2 - t1
        phases["harvest"] += t3 - t2
    if server._overlap and server._pending:
        t0 = time.time()
        server.sync(flush=True)
        phases["harvest"] += time.time() - t0
    wall = time.time() - t_start
    resps, server._responses = server._responses, []
    assert len(resps) == len(reqs)
    toks = sum(min(len(r.tokens), max_tokens) for r in resps)
    return resps, {"wall_s": wall, "tok_s": toks / wall,
                   "phases": {k2: round(v, 3) for k2, v in phases.items()}}


def pipelined(target, t_params, draft, d_params, *, quick, use_worker,
              profile, k=3):
    """Serial tick vs the pipelined tick (double-buffered overlap +
    device-side admission ring, optionally + the disaggregated prefill
    worker) on a prompt-heavy saturated queue.  Greedy, so the section
    doubles as a parity gate: every variant must produce token-identical
    responses.  With ``profile`` on, a fenced pass splits the wall into
    admit / dispatch / harvest and the fenced-vs-pipelined delta measures
    the drafter-compute-over-D2H overlap directly."""
    from benchmarks import common as C
    n_req, max_tokens, prompt_len, slots = ((10, 8, 48, 4) if quick
                                            else (24, 12, 64, 4))
    ecfg = EngineConfig(k=k, rule="mars", mode="greedy", temperature=0.0)
    prompts = C.corpus().sample_batch(n_req, prompt_len, seed=11)
    # ragged budgets: slots free mid-group, which is exactly the regime the
    # admission ring targets (uniform budgets finish in lockstep and the
    # host refills every slot at the sync anyway)
    budgets = (max(max_tokens // 2, 2), max_tokens, 2 * max_tokens)
    reqs = [Request(uid=i, prompt=np.asarray(prompts[i], np.int32),
                    params=SamplingParams(max_tokens=budgets[i % 3],
                                          temperature=0.0))
            for i in range(n_req)]
    max_tok_hi = max(budgets)

    def mk(**kw):
        return SpecServer(
            target, IndependentDrafter(draft, k=k, temperature=0.0),
            t_params, d_params, ecfg,
            ServerConfig(slots=slots,
                         max_len=prompt_len + max_tok_hi + k + 4,
                         max_prompt_len=prompt_len, cache="paged", **kw))

    servers = {"serving/pipeline_serial": mk(),
               "serving/pipeline_overlap": mk(overlap=True,
                                              ring_depth=slots)}
    if use_worker:
        servers["serving/pipeline_worker"] = mk(overlap=True,
                                                ring_depth=slots,
                                                prefill_worker=True)

    # parity gate first (also the compile warm-up): all variants identical
    base = None
    for name, srv in servers.items():
        for r in reqs:
            srv.submit(dataclasses.replace(r))
        out = {r.uid: np.asarray(r.tokens) for r in srv.run()}
        assert sorted(out) == list(range(n_req)), name
        if base is None:
            base = out
        else:
            for uid in base:
                np.testing.assert_array_equal(
                    out[uid], base[uid],
                    err_msg=f"{name} diverged from serial on req {uid}")

    best = _measure(servers, reqs, max_tok_hi, repeats=2 if quick else 3)
    serial = best["serving/pipeline_serial"]
    over = best["serving/pipeline_overlap"]
    uplift = over["tok_s"] / serial["tok_s"]
    ov_srv = servers["serving/pipeline_overlap"]

    print(f"\npipelined tick ({n_req} req x {min(budgets)}-{max_tok_hi} tok, "
          f"prompt {prompt_len}, {slots} slots, paged, greedy):")
    print(f"  serial         : {serial['tok_s']:8.1f} tok/s")
    print(f"  overlap+ring   : {over['tok_s']:8.1f} tok/s  "
          f"({uplift:.2f}x, {ov_srv.ring_refills} ring refills, "
          f"{ov_srv.slot_idle_ticks} idle slot-ticks)")
    rows = [("serving/pipeline_serial", 0.0,
             f"tok_s={serial['tok_s']:.1f}"),
            ("serving/pipeline_overlap", 0.0,
             f"tok_s={over['tok_s']:.1f};uplift={uplift:.2f}")]
    summary = {
        "workload": {"requests": n_req, "budgets": list(budgets),
                     "prompt_len": prompt_len, "slots": slots,
                     "cache": "paged", "quick": bool(quick)},
        "serial_tok_s": round(serial["tok_s"], 1),
        "overlap_tok_s": round(over["tok_s"], 1),
        "uplift": round(uplift, 2),
        "ring_refills": int(ov_srv.ring_refills),
        "slot_idle_ticks": int(ov_srv.slot_idle_ticks),
        "token_parity": "identical",
    }
    if use_worker:
        wrk = best["serving/pipeline_worker"]
        wrk_srv = servers["serving/pipeline_worker"]
        print(f"  +prefill worker: {wrk['tok_s']:8.1f} tok/s  "
              f"({wrk_srv.worker.stats['fills']} fills, "
              f"{wrk_srv.worker.stats['filled_tokens']} prompt tok off "
              f"the decode path)")
        rows.append(("serving/pipeline_worker", 0.0,
                     f"tok_s={wrk['tok_s']:.1f};"
                     f"fills={wrk_srv.worker.stats['fills']}"))
        summary["worker_tok_s"] = round(wrk["tok_s"], 1)
        summary["worker"] = {k2: int(v) for k2, v in
                             wrk_srv.worker.stats.items()}
    if profile:
        # fenced pass: serialised per-phase device time; pipelined pass:
        # the same server free-running.  fenced - pipelined = hidden work.
        prof_srv = servers["serving/pipeline_overlap"]
        _, fenced = _serve_phased(prof_srv, reqs, max_tok_hi, fence=True)
        _, piped = _serve_phased(prof_srv, reqs, max_tok_hi, fence=False)
        hidden = max(1.0 - piped["wall_s"] / max(fenced["wall_s"], 1e-9),
                     0.0)
        print(f"  phases (fenced): admit {fenced['phases']['admit']:.3f}s, "
              f"dispatch {fenced['phases']['dispatch']:.3f}s, "
              f"harvest {fenced['phases']['harvest']:.3f}s; "
              f"pipelined wall {piped['wall_s']:.3f}s vs fenced "
              f"{fenced['wall_s']:.3f}s -> {hidden:.0%} hidden")
        rows.append(("serving/pipeline_phases", 0.0,
                     f"fenced_s={fenced['wall_s']:.3f};"
                     f"piped_s={piped['wall_s']:.3f};hidden={hidden:.2f}"))
        summary["phases_fenced"] = fenced["phases"]
        summary["fenced_wall_s"] = round(fenced["wall_s"], 3)
        summary["pipelined_wall_s"] = round(piped["wall_s"], 3)
        summary["overlap_hidden_frac"] = round(hidden, 2)
    return rows, summary


# ---------------------------------------------------------------------------
# Telemetry overhead: the observability stack must ride the existing polls
# ---------------------------------------------------------------------------

def telemetry_overhead(target, t_params, draft, d_params, *, quick, k=3,
                       metrics_out=None, trace_out=None, events_out=None):
    """Same saturated pipelined workload served with telemetry off vs on
    (lifecycle tracer + metrics registry + tick spans).  The contract under
    test: telemetry reads ONLY rows the harvest poll already transfers, so
    host syncs are identical and the tok/s overhead must stay under 2% —
    asserted here, recorded under ``observability`` in BENCH_serving.json.
    A final fresh-telemetry pass writes the Prometheus / Chrome-trace /
    JSONL artifacts (fresh so the files hold exactly one lifecycle per
    uid; the measurement passes reuse uids across repeats)."""
    from benchmarks import common as C
    from repro.obs import ServerTelemetry
    n_req, max_tokens, prompt_len, slots = ((10, 8, 48, 4) if quick
                                            else (24, 12, 64, 4))
    ecfg = EngineConfig(k=k, rule="mars", mode="greedy", temperature=0.0,
                        guard="margin")
    prompts = C.corpus().sample_batch(n_req, prompt_len, seed=13)
    budgets = (max(max_tokens // 2, 2), max_tokens, 2 * max_tokens)
    reqs = [Request(uid=i, prompt=np.asarray(prompts[i], np.int32),
                    params=SamplingParams(max_tokens=budgets[i % 3],
                                          temperature=0.0))
            for i in range(n_req)]
    max_tok_hi = max(budgets)

    def mk(telemetry=None):
        # adaptive + overlap + ring: the config where every telemetry hook
        # fires (retune spans, ring-staged lifecycles, in-flight counter)
        return SpecServer(
            target, IndependentDrafter(draft, k=k, temperature=0.0),
            t_params, d_params, ecfg,
            ServerConfig(slots=slots,
                         max_len=prompt_len + max_tok_hi + k + 4,
                         max_prompt_len=prompt_len, cache="paged",
                         overlap=True, ring_depth=slots,
                         theta_mode="adaptive"),
            telemetry=telemetry)

    servers = {"serving/telemetry_off": mk(),
               "serving/telemetry_on": mk(ServerTelemetry())}

    # parity + warm-up: telemetry must not perturb a single token, and the
    # zero-transfer contract means host syncs match exactly
    outs, syncs = {}, {}
    for name, srv in servers.items():
        for r in reqs:
            srv.submit(dataclasses.replace(r))
        outs[name] = {r.uid: np.asarray(r.tokens) for r in srv.run()}
        syncs[name] = srv.host_syncs
    for uid in outs["serving/telemetry_off"]:
        np.testing.assert_array_equal(
            outs["serving/telemetry_on"][uid],
            outs["serving/telemetry_off"][uid],
            err_msg=f"telemetry changed tokens on req {uid}")
    assert syncs["serving/telemetry_on"] == syncs["serving/telemetry_off"], (
        "telemetry added device->host transfers: "
        f"{syncs['serving/telemetry_on']} syncs vs "
        f"{syncs['serving/telemetry_off']}")

    best = _measure(servers, reqs, max_tok_hi, repeats=3 if quick else 4)
    off, on = best["serving/telemetry_off"], best["serving/telemetry_on"]
    overhead = max(1.0 - on["tok_s"] / off["tok_s"], 0.0)
    assert overhead < 0.02, (
        f"telemetry overhead {overhead:.1%} >= 2% "
        f"({on['tok_s']:.1f} vs {off['tok_s']:.1f} tok/s)")

    # artifact pass: fresh server + fresh telemetry, one submission per uid
    tel = ServerTelemetry()
    srv = mk(tel)
    for r in reqs:
        srv.submit(dataclasses.replace(r))
    srv.run()
    tel.write(metrics_out, trace_out, events_out)
    ts = tel.summary()

    def _ms(v):
        return round(v * 1e3, 2) if v is not None else None

    print(f"\ntelemetry ({n_req} req, adaptive theta, overlap+ring, paged):")
    print(f"  off: {off['tok_s']:8.1f} tok/s   on: {on['tok_s']:8.1f} tok/s "
          f"({overhead:.1%} overhead, < 2% asserted)")
    print(f"  {ts['finished']} lifecycles, {ts['span_events']} span events, "
          f"{ts['theta_retunes']} retunes; TTFT p50 {_ms(ts['ttft_p50_s'])}ms")
    for flag, path in (("--metrics-out", metrics_out),
                       ("--trace-out", trace_out),
                       ("--events-out", events_out)):
        if path:
            print(f"  wrote {flag[2:]}: {path}")
    rows = [("serving/telemetry_off", 0.0, f"tok_s={off['tok_s']:.1f}"),
            ("serving/telemetry_on", 0.0,
             f"tok_s={on['tok_s']:.1f};overhead={overhead:.3f}")]
    summary = {
        "workload": {"requests": n_req, "budgets": list(budgets),
                     "prompt_len": prompt_len, "slots": slots,
                     "cache": "paged", "overlap": True,
                     "theta_mode": "adaptive", "quick": bool(quick)},
        "off_tok_s": round(off["tok_s"], 1),
        "on_tok_s": round(on["tok_s"], 1),
        "overhead_frac": round(overhead, 4),
        "host_syncs_match": True,
        "token_parity": "identical",
        "finished_lifecycles": int(ts["finished"]),
        "trace_events": int(ts["span_events"]),
        "theta_retunes": int(ts["theta_retunes"]),
        "ttft_p50_ms": _ms(ts["ttft_p50_s"]),
        "ttft_p99_ms": _ms(ts["ttft_p99_s"]),
        "itl_p50_ms": _ms(ts["itl_p50_s"]),
    }
    return rows, summary


# ---------------------------------------------------------------------------
# Mesh sweep: tok/s scaling of the partitioned tick vs one device
# ---------------------------------------------------------------------------

# Dedicated sweep target: heavy enough that a tick group is compute-bound
# (the quick pair's ticks are dispatch-bound, which hides any partitioning
# win on CPU hosts where the 1-device baseline already multi-threads).
SWEEP_TARGET_CFG = ModelConfig(name="sweep-target", family="dense",
                               n_layers=6, d_model=512, n_heads=8,
                               n_kv_heads=8, d_ff=1024, vocab_size=64,
                               dtype="float32")


def mesh_sweep(draft, d_params, mesh_shape, *, cache, kv_dtype="bf16", k=4):
    """Weak-scaling sweep: per-shard slot count fixed, the data axis
    multiplies the admitted concurrency.  Baseline = the SAME workload on a
    single-device server with one shard's slots; the mesh server runs
    ``data`` shards of them concurrently.  Reports tok/s and the scaling
    ratio (>1 means the data axis bought real throughput)."""
    data, model = mesh_shape
    target = build_model(SWEEP_TARGET_CFG)
    t_params = target.init(jax.random.PRNGKey(0))
    per_shard_slots, n_req, max_tokens, prompt_len = 4, 24, 8, 64
    ecfg = EngineConfig(k=k, rule="mars", mode="sample", temperature=1.0,
                        guard="margin")

    from benchmarks import common as C
    reqs = _requests(n_req, max_tokens, prompt_len, C.corpus())

    def mk(mesh, slots, **kw):
        return SpecServer(
            target, IndependentDrafter(draft, k=k), t_params, d_params,
            ecfg,
            ServerConfig(slots=slots, max_len=prompt_len + max_tokens + k + 4,
                         max_prompt_len=prompt_len, cache=cache, mesh=mesh,
                         kv_dtype=kv_dtype, **kw))

    servers = {"serving/mesh_1dev": mk(None, per_shard_slots),
               f"serving/mesh_{data}x{model}": mk(mesh_shape,
                                                  per_shard_slots * data),
               # stealing off: admission fills free slots in id order, so
               # a drained shard waits on its own harvests even when the
               # neighbour shard has headroom — the before/after pins what
               # the load-aware order buys
               "serving/mesh_nosteal": mk(mesh_shape,
                                          per_shard_slots * data,
                                          shard_steal=False)}
    best = _measure(servers, reqs, max_tokens, repeats=4)
    base = best["serving/mesh_1dev"]
    part = best[f"serving/mesh_{data}x{model}"]
    nosteal = best["serving/mesh_nosteal"]
    scaling = part["tok_s"] / base["tok_s"]
    steal_x = part["tok_s"] / max(nosteal["tok_s"], 1e-9)

    print(f"\nmesh sweep ({cache} cache, {per_shard_slots} slots/shard, "
          f"target {SWEEP_TARGET_CFG.n_layers}L/d{SWEEP_TARGET_CFG.d_model}):")
    print(f"  1 device   : {base['tok_s']:8.1f} tok/s "
          f"({per_shard_slots} slots)")
    print(f"  mesh {data}x{model}   : {part['tok_s']:8.1f} tok/s "
          f"({per_shard_slots * data} slots, "
          f"{part['syncs_per_tick']:.2f} syncs/group)")
    print(f"  scaling    : {scaling:.2f}x from the data axis")
    print(f"  stealing   : {nosteal['tok_s']:8.1f} tok/s without "
          f"cross-shard work stealing ({steal_x:.2f}x from the "
          f"load-aware admission order)")
    rows = [
        ("serving/mesh_1dev", 0.0,
         f"tok_s={base['tok_s']:.1f};slots={per_shard_slots}"),
        (f"serving/mesh_{data}x{model}", 0.0,
         f"tok_s={part['tok_s']:.1f};slots={per_shard_slots * data};"
         f"cache={cache}"),
        ("serving/mesh_scaling", 0.0, f"x={scaling:.2f}"),
        ("serving/mesh_steal", 0.0,
         f"off_tok_s={nosteal['tok_s']:.1f};x={steal_x:.2f}"),
    ]
    summary = {"shape": [data, model], "cache": cache,
               "kv_dtype": kv_dtype,
               "slots_per_shard": per_shard_slots,
               "baseline_tok_s": round(base["tok_s"], 1),
               "baseline_slots": per_shard_slots,
               "mesh_tok_s": round(part["tok_s"], 1),
               "mesh_slots": per_shard_slots * data,
               "mesh_host_syncs": int(part["host_syncs"]),
               "mesh_tick_groups": int(part["ticks"]),
               "scaling": round(scaling, 2),
               "steal": {"on_tok_s": round(part["tok_s"], 1),
                         "off_tok_s": round(nosteal["tok_s"], 1),
                         "uplift": round(steal_x, 2)}}
    return rows, summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="random weights, small workload (CI smoke)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-tokens", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=128,
                    help="prompt-heavy serving (prompts >> outputs, the "
                         "common production regime): admission dominates")
    ap.add_argument("--steps-per-sync", type=int, default=4)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--cache", default="dense", choices=["dense", "paged"],
                    help="KV layout of the device-resident server (the "
                         "legacy baseline always runs dense)")
    ap.add_argument("--kv-dtype", default="bf16",
                    choices=["bf16", "int8", "fp8"],
                    help="paged only: KV pool storage dtype for the "
                         "device-resident server; int8/fp8 add a quantized "
                         "section (equal-HBM admission vs bf16, greedy "
                         "fidelity, theta-sweep drift) to the report and "
                         "BENCH_serving.json")
    ap.add_argument("--prefix-cache", default="off", choices=["off", "on"],
                    help="paged only: refcounted prefix-block sharing; "
                         "adds a prefix-reuse section (shared system "
                         "prompt, equal-pool admission, multi-turn) to the "
                         "report and BENCH_serving.json")
    ap.add_argument("--mesh", default=None, metavar="DATA,MODEL",
                    help="add a mesh-sweep section: tok/s of the "
                         "(data, model)-partitioned server vs one device "
                         "(host devices are forced automatically)")
    ap.add_argument("--multi-arch", action="store_true",
                    help="paged only: add a multi-arch section serving a "
                         "hybrid and a sliding-window config through the "
                         "block pool (int8 included), asserting offline "
                         "parity and recording tok/s + blocks/slot under "
                         "'multi_arch' in BENCH_serving.json")
    ap.add_argument("--overlap", action="store_true",
                    help="add a pipelined-tick section: serial tick vs "
                         "double-buffered overlap + device-side admission "
                         "ring on a saturated paged workload, with a "
                         "token-parity gate (written to BENCH_serving.json "
                         "under 'pipeline')")
    ap.add_argument("--prefill-worker", action="store_true",
                    help="with --overlap: add a third variant that also "
                         "prefills cold prompts through the disaggregated "
                         "worker program")
    ap.add_argument("--profile-phases", action="store_true",
                    help="with --overlap: fenced per-phase timing "
                         "(admit/dispatch/harvest via block_until_ready) "
                         "vs the free-running pipeline; the delta is the "
                         "overlap-hidden fraction")
    ap.add_argument("--theta-mode", default="fixed",
                    choices=["fixed", "adaptive"],
                    help="adaptive: add a bursty open-loop section "
                         "comparing a fixed-theta sweep against the "
                         "margin/acceptance controller on p50/p99 latency "
                         "and greedy-token agreement vs strict "
                         "verification (written to BENCH_serving.json "
                         "under 'adaptive')")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="telemetry section: write Prometheus text metrics "
                         "here (any of the three --*-out flags enables the "
                         "telemetry-overhead section; docs/OBSERVABILITY.md)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="telemetry section: write the Perfetto-loadable "
                         "Chrome trace of tick spans here")
    ap.add_argument("--events-out", default=None, metavar="PATH",
                    help="telemetry section: write the per-request "
                         "lifecycle JSONL here")
    args = ap.parse_args()

    mesh_shape = None
    if args.mesh:
        mesh_shape = tuple(int(x) for x in args.mesh.split(","))
        if len(mesh_shape) != 2 or min(mesh_shape) < 1:
            raise SystemExit(f"--mesh expects DATA,MODEL, got {args.mesh!r}")

    from benchmarks import common as C
    if args.quick:
        target = build_model(C.TARGET_CFG)
        draft = build_model(C.DRAFT_CFG)
        t_params = target.init(jax.random.PRNGKey(0))
        d_params = draft.init(jax.random.PRNGKey(1))
        n_req, max_tokens = min(args.requests, 8), min(args.max_tokens, 8)
    else:
        target, t_params, draft, d_params = C.get_pair()
        n_req, max_tokens = args.requests, args.max_tokens

    if args.prefix_cache == "on" and args.cache != "paged":
        raise SystemExit("--prefix-cache on requires --cache paged")
    if args.kv_dtype != "bf16" and args.cache != "paged":
        raise SystemExit(f"--kv-dtype {args.kv_dtype} requires --cache "
                         "paged (quantized storage lives in the block pool)")
    ecfg = EngineConfig(k=args.k, rule="mars", mode="sample",
                        temperature=1.0, guard="margin")
    scfg = ServerConfig(slots=args.slots,
                        max_len=args.prompt_len + max_tokens + args.k + 4,
                        max_prompt_len=args.prompt_len,
                        steps_per_sync=args.steps_per_sync,
                        cache=args.cache, kv_dtype=args.kv_dtype,
                        prefix_cache=args.prefix_cache)
    reqs = _requests(n_req, max_tokens, args.prompt_len, C.corpus())

    def new_server():
        return SpecServer(target, IndependentDrafter(draft, k=args.k),
                          t_params, d_params, ecfg, scfg)

    def old_server():
        return LegacyServer(target, IndependentDrafter(draft, k=args.k),
                            t_params, d_params, ecfg, scfg)

    print(f"workload: {n_req} requests x {max_tokens} tokens "
          f"(prompt {args.prompt_len}), {args.slots} slots, K={args.k}, "
          f"steps_per_sync={args.steps_per_sync}, cache={args.cache}")
    best = _measure({"new": new_server(), "old": old_server()},
                    reqs, max_tokens, repeats=2 if args.quick else 3)
    new, old = best["new"], best["old"]
    speedup = new["tok_s"] / old["tok_s"]

    print(f"device-resident: {new['tok_s']:8.1f} tok/s  "
          f"({new['tokens']} tok in {new['wall_s']:.2f}s, "
          f"{new['ticks']} tick groups, "
          f"{new['syncs_per_tick']:.2f} host syncs/group — all at harvest)")
    print(f"legacy         : {old['tok_s']:8.1f} tok/s  "
          f"({old['tokens']} tok in {old['wall_s']:.2f}s, "
          f"{old['ticks']} ticks, "
          f"{old['syncs_per_tick']:.2f} host syncs/tick)")
    print(f"speedup        : {speedup:.2f}x")

    rows = [
        ("serving/device_resident",
         new["wall_s"] / max(new["ticks"], 1) * 1e6,
         f"tok_s={new['tok_s']:.1f};cache={args.cache};"
         f"syncs_per_group={new['syncs_per_tick']:.2f}"),
        ("serving/legacy",
         old["wall_s"] / max(old["ticks"], 1) * 1e6,
         f"tok_s={old['tok_s']:.1f};syncs_per_tick={old['syncs_per_tick']:.2f}"),
        ("serving/speedup", 0.0, f"x={speedup:.2f}"),
    ]
    lc_rows, lc_summary = longctx_admission(target, t_params, draft,
                                            d_params, k=min(args.k, 3))
    rows += lc_rows
    prefix_summary = None
    if args.prefix_cache == "on":
        p_rows, prefix_summary = prefix_reuse(target, t_params, draft,
                                              d_params, quick=args.quick,
                                              k=min(args.k, 3))
        rows += p_rows
    quant_summary = None
    if args.kv_dtype != "bf16":
        q_rows, quant_summary = quantized_pool(target, t_params, draft,
                                               d_params,
                                               kv_dtype=args.kv_dtype,
                                               k=min(args.k, 3))
        rows += q_rows
    mesh_summary = None
    if mesh_shape is not None:
        m_rows, mesh_summary = mesh_sweep(draft, d_params, mesh_shape,
                                          cache=args.cache,
                                          kv_dtype=args.kv_dtype, k=args.k)
        rows += m_rows
    multiarch_summary = None
    if args.multi_arch:
        if args.cache != "paged":
            raise SystemExit("--multi-arch requires --cache paged")
        ma_rows, multiarch_summary = multi_arch_paged(k=min(args.k, 3))
        rows += ma_rows
    pipeline_summary = None
    if args.overlap:
        p_rows, pipeline_summary = pipelined(target, t_params, draft,
                                             d_params, quick=args.quick,
                                             use_worker=args.prefill_worker,
                                             profile=args.profile_phases,
                                             k=min(args.k, 3))
        rows += p_rows
    adaptive_summary = None
    if args.theta_mode == "adaptive":
        a_rows, adaptive_summary = adaptive_serving(target, t_params, draft,
                                                    d_params,
                                                    quick=args.quick,
                                                    k=min(args.k, 3))
        rows += a_rows
    obs_summary = None
    if args.metrics_out or args.trace_out or args.events_out:
        o_rows, obs_summary = telemetry_overhead(
            target, t_params, draft, d_params, quick=args.quick,
            k=min(args.k, 3), metrics_out=args.metrics_out,
            trace_out=args.trace_out, events_out=args.events_out)
        rows += o_rows
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    # machine-readable perf-trajectory baseline (committed at repo root so
    # future PRs can diff tok/s, sync counts, and mesh scaling)
    summary = {
        "benchmark": "serving_throughput",
        "workload": {"requests": n_req, "max_tokens": max_tokens,
                     "prompt_len": args.prompt_len, "slots": args.slots,
                     "k": args.k, "cache": args.cache,
                     "quick": bool(args.quick)},
        "device_resident": {"tok_s": round(new["tok_s"], 1),
                            "host_syncs": int(new["host_syncs"]),
                            "tick_groups": int(new["ticks"]),
                            "syncs_per_group": round(new["syncs_per_tick"],
                                                     3)},
        "legacy": {"tok_s": round(old["tok_s"], 1),
                   "syncs_per_tick": round(old["syncs_per_tick"], 2)},
        "speedup_vs_legacy": round(speedup, 2),
        "longctx_admission": lc_summary,
        "prefix": prefix_summary,
        "quantized": quant_summary,
        "mesh": mesh_summary,
        "multi_arch": multiarch_summary,
        "pipeline": pipeline_summary,
        "adaptive": adaptive_summary,
        "observability": obs_summary,
    }
    # merge, don't clobber: sections another invocation produced (e.g. the
    # prefix or quantized CI legs) survive runs that don't exercise them
    merged = {}
    try:
        with open(BENCH_JSON) as f:
            merged = json.load(f)
    except (OSError, ValueError):
        pass
    for key, val in summary.items():
        if val is not None or key not in merged:
            merged[key] = val
    with open(BENCH_JSON, "w") as f:
        json.dump(merged, f, indent=2)
        f.write("\n")
    print(f"\nwrote {os.path.relpath(BENCH_JSON)}")
    return speedup


if __name__ == "__main__":
    main()
