"""Paper Figure 1 / Figure 4: logit-ratio vs probability-ratio statistics.

Decodes with the trained bench target and collects, at every decoding step:
top-1 logit, logit ratio z2/z1, probability ratio p2/p1.  Validates the
paper's three observations:

  (a) top-1 logits are (almost always) positive for a trained model,
  (b) a substantial fraction of steps fall in the relaxation zone r > 0.9,
  (c) the logit ratio decouples from the probability ratio — high-r steps
      span a wide range of p2/p1 (softmax exponential distortion).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C


def run(n_prompts=8, steps=128):
    target, t_params, _, _ = C.get_pair()
    p, plen = C.prompts(n_prompts, s=32)
    b, s = p.shape
    cache = target.init_cache(t_params, b, s + steps + 2)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    _, cache = target.decode(t_params, p, pos, cache,
                             token_mask=pos < (plen - 1)[:, None])
    last = p[:, -1]
    z1s, ratios, pratios = [], [], []
    key = jax.random.PRNGKey(0)

    @jax.jit
    def step(cache, last, key):
        logits, cache = target.decode(
            t_params, last[:, None], cache["index"][:, None], cache)
        lg = logits[:, -1].astype(jnp.float32)
        vals, _ = jax.lax.top_k(lg, 2)
        probs = jax.nn.softmax(lg, -1)
        pv, _ = jax.lax.top_k(probs, 2)
        nxt = jax.random.categorical(key, lg, -1).astype(jnp.int32)
        return cache, nxt, vals, pv

    for i in range(steps):
        key, k2 = jax.random.split(key)
        cache, last, vals, pv = step(cache, last, k2)
        z1s.append(np.asarray(vals[:, 0]))
        ratios.append(np.asarray(vals[:, 1] / np.maximum(vals[:, 0], 1e-9)))
        pratios.append(np.asarray(pv[:, 1] / np.maximum(pv[:, 0], 1e-9)))

    z1 = np.concatenate(z1s)
    r = np.concatenate(ratios)
    pr = np.concatenate(pratios)
    pos_frac = float((z1 > 0).mean())
    valid = z1 > 0
    zone = float(((r > 0.9) & valid).mean())
    # decoupling: spread of p2/p1 within the relaxation zone
    in_zone = pr[(r > 0.9) & valid]
    stats = {
        "steps": len(z1),
        "top1_logit_positive_frac": pos_frac,
        "relax_zone_frac(r>0.9)": zone,
        "zone_pratio_p10": float(np.percentile(in_zone, 10)) if len(in_zone) else None,
        "zone_pratio_p90": float(np.percentile(in_zone, 90)) if len(in_zone) else None,
        "corr(logit_ratio, prob_ratio)": float(np.corrcoef(r[valid], pr[valid])[0, 1]),
    }
    for k, v in stats.items():
        print(f"  {k}: {v}")
    return stats


if __name__ == "__main__":
    run()
