"""Paper Figure 1 / Figure 4: logit-ratio vs probability-ratio statistics.

Decodes with the trained bench target and collects, at every decoding step:
top-1 logit, logit ratio z2/z1, probability ratio p2/p1.  Validates the
paper's three observations:

  (a) top-1 logits are (almost always) positive for a trained model,
  (b) a substantial fraction of steps fall in the relaxation zone r > 0.9,
  (c) the logit ratio decouples from the probability ratio — high-r steps
      span a wide range of p2/p1 (softmax exponential distortion).

Margins are sourced on device: the decode loop is a ``lax.scan`` whose body
computes the ratio with ``repro.core.verify.top2_and_ratio`` — the SAME
primitive the verification engine and the serving margin stats use — and the
stacked per-step statistics cross the device boundary exactly once at the
end.  (The original harness re-derived the ratio host-side from a top-k
transfer every step: 3 device→host round-trips per generated token.)

``theta_mode="adaptive"`` overlays the serving controller's operating
points on the distribution: the per-row margin EMA (folded with the
session's ``MARGIN_EMA_DECAY``, exactly as ``DecodeSession.cycle``
maintains it on device) and the theta each EMA would steer the
``ThetaController`` to at zero queue pressure.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core.session import MARGIN_EMA_DECAY
from repro.core.verify import top2_and_ratio


def run(n_prompts=8, steps=128, theta_mode="fixed"):
    target, t_params, _, _ = C.get_pair()
    p, plen = C.prompts(n_prompts, s=32)
    b, s = p.shape
    cache = target.init_cache(t_params, b, s + steps + 2)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    _, cache = target.decode(t_params, p, pos, cache,
                             token_mask=pos < (plen - 1)[:, None])

    def step(carry, key):
        cache, last = carry
        logits, cache = target.decode(
            t_params, last[:, None], cache["index"][:, None], cache)
        lg = logits[:, -1].astype(jnp.float32)
        _, _, ratio, valid = top2_and_ratio(lg)        # the engine's primitive
        z1 = jnp.max(lg, axis=-1)
        pv, _ = jax.lax.top_k(jax.nn.softmax(lg, -1), 2)
        nxt = jax.random.categorical(key, lg, -1).astype(jnp.int32)
        return (cache, nxt), (z1, jnp.where(valid, ratio, 0.0),
                              pv[:, 1] / jnp.maximum(pv[:, 0], 1e-9))

    @jax.jit
    def sweep(cache, last, key):
        keys = jax.random.split(key, steps)
        _, stacked = jax.lax.scan(step, (cache, last), keys)
        return stacked                       # each (steps, B), one transfer

    z1, r, pr = (np.asarray(x).ravel()
                 for x in sweep(cache, p[:, -1], jax.random.PRNGKey(0)))
    pos_frac = float((z1 > 0).mean())
    valid = z1 > 0
    zone = float(((r > 0.9) & valid).mean())
    # decoupling: spread of p2/p1 within the relaxation zone
    in_zone = pr[(r > 0.9) & valid]
    stats = {
        "steps": len(z1),
        "top1_logit_positive_frac": pos_frac,
        "relax_zone_frac(r>0.9)": zone,
        "zone_pratio_p10": float(np.percentile(in_zone, 10)) if len(in_zone) else None,
        "zone_pratio_p90": float(np.percentile(in_zone, 90)) if len(in_zone) else None,
        "corr(logit_ratio, prob_ratio)": float(np.corrcoef(r[valid], pr[valid])[0, 1]),
    }
    if theta_mode == "adaptive":
        stats.update(_controller_overlay(np.asarray(r).reshape(steps, -1),
                                         z1.reshape(steps, -1)))
    for k, v in stats.items():
        print(f"  {k}: {v}")
    return stats


def _controller_overlay(r_steps, z1_steps):
    """Fold the per-step ratios into the session's margin EMA (decay
    ``MARGIN_EMA_DECAY``, unseen rows stay at the 0.0 sentinel — the exact
    device-side recurrence) and report where those EMAs would steer the
    serving ``ThetaController`` at zero queue pressure."""
    from repro.serving import ControllerConfig, ThetaController

    ema = np.zeros(r_steps.shape[1])
    for t in range(r_steps.shape[0]):
        sample = np.where(z1_steps[t] > 0, r_steps[t], -1.0)
        seen = sample >= 0
        ema = np.where(seen & (ema > 0),
                       MARGIN_EMA_DECAY * ema
                       + (1 - MARGIN_EMA_DECAY) * sample,
                       np.where(seen, sample, ema))
    ctl = ThetaController(ControllerConfig())
    theta = np.full_like(ema, ctl.cfg.theta_max)
    for _ in range(64):                    # iterate the update to fixed point
        theta = ctl.update(theta, np.zeros_like(ema), ema, 0.0)
    guided = ema > 0
    return {
        "margin_ema_mean": float(ema[guided].mean()) if guided.any() else None,
        "controller_theta_p10": float(np.percentile(theta, 10)),
        "controller_theta_p90": float(np.percentile(theta, 90)),
    }


if __name__ == "__main__":
    run()
