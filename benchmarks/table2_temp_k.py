"""Paper Table 2: temperature and draft-length (K) ablation for MARS.

Expected trends: τ grows with K but speedup peaks at moderate K; efficiency
stable across temperature.
"""
from __future__ import annotations

from benchmarks import common as C
from repro.core import EngineConfig, IndependentDrafter


def run(max_new=80, n_prompts=4):
    target, t_params, draft, d_params = C.get_pair()
    rows = []
    for temp in (0.2, 0.6, 1.0):
        _, ar_time, _, _ = C.eval_ar(target, t_params, max_new=max_new,
                                     n_prompts=n_prompts, temperature=temp)
        for k in (2, 4, 8):
            drafter = IndependentDrafter(draft, k=k, temperature=temp)
            ecfg = EngineConfig(k=k, rule="mars", mode="sample",
                                temperature=temp, guard="margin")
            r = C.eval_engine(f"T={temp} K={k}", target, t_params, drafter,
                              d_params, ecfg, max_new=max_new,
                              n_prompts=n_prompts, ar_time=ar_time)
            print(r.row())
            rows.append(((temp, k), r))
    return rows


if __name__ == "__main__":
    run()
