"""Shared benchmark infrastructure.

Trains (once, then checkpoints under experiments/models/) a tiny
target/draft pair on the Markov corpus, plus EAGLE-style and Medusa-style
heads distilled against the target.  The corpus temperature knob puts the
trained target into genuine low-margin regimes, which is the phenomenon the
paper exploits — so τ/θ trends measured here are real model behaviour, not
synthetic logits.

Quality metrics (CPU-scale stand-ins for the paper's task accuracies):
  * nll      — target-model NLL of the generated continuation (lower =
               better "generation quality" under the target itself)
  * greedy_match — at T=0, exact agreement with vanilla AR output
  * corpus_nll   — NLL under the TRUE corpus process (measures whether lossy
               acceptance hurts ground-truth fidelity)
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.configs.base import ModelConfig
from repro.core import (EngineConfig, EagleDrafter, IndependentDrafter,
                        MedusaDrafter, PLDrafter, init_eagle_params,
                        init_medusa_params, make_ar_generate_fn,
                        make_generate_fn, metrics)
from repro.data import MarkovCorpus, make_lm_batches
from repro.models import build_model
from repro.models.model import _apply_block
from repro.optim import adamw, apply_updates
from repro.train import Trainer, TrainerConfig

VOCAB = 64
CKPT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "models")

TARGET_CFG = ModelConfig(name="bench-target", family="dense", n_layers=4,
                         d_model=256, n_heads=4, n_kv_heads=4, d_ff=512,
                         vocab_size=VOCAB, dtype="float32")
DRAFT_CFG = ModelConfig(name="bench-draft", family="dense", n_layers=1,
                        d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
                        vocab_size=VOCAB, dtype="float32")


def corpus(temperature: float = 1.2) -> MarkovCorpus:
    return MarkovCorpus(vocab_size=VOCAB, temperature=temperature,
                        branching=8, seed=0)


def _train_lm(cfg, steps, name, *, lr=3e-3, batch=16, seq=64):
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(hash(name) % (1 << 31)))
    step_done = latest_step(CKPT_DIR, name=name)
    if step_done == steps:
        loaded = load_checkpoint(CKPT_DIR, steps, params, name=name)
        return model, jax.tree.map(jnp.asarray, loaded)
    trainer = Trainer(model, TrainerConfig(lr=lr, warmup_steps=20,
                                           total_steps=steps, log_every=100))
    params, _ = trainer.fit(
        params, make_lm_batches(corpus(), batch=batch, seq_len=seq,
                                n_batches=steps),
        log=lambda s: print(f"  [{name}] {s}"))
    save_checkpoint(CKPT_DIR, steps, params, name=name)
    return model, params


def get_pair(target_steps: int = 600, draft_steps: int = 400):
    target, t_params = _train_lm(TARGET_CFG, target_steps, "target")
    draft, d_params = _train_lm(DRAFT_CFG, draft_steps, "draft")
    return target, t_params, draft, d_params


# ---------------------------------------------------------------------------
# EAGLE / Medusa head distillation
# ---------------------------------------------------------------------------

_FEAT_FNS = {}


def _target_features(target, t_params, tokens):
    """Jitted (per model) feature extraction — eager dispatch of a full
    decode graph per training batch exhausts the CPU JIT engine."""
    fn = _FEAT_FNS.get(id(target))
    if fn is None:
        @jax.jit
        def fn(t_params, tokens):
            b, s = tokens.shape
            cache = target.init_cache(t_params, b, s + 8)
            pos = jnp.broadcast_to(
                jnp.arange(s, dtype=jnp.int32)[None], (b, s))
            _, _, feats = target.decode(t_params, tokens, pos, cache,
                                        with_features=True)
            return feats
        _FEAT_FNS[id(target)] = fn
    return fn(t_params, tokens)


def train_eagle_head(target, t_params, steps: int = 300):
    name = "eagle_head"
    cfg = target.cfg
    e_params = init_eagle_params(cfg, jax.random.PRNGKey(11))
    if latest_step(CKPT_DIR, name=name) == steps:
        return jax.tree.map(jnp.asarray, load_checkpoint(
            CKPT_DIR, steps, e_params, name=name))

    tx = adamw(2e-3, weight_decay=0.01)
    opt = tx.init(e_params)
    head_w = t_params["lm_head"]

    def loss_fn(ep, tokens, feats):
        b, s = tokens.shape
        emb = t_params["embedding"][tokens]
        feats_prev = jnp.concatenate(
            [jnp.zeros_like(feats[:, :1]), feats[:, :-1]], axis=1)
        x = jnp.concatenate([emb, feats_prev], -1) @ ep["fc"]
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        y, _, _ = _apply_block(cfg, ep["block"], x, pos)
        logits = y @ head_w
        logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), -1)
        nll = -jnp.take_along_axis(logp, tokens[:, 1:, None], -1)
        return nll.mean()

    @jax.jit
    def step(ep, opt, tokens, feats):
        l, g = jax.value_and_grad(loss_fn)(ep, tokens, feats)
        upd, opt = tx.update(g, opt, ep)
        return apply_updates(ep, upd), opt, l

    for i, b in enumerate(make_lm_batches(corpus(), batch=16, seq_len=64,
                                          n_batches=steps)):
        tokens = jnp.asarray(b["tokens"][:, :-1])
        feats = _target_features(target, t_params, tokens)
        e_params, opt, l = step(e_params, opt, tokens, feats)
        if i % 100 == 0:
            print(f"  [eagle] step {i} loss {float(l):.3f}")
    save_checkpoint(CKPT_DIR, steps, e_params, name=name)
    return e_params


def train_medusa_heads(target, t_params, n_heads: int = 4, steps: int = 300):
    name = "medusa_heads"
    m_params = init_medusa_params(target.cfg, jax.random.PRNGKey(12), n_heads)
    if latest_step(CKPT_DIR, name=name) == steps:
        return jax.tree.map(jnp.asarray, load_checkpoint(
            CKPT_DIR, steps, m_params, name=name))
    tx = adamw(2e-3, weight_decay=0.01)
    opt = tx.init(m_params)
    head_w = t_params["lm_head"]

    def loss_fn(mp, tokens, feats):
        total = 0.0
        for h in range(n_heads):
            off = h + 2   # feat at t predicts token t+2+h (t+1 is pending)
            if tokens.shape[1] <= off:
                continue
            f = feats[:, :-off]
            fh = f + jax.nn.silu(f @ mp["heads_w1"][h])
            logits = fh @ head_w
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
            lbl = tokens[:, off:, None]
            total += -jnp.take_along_axis(logp, lbl, -1).mean()
        return total / n_heads

    @jax.jit
    def step(mp, opt, tokens, feats):
        l, g = jax.value_and_grad(loss_fn)(mp, tokens, feats)
        upd, opt = tx.update(g, opt, mp)
        return apply_updates(mp, upd), opt, l

    for i, b in enumerate(make_lm_batches(corpus(), batch=16, seq_len=64,
                                          n_batches=steps)):
        tokens = jnp.asarray(b["tokens"][:, :-1])
        feats = _target_features(target, t_params, tokens)
        m_params, opt, l = step(m_params, opt, tokens, feats)
        if i % 100 == 0:
            print(f"  [medusa] step {i} loss {float(l):.3f}")
    save_checkpoint(CKPT_DIR, steps, m_params, name=name)
    return m_params


# ---------------------------------------------------------------------------
# Evaluation harness
# ---------------------------------------------------------------------------

def prompts(n: int = 8, s: int = 32, seed: int = 123):
    c = corpus()
    toks = c.sample_batch(n, s, seed=seed)
    return jnp.asarray(toks), jnp.full((n,), s, jnp.int32)


def sequence_nll(target, t_params, tokens, lengths, start):
    """Mean target-NLL of tokens[start:length] per sequence."""
    logits, _ = target.forward(t_params, {"tokens": tokens[:, :-1]})
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    nll = -jnp.take_along_axis(logp, tokens[:, 1:, None], -1)[..., 0]
    pos = jnp.arange(nll.shape[1])[None]
    mask = (pos >= start - 1) & (pos < (lengths - 1)[:, None])
    return float((nll * mask).sum() / jnp.maximum(mask.sum(), 1))


def corpus_nll(c: MarkovCorpus, tokens: np.ndarray, lengths, start) -> float:
    total, n = 0.0, 0
    for b in range(tokens.shape[0]):
        seq = tokens[b, :int(lengths[b])]
        for t in range(max(start, c.order), len(seq)):
            cid = c._ctx_id(seq[t - c.order:t])
            succ = c._succ[cid]
            p = c._probs[cid][succ == seq[t]].sum()
            total += -np.log(max(p, 1e-9))
            n += 1
    return total / max(n, 1)


@dataclasses.dataclass
class RunResult:
    name: str
    tau: float
    accept_rate: float
    relax_frac: float
    wall_s: float
    tokens_generated: int
    nll: float
    corpus_nll_: float
    speedup_measured: float = 0.0
    speedup_v5e: float = 0.0
    greedy_match: float = float("nan")
    # mean first-rejection top-2 ratio EMA over rows that saw a rejection,
    # read straight off the engine's on-device stats (no logit recompute)
    margin_ema: float = float("nan")

    def row(self):
        m = (f" margin={self.margin_ema:.3f}"
             if self.margin_ema == self.margin_ema else "")
        return (f"{self.name:24s} tau={self.tau:5.2f} "
                f"acc={self.accept_rate:.2f} relax={self.relax_frac:.2f} "
                f"speedup(meas)={self.speedup_measured:4.2f}x "
                f"speedup(v5e)={self.speedup_v5e:4.2f}x "
                f"nll={self.nll:.3f} corpus_nll={self.corpus_nll_:.3f}{m}")


def time_generate(fn, *args, repeats: int = 1, **kw):
    out = fn(*args, **kw)              # compile + warm
    jax.block_until_ready(out["tokens"])
    t0 = time.time()
    for _ in range(repeats):
        out = fn(*args, **kw)
        jax.block_until_ready(out["tokens"])
    return out, (time.time() - t0) / repeats


def eval_engine(name, target, t_params, drafter, d_params, ecfg: EngineConfig,
                *, max_new=96, n_prompts=6, theta=None, ar_time=None,
                seed=0, paged=None) -> RunResult:
    """``paged`` (a ``repro.models.paging.PagedCacheConfig``) runs the whole
    evaluation through the paged pool — with ``kv_dtype="int8"``/``"fp8"``
    this is how the fidelity harnesses measure quantized-KV drift."""
    p, plen = prompts(n_prompts)
    gen = make_generate_fn(target, drafter, ecfg, paged=paged)
    out, dt = time_generate(gen, t_params, d_params, p, plen,
                            jax.random.PRNGKey(seed), max_new=max_new,
                            theta=theta)
    st = out["stats"]
    tau = metrics.tau(st)
    k = ecfg.k
    # v5e-analytic speedup: per-token draft/target cost from param bytes
    c = metrics.flops_cost_ratio(
        sum(x.size for x in jax.tree.leaves(d_params)) if d_params is not None
        and not isinstance(drafter, (PLDrafter,)) else 0,
        sum(x.size for x in jax.tree.leaves(t_params)))
    sp_v5e = metrics.analytic_speedup(tau, k, cost_draft_ratio=c,
                                      verify_overhead=1.05)
    toks = int(np.asarray(st["commits"]).sum())
    nll = sequence_nll(target, t_params, out["tokens"], out["lengths"],
                       int(plen[0]))
    cn = corpus_nll(corpus(), np.asarray(out["tokens"]), out["lengths"],
                    int(plen[0]))
    me = np.asarray(st.get("margin_ema", np.zeros((0,), np.float32)))
    margin = float(me[me > 0].mean()) if (me > 0).any() else float("nan")
    return RunResult(
        name=name, tau=tau, accept_rate=metrics.acceptance_rate(st, k),
        relax_frac=metrics.relax_fraction(st), wall_s=dt,
        tokens_generated=toks, nll=nll, corpus_nll_=cn,
        speedup_measured=(ar_time / dt if ar_time else 0.0),
        speedup_v5e=sp_v5e, margin_ema=margin)


def eval_ar(target, t_params, *, max_new=96, n_prompts=6, temperature=1.0,
            seed=0):
    p, plen = prompts(n_prompts)
    gen = make_ar_generate_fn(target, temperature=temperature)
    out, dt = time_generate(gen, t_params, p, plen, jax.random.PRNGKey(seed),
                            max_new=max_new)
    nll = sequence_nll(target, t_params, out["tokens"], out["lengths"],
                       int(plen[0]))
    cn = corpus_nll(corpus(), np.asarray(out["tokens"]), out["lengths"],
                    int(plen[0]))
    return out, dt, nll, cn
