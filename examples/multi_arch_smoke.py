"""Run MARS speculative decoding against every assigned architecture family.

Instantiates the REDUCED smoke variant of each of the 10 assigned
architectures as the target model (random weights — this demonstrates the
engine's architecture coverage, incl. recurrent state recompute for
SSM/hybrid targets) and spec-decodes a few tokens with MARS.

    PYTHONPATH=src python examples/multi_arch_smoke.py [--arch <id>]
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_smoke, list_archs
from repro.configs.base import ModelConfig
from repro.core import (EngineConfig, IndependentDrafter, make_generate_fn,
                        metrics)
from repro.models import build_model


def run_arch(arch: str):
    cfg = dataclasses.replace(get_smoke(arch), dtype="float32")
    target = build_model(cfg)
    d_cfg = ModelConfig(name="draft", family="dense", n_layers=1, d_model=64,
                        n_heads=2, n_kv_heads=2, d_ff=128,
                        vocab_size=cfg.vocab_size, dtype="float32")
    draft = build_model(d_cfg)
    t_params = target.init(jax.random.PRNGKey(1))
    d_params = draft.init(jax.random.PRNGKey(2))

    prompt = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 3,
                                cfg.vocab_size)
    plen = jnp.full((2,), 8, jnp.int32)
    frames = None
    if cfg.family == "audio":   # stub frontend embeddings
        frames = jax.random.normal(jax.random.PRNGKey(5),
                                   (2, cfg.encoder_seq_len, cfg.d_model))
    gen = make_generate_fn(
        target, IndependentDrafter(draft, k=3, temperature=1.0),
        EngineConfig(k=3, rule="mars", mode="sample", temperature=1.0))
    out = gen(t_params, d_params, prompt, plen, jax.random.PRNGKey(0),
              max_new=16, encoder_frames=frames)
    t = metrics.tau(out["stats"])
    print(f"  {arch:24s} [{cfg.family:6s}] generated "
          f"{int(out['lengths'][0]) - 8} tokens, tau={t:.2f}  OK")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list_archs())
    args = ap.parse_args()
    archs = [args.arch] if args.arch else list_archs()
    print("MARS speculative decoding across assigned architectures:")
    for arch in archs:
        run_arch(arch)


if __name__ == "__main__":
    main()
