"""Quickstart: MARS verification in 60 lines.

Trains a tiny target + draft LM on a synthetic corpus (CPU, ~2 min), then
generates with strict verification vs. MARS and prints the τ / speedup
difference — the paper's core effect, end to end.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from benchmarks import common as C
from repro.core import EngineConfig, IndependentDrafter, metrics

K = 4


def main():
    print("training tiny target (4L/256d) + draft (1L/64d) ...")
    target, t_params, draft, d_params = C.get_pair()

    _, ar_time, ar_nll, _ = C.eval_ar(target, t_params, max_new=96)
    print(f"vanilla AR:      {ar_time:.2f}s  nll={ar_nll:.3f}")

    drafter = IndependentDrafter(draft, k=K, temperature=1.0)
    for rule in ("strict", "mars"):
        ecfg = EngineConfig(k=K, rule=rule, mode="sample", temperature=1.0, guard="margin")
        r = C.eval_engine(rule, target, t_params, drafter, d_params, ecfg,
                          max_new=96, ar_time=ar_time)
        extra = (f"  ({r.relax_frac:.0%} of accepts via relaxation)"
                 if rule == "mars" else "")
        print(f"{rule:6s} verify:   {r.wall_s:.2f}s  tau={r.tau:.2f}  "
              f"speedup={r.speedup_measured:.2f}x  nll={r.nll:.3f}{extra}")

    print("\nMARS accepts low-margin runner-up tokens -> higher tau at "
          "matched quality (paper Alg. 1).")


if __name__ == "__main__":
    main()
