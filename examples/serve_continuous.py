"""End-to-end serving driver: continuous-batching MARS server.

Trains the tiny pair (cached), then serves a stream of batched requests
through the device-resident slot scheduler with speculative decoding + MARS
verification, printing per-request τ and latency — the paper's serving
scenario at CPU scale.

Each request carries its own ``SamplingParams`` (token budget AND
temperature): both live in the device carry, so the tick loop enforces them
without any host round-trip — note the per-request τ spread across the
mixed-temperature stream, and the host-sync counter at the end.

The server is a thin wrapper over the shared ``DecodeSession`` engine core,
so the same scheduler serves chain drafts (independent small-LM drafter)
AND tree drafts (EAGLE-style head + caterpillar tree) — the second pass
below flips ``EngineConfig(topology="tree")`` and nothing else.

``--cache`` and ``--mesh`` exercise the exact paths the production server
uses: the paged block-pool KV layout, and the mesh-partitioned tick (slots
sharded over the ``data`` axis, target tensor dims over ``model``).

``--system-prompt`` streams requests that all share one long system prefix
through the prefix cache (``--cache paged`` implied): the first request
prefills the prefix cold, every follower maps the published KV blocks
read-only and prefills only its own suffix — the run prints the cache hit
rate, the prompt tokens whose KV was reused, and the blocks saved.

    PYTHONPATH=src python examples/serve_continuous.py
    PYTHONPATH=src python examples/serve_continuous.py --cache paged
    PYTHONPATH=src python examples/serve_continuous.py \
        --cache paged --kv-dtype int8
    PYTHONPATH=src python examples/serve_continuous.py \
        --system-prompt --system-len 64
    # 2-way slot sharding needs >= 2 devices; on CPU force host devices
    XLA_FLAGS=--xla_force_host_platform_device_count=2 \
    PYTHONPATH=src python examples/serve_continuous.py --mesh 2,1
"""
import argparse
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks import common as C
from repro.core import EagleDrafter, EngineConfig, IndependentDrafter
from repro.serving import Request, SamplingParams, ServerConfig, SpecServer


def serve(server, n_req=12, max_tokens=48, label="", temperatures=(1.0,)):
    telemetry = getattr(server, "obs", None)
    cor = C.corpus()
    for i in range(n_req):
        prompt = cor.sample_batch(1, 24, seed=100 + i)[0]
        temp = temperatures[i % len(temperatures)]
        server.submit(Request(uid=i, prompt=prompt,
                              params=SamplingParams(max_tokens=max_tokens,
                                                    temperature=temp)))
    mesh = server.cfg.mesh
    where = (f"a {mesh[0]}x{mesh[1]} (data, model) mesh" if mesh
             else "one device")
    print(f"serving {n_req} {label} requests on {server.cfg.slots} slots "
          f"({server.cfg.cache} KV cache, {where}, "
          f"temperatures {list(temperatures)}) ...")
    responses = server.run()
    taus = []
    for r in sorted(responses, key=lambda r: r.uid):
        taus.append(r.tau)
        print(f"  req {r.uid:2d}: {len(r.tokens):3d} tokens  "
              f"tau={r.tau:4.2f}  latency={r.latency_s:5.2f}s")
    print(f"mean tau = {np.mean(taus):.2f} "
          f"(tokens committed per verify cycle; >1 == speculative win)")
    print(f"host syncs: {server.host_syncs} across {server.step_calls} "
          f"fused tick groups — the tick loop itself never touches the "
          f"host")
    if telemetry is not None:
        ts = telemetry.summary()

        def _ms(v):
            return f"{v * 1e3:.1f}ms" if v is not None else "n/a"
        print(f"telemetry: TTFT p50={_ms(ts['ttft_p50_s'])} "
              f"p99={_ms(ts['ttft_p99_s'])}, ITL p50={_ms(ts['itl_p50_s'])} "
              f"— all from polls the sync already pays for")
    print()


def serve_system_prompt(target, t_params, draft, d_params, *, slots,
                        mesh, system_len, kv_dtype="bf16", n_req=12,
                        max_tokens=24):
    """Stream ``n_req`` requests sharing one ``system_len``-token system
    prefix through the prefix cache, printing hit rate and blocks saved."""
    scfg = ServerConfig(slots=slots, max_len=256,
                        max_prompt_len=system_len + 16, cache="paged",
                        block_size=16, prefix_cache="on", mesh=mesh,
                        kv_dtype=kv_dtype)
    server = SpecServer(
        target, IndependentDrafter(draft, k=4, temperature=0.0),
        t_params, d_params,
        EngineConfig(k=4, rule="mars", mode="greedy", temperature=0.0,
                     guard="margin"),
        scfg)
    cor = C.corpus()
    system = cor.sample_batch(1, system_len, seed=7)[0]
    suffix_len = 8
    for i in range(n_req):
        suffix = cor.sample_batch(1, suffix_len, seed=200 + i)[0]
        server.submit(Request(
            uid=i, prompt=np.concatenate([system, suffix]),
            params=SamplingParams(max_tokens=max_tokens, temperature=0.0)))
    print(f"serving {n_req} requests sharing a {system_len}-token system "
          f"prompt ({scfg.slots} slots, paged + prefix cache) ...")
    for r in sorted(server.run(), key=lambda r: r.uid):
        print(f"  req {r.uid:2d}: {len(r.tokens):3d} tokens  "
              f"tau={r.tau:4.2f}  latency={r.latency_s:5.2f}s")
    s = server.prefix.summary()
    cold = n_req * (system_len + suffix_len - 1)   # per-request prompt - 1
    print(f"prefix cache: hit rate {s['hit_rate']:.0%}  "
          f"tokens reused {s['tokens_reused']}/{s['tokens_total']} "
          f"({s['reuse_rate']:.0%})")
    print(f"prefill positions decoded: {server.prefill_tokens} "
          f"(cold would be {cold} — "
          f"{1 - server.prefill_tokens / cold:.0%} saved)")
    print(f"blocks: {s['blocks_shared']} shared mappings, "
          f"{s['cow_clones']} COW clones, {s['published_blocks']} published "
          f"({server.pool.n_blocks} physical in the pool)\n")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cache", default="dense", choices=["dense", "paged"],
                    help="KV layout: dense per-slot rings, or paged block "
                         "tables over a shared pool")
    ap.add_argument("--mesh", default=None, metavar="DATA,MODEL",
                    help="partition the tick over a (data, model) mesh "
                         "(needs data*model devices; on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N first)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--kv-dtype", default="bf16",
                    choices=["bf16", "int8", "fp8"],
                    help="paged only: KV pool storage dtype — int8/fp8 "
                         "quantize blocks on write with per-token-head "
                         "scales in a parallel pool")
    ap.add_argument("--system-prompt", action="store_true",
                    help="stream requests sharing one long system prefix "
                         "through the prefix cache (paged implied); print "
                         "hit rate and blocks saved")
    ap.add_argument("--system-len", type=int, default=64,
                    help="--system-prompt: shared prefix length in tokens")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write Prometheus text metrics at the end of the "
                         "chain-topology pass (docs/OBSERVABILITY.md)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the tick-span Chrome trace (Perfetto) here")
    ap.add_argument("--events-out", default=None, metavar="PATH",
                    help="write the per-request lifecycle JSONL here")
    args = ap.parse_args()
    mesh = None
    if args.mesh:
        try:
            mesh = tuple(int(x) for x in args.mesh.split(","))
            assert len(mesh) == 2 and min(mesh) >= 1
        except (ValueError, AssertionError):
            raise SystemExit(f"--mesh expects DATA,MODEL (got {args.mesh!r})")
    if args.kv_dtype != "bf16" and args.cache != "paged" \
            and not args.system_prompt:
        raise SystemExit(f"--kv-dtype {args.kv_dtype} requires --cache "
                         "paged (quantized storage lives in the block pool)")

    target, t_params, draft, d_params = C.get_pair()
    if args.system_prompt:
        serve_system_prompt(target, t_params, draft, d_params,
                            slots=args.slots, mesh=mesh,
                            system_len=args.system_len,
                            kv_dtype=args.kv_dtype)
        return
    scfg = ServerConfig(slots=args.slots, max_len=256, max_prompt_len=32,
                        cache=args.cache, mesh=mesh,
                        kv_dtype=args.kv_dtype)

    telemetry = None
    if args.metrics_out or args.trace_out or args.events_out:
        from repro.obs import ServerTelemetry
        telemetry = ServerTelemetry()

    # chain topology: independent small-LM drafter, sampling verification,
    # a different per-request temperature riding each slot's carry
    serve(SpecServer(
        target, IndependentDrafter(draft, k=4, temperature=1.0),
        t_params, d_params,
        EngineConfig(k=4, rule="mars", mode="sample", temperature=1.0,
                     guard="margin"),
        scfg, telemetry=telemetry),
        label="chain", temperatures=(0.5, 1.0, 2.0))
    if telemetry is not None:
        telemetry.write(args.metrics_out, args.trace_out, args.events_out)

    # tree topology: EAGLE-style head, caterpillar tree, greedy + MARS —
    # same scheduler, same session core, different draft topology
    e_params = C.train_eagle_head(target, t_params)
    serve(SpecServer(
        target, EagleDrafter(target, k=3, temperature=0.0),
        t_params, e_params,
        EngineConfig(k=3, rule="mars", mode="greedy", temperature=0.0,
                     guard="margin", topology="tree", branch=2),
        scfg),
        label="tree")


if __name__ == "__main__":
    main()
