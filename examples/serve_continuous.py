"""End-to-end serving driver: continuous-batching MARS server.

Trains the tiny pair (cached), then serves a stream of batched requests
through the slot scheduler with speculative decoding + MARS verification,
printing per-request τ and latency — the paper's serving scenario at CPU
scale.

    PYTHONPATH=src python examples/serve_continuous.py
"""
import numpy as np

from benchmarks import common as C
from repro.core import EngineConfig, IndependentDrafter
from repro.serving import Request, SamplingParams, ServerConfig, SpecServer


def main():
    target, t_params, draft, d_params = C.get_pair()

    server = SpecServer(
        target, IndependentDrafter(draft, k=4, temperature=1.0),
        t_params, d_params,
        EngineConfig(k=4, rule="mars", mode="sample", temperature=1.0, guard="margin"),
        ServerConfig(slots=4, max_len=256, max_prompt_len=32))

    cor = C.corpus()
    n_req = 12
    for i in range(n_req):
        prompt = cor.sample_batch(1, 24, seed=100 + i)[0]
        server.submit(Request(uid=i, prompt=prompt,
                              params=SamplingParams(max_tokens=48)))

    print(f"serving {n_req} requests on {server.cfg.slots} slots ...")
    responses = server.run()
    taus = []
    for r in sorted(responses, key=lambda r: r.uid):
        taus.append(r.tau)
        print(f"  req {r.uid:2d}: {len(r.tokens):3d} tokens  "
              f"tau={r.tau:4.2f}  latency={r.latency_s:5.2f}s")
    print(f"\nmean tau = {np.mean(taus):.2f} "
          f"(tokens committed per verify cycle; >1 == speculative win)")


if __name__ == "__main__":
    main()
