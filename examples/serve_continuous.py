"""End-to-end serving driver: continuous-batching MARS server.

Trains the tiny pair (cached), then serves a stream of batched requests
through the slot scheduler with speculative decoding + MARS verification,
printing per-request τ and latency — the paper's serving scenario at CPU
scale.

The server is a thin wrapper over the shared ``DecodeSession`` engine core,
so the same scheduler serves chain drafts (independent small-LM drafter)
AND tree drafts (EAGLE-style head + caterpillar tree) — the second pass
below flips ``EngineConfig(topology="tree")`` and nothing else.

    PYTHONPATH=src python examples/serve_continuous.py
"""
import numpy as np

from benchmarks import common as C
from repro.core import EagleDrafter, EngineConfig, IndependentDrafter
from repro.serving import Request, SamplingParams, ServerConfig, SpecServer


def serve(server, n_req=12, max_tokens=48, label=""):
    cor = C.corpus()
    for i in range(n_req):
        prompt = cor.sample_batch(1, 24, seed=100 + i)[0]
        server.submit(Request(uid=i, prompt=prompt,
                              params=SamplingParams(max_tokens=max_tokens)))
    print(f"serving {n_req} {label} requests on {server.cfg.slots} slots ...")
    responses = server.run()
    taus = []
    for r in sorted(responses, key=lambda r: r.uid):
        taus.append(r.tau)
        print(f"  req {r.uid:2d}: {len(r.tokens):3d} tokens  "
              f"tau={r.tau:4.2f}  latency={r.latency_s:5.2f}s")
    print(f"mean tau = {np.mean(taus):.2f} "
          f"(tokens committed per verify cycle; >1 == speculative win)\n")


def main():
    target, t_params, draft, d_params = C.get_pair()

    # chain topology: independent small-LM drafter, sampling verification
    serve(SpecServer(
        target, IndependentDrafter(draft, k=4, temperature=1.0),
        t_params, d_params,
        EngineConfig(k=4, rule="mars", mode="sample", temperature=1.0,
                     guard="margin"),
        ServerConfig(slots=4, max_len=256, max_prompt_len=32)),
        label="chain")

    # tree topology: EAGLE-style head, caterpillar tree, greedy + MARS —
    # same scheduler, same session core, different draft topology
    e_params = C.train_eagle_head(target, t_params)
    serve(SpecServer(
        target, EagleDrafter(target, k=3, temperature=0.0),
        t_params, e_params,
        EngineConfig(k=3, rule="mars", mode="greedy", temperature=0.0,
                     guard="margin", topology="tree", branch=2),
        ServerConfig(slots=4, max_len=256, max_prompt_len=32)),
        label="tree")


if __name__ == "__main__":
    main()
