"""Serving observability: lifecycle traces, metrics, and tick spans.

Three pillars, all fed exclusively from device→host transfers the server
already pays for (the sync poll and the finished-row gather) — telemetry
never adds a transfer, so the sync-free tick contract is untouched:

* :mod:`repro.obs.trace`    — per-request lifecycle records
  (:class:`RequestTrace`) with monotonic host timestamps for
  submit → staged → admitted → first commit → finish/cancel, plus the
  device stats harvested at finish; honest TTFT / inter-token latency.
* :mod:`repro.obs.registry` — a dependency-free metrics registry
  (counters, gauges, windowed histograms; pure numpy) with a Prometheus
  text-exposition writer in :mod:`repro.obs.export`.
* :mod:`repro.obs.spans`    — tick-phase spans (admit / dispatch /
  harvest / retune / gather) exported as Chrome trace-event JSON,
  loadable in Perfetto, including the overlap pipeline's in-flight
  snapshot depth as a counter track.

:class:`ServerTelemetry` bundles all three behind the hook interface
``SpecServer`` calls; see docs/OBSERVABILITY.md.
"""
from repro.obs.export import (chrome_trace_json, prometheus_text,
                              write_chrome_trace, write_events_jsonl,
                              write_prometheus)
from repro.obs.registry import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.spans import SpanRecorder
from repro.obs.telemetry import ServerTelemetry
from repro.obs.trace import RequestTrace, RequestTracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "RequestTrace", "RequestTracer", "SpanRecorder", "ServerTelemetry",
    "prometheus_text", "write_prometheus", "chrome_trace_json",
    "write_chrome_trace", "write_events_jsonl",
]
