"""Writers: Prometheus text exposition, Chrome trace JSON, JSONL events.

All writers are pure functions of the in-memory objects; file variants
create parent directories and write atomically enough for CI consumption
(single write, then close).
"""
from __future__ import annotations

import json
import math
import os
from typing import Iterable

from repro.obs.registry import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.spans import SpanRecorder


def _fmt(v: float) -> str:
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def prometheus_text(registry: MetricsRegistry) -> str:
    """Render the registry in Prometheus text exposition format 0.0.4."""
    lines = []
    for m in registry.metrics():
        if m.help:
            lines.append(f"# HELP {m.name} {m.help}")
        lines.append(f"# TYPE {m.name} {m.kind}")
        if isinstance(m, (Counter, Gauge)):
            lines.append(f"{m.name} {_fmt(m.value)}")
        elif isinstance(m, Histogram):
            for bound, cum in zip(list(m.bounds) + [math.inf],
                                  m.bucket_counts.tolist()):
                lines.append(f'{m.name}_bucket{{le="{_fmt(bound)}"}} {cum}')
            lines.append(f"{m.name}_sum {_fmt(m.sum)}")
            lines.append(f"{m.name}_count {m.count}")
        else:  # pragma: no cover - registry only creates the three kinds
            raise TypeError(f"unknown metric kind: {type(m).__name__}")
    return "\n".join(lines) + "\n"


def _ensure_parent(path: str) -> None:
    parent = os.path.dirname(os.path.abspath(path))
    if parent:
        os.makedirs(parent, exist_ok=True)


def write_prometheus(registry: MetricsRegistry, path: str) -> None:
    _ensure_parent(path)
    with open(path, "w") as f:
        f.write(prometheus_text(registry))


def chrome_trace_json(spans: SpanRecorder) -> str:
    return json.dumps(spans.chrome_trace(), indent=None, separators=(",", ":"))


def write_chrome_trace(spans: SpanRecorder, path: str) -> None:
    _ensure_parent(path)
    with open(path, "w") as f:
        f.write(chrome_trace_json(spans))


def write_events_jsonl(events: Iterable[dict], path: str) -> None:
    _ensure_parent(path)
    with open(path, "w") as f:
        for ev in events:
            f.write(json.dumps(ev, separators=(",", ":")) + "\n")


def read_events_jsonl(path: str):
    """Round-trip helper (used by tests and tools/check_trace.py)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
