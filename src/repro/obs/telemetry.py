"""ServerTelemetry: the bundle SpecServer talks to.

One object wires the three pillars together — a :class:`RequestTracer`
(lifecycle), a :class:`MetricsRegistry` (Prometheus-exportable
instruments), and a :class:`SpanRecorder` (tick spans) — behind the
narrow hook interface the scheduler calls. Every hook consumes only
host-resident values (python ints/floats/numpy rows the sync poll
already transferred); none triggers a device→host transfer, so passing
``telemetry=`` to ``SpecServer`` cannot violate the sync-free tick
contract.

All hooks are cheap dict/list appends; the scheduler guards each call
site with ``if self.obs is not None`` so ``telemetry=None`` stays
zero-cost.
"""
from __future__ import annotations

import time
from contextlib import nullcontext
from typing import Callable, List, Optional, Sequence, Tuple

from repro.obs.export import (write_chrome_trace, write_events_jsonl,
                              write_prometheus)
from repro.obs.registry import MetricsRegistry
from repro.obs.spans import SpanRecorder
from repro.obs.trace import RequestTrace, RequestTracer

# Theta lives in [0, 1]; latency buckets make no sense for it.
_THETA_BUCKETS = tuple(x / 20 for x in range(1, 21))
_TOKEN_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024)


class ServerTelemetry:
    """Lifecycle + metrics + spans for one server, on one shared clock."""

    def __init__(self, *, namespace: str = "mars",
                 clock: Callable[[], float] = time.perf_counter,
                 max_span_events: int = 200_000, annotate: bool = True) -> None:
        self.clock = clock
        self.tracer = RequestTracer(clock=clock)
        self.registry = MetricsRegistry(namespace=namespace)
        self.spans = SpanRecorder(clock=clock, max_events=max_span_events,
                                  annotate=annotate)
        r = self.registry
        self.submitted = r.counter("requests_submitted_total", "Requests submitted")
        self.admitted = r.counter("requests_admitted_total", "Requests seated in a slot")
        self.finished = r.counter("requests_finished_total", "Requests finished")
        self.canceled = r.counter("requests_canceled_total", "Requests canceled while queued")
        self.ring_staged = r.counter("requests_ring_staged_total", "Requests staged in the admission ring")
        self.tokens = r.counter("tokens_committed_total", "Tokens committed across finished requests")
        self.accepts = r.counter("draft_accepts_total", "Draft tokens accepted (strict + relaxed)")
        self.relaxed = r.counter("relaxed_accepts_total", "Draft tokens accepted via theta relaxation")
        self.cycles = r.counter("verify_cycles_total", "Verify cycles across finished requests")
        self.retunes = r.counter("theta_retunes_total", "Controller theta retune dispatches")
        self.syncs = r.counter("sync_polls_total", "Harvest polls applied")
        self.queue_depth = r.gauge("queue_depth", "Requests waiting in the host queue")
        self.slots_active = r.gauge("slots_active", "Slots currently decoding")
        self.inflight = r.gauge("inflight_snapshots", "Overlap pipeline snapshots in flight")
        self.margin_mean = r.gauge("margin_ema_mean", "Mean margin EMA over live slots at last poll")
        self.ttft = r.histogram("ttft_seconds", "Time to first committed token (host-observed)")
        self.itl = r.histogram("itl_seconds", "Mean inter-token latency after first commit")
        self.latency = r.histogram("request_latency_seconds", "Submit-to-finish latency")
        self.req_tokens = r.histogram("request_tokens", "Committed tokens per finished request",
                                      buckets=_TOKEN_BUCKETS)
        self.theta = r.histogram("theta_applied", "Theta values applied (admission + retunes)",
                                 buckets=_THETA_BUCKETS)

    # -- spans -------------------------------------------------------------

    def span(self, name: str, **args):
        return self.spans.span(name, **args)

    # -- lifecycle hooks (called by SpecServer) ----------------------------

    def on_submit(self, uid: int, prompt_len: int, max_tokens: int) -> None:
        self.tracer.on_submit(uid, prompt_len, max_tokens)
        self.submitted.inc()

    def on_cancel(self, uid: int) -> None:
        self.tracer.on_cancel(uid)
        self.canceled.inc()

    def on_staged(self, uid: int, shard: Optional[int] = None) -> None:
        self.tracer.on_staged(uid, shard=shard)
        self.ring_staged.inc()

    def on_admitted(self, uid: int, slot: int, *, theta: float,
                    prefix_hit_tokens: int = 0, blocks_held: int = 0,
                    via_ring: bool = False) -> None:
        self.tracer.on_admitted(uid, slot, theta=theta,
                                prefix_hit_tokens=prefix_hit_tokens,
                                blocks_held=blocks_held, via_ring=via_ring)
        self.admitted.inc()
        self.theta.observe(theta)

    def on_prefill_handoff(self, uid: int, tokens: int) -> None:
        self.tracer.on_prefill_handoff(uid, tokens)

    def on_first_commit(self, uid: int, tokens: int) -> None:
        self.tracer.on_first_commit(uid, tokens)

    def on_retune(self, pairs: Sequence[Tuple[int, float]]) -> None:
        for uid, theta in pairs:
            self.tracer.on_retune(uid, theta)
            self.theta.observe(theta)
        self.retunes.inc()

    def on_finish(self, uid: int, *, n_tokens: int, n_cycles: int,
                  n_accepted: int, n_relaxed: int, margin_ema: float,
                  theta: float, blocks_held: int) -> None:
        self.tracer.on_finish(uid, n_tokens=n_tokens, n_cycles=n_cycles,
                              n_accepted=n_accepted, n_relaxed=n_relaxed,
                              margin_ema=margin_ema, theta=theta,
                              blocks_held=blocks_held)
        self.finished.inc()
        self.tokens.inc(n_tokens)
        self.accepts.inc(n_accepted)
        self.relaxed.inc(n_relaxed)
        self.cycles.inc(n_cycles)
        self.req_tokens.observe(n_tokens)
        tr = self.tracer.traces[uid]
        if tr.ttft_s is not None:
            self.ttft.observe(tr.ttft_s)
        if tr.itl_s is not None:
            self.itl.observe(tr.itl_s)
        if tr.latency_s is not None:
            self.latency.observe(tr.latency_s)

    def on_sync(self, *, queue_depth: int, slots_active: int,
                inflight: int, margin_mean: Optional[float] = None) -> None:
        self.syncs.inc()
        self.queue_depth.set(queue_depth)
        self.slots_active.set(slots_active)
        self.inflight.set(inflight)
        if margin_mean is not None:
            self.margin_mean.set(margin_mean)

    def on_inflight(self, depth: int) -> None:
        """Overlap pipeline depth — both a gauge and a Perfetto counter track."""
        self.inflight.set(depth)
        self.spans.counter("inflight_snapshots", depth)

    # -- views / export ----------------------------------------------------

    def finished_traces(self) -> List[RequestTrace]:
        return self.tracer.finished()

    def write(self, metrics_out: Optional[str] = None,
              trace_out: Optional[str] = None,
              events_out: Optional[str] = None) -> None:
        if metrics_out:
            write_prometheus(self.registry, metrics_out)
        if trace_out:
            write_chrome_trace(self.spans, trace_out)
        if events_out:
            write_events_jsonl(self.tracer.events, events_out)

    def summary(self) -> dict:
        """Small human-facing rollup (printed by launchers)."""
        return {
            "finished": int(self.finished.value),
            "tokens": int(self.tokens.value),
            "ttft_p50_s": self.ttft.percentile(50),
            "ttft_p99_s": self.ttft.percentile(99),
            "itl_p50_s": self.itl.percentile(50),
            "latency_p50_s": self.latency.percentile(50),
            "theta_retunes": int(self.retunes.value),
            "span_events": len(self.spans.events),
        }


def null_span(*_a, **_k):
    """Module-level no-op context for telemetry-off paths."""
    return nullcontext()
