"""Per-request lifecycle traces.

One :class:`RequestTrace` per uid records the host-observed lifecycle
(submit → ring-staged → admitted → prefill handoff → first commit →
finish/cancel) on a monotonic clock, plus the device stats the harvest
poll already carries (cycles, accepts, relaxed, margin EMA, theta
trajectory, blocks held, prefix hits).

Timestamps are *host observation* times: the device may commit a token
mid-group, but the host can only see it at the next ``sync()`` poll, so
first-commit (and therefore TTFT) is quantized to sync granularity —
honest for a serving system, since that is exactly when a streaming API
could first emit the token. Under ``overlap`` the poll is additionally
one dispatch group late by construction.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core import metrics as _metrics


@dataclass
class RequestTrace:
    """Lifecycle record for one request uid. All times are seconds on the
    tracer's monotonic clock (``t0`` = tracer construction)."""

    uid: int
    prompt_len: int = 0
    max_tokens: int = 0
    submit_s: Optional[float] = None
    staged_s: Optional[float] = None          # pushed into the AdmissionRing
    admitted_s: Optional[float] = None        # seated in a slot (host or device side)
    prefill_handoff_s: Optional[float] = None  # routed through PrefillWorker
    first_commit_s: Optional[float] = None    # first poll showing committed tokens
    finish_s: Optional[float] = None
    cancel_s: Optional[float] = None
    slot: Optional[int] = None
    shard: Optional[int] = None
    staged_via_ring: bool = False
    prefix_hit_tokens: int = 0
    blocks_held: int = 0
    tokens_at_first_commit: int = 0
    # Device stats harvested at finish.
    n_tokens: int = 0
    n_cycles: int = 0
    n_accepted: int = 0
    n_relaxed: int = 0
    margin_ema: float = 0.0
    # (time_s, theta) — admission theta plus every controller retune.
    theta_path: List[Tuple[float, float]] = field(default_factory=list)

    @property
    def ttft_s(self) -> Optional[float]:
        return _metrics.ttft(self.submit_s, self.first_commit_s)

    @property
    def itl_s(self) -> Optional[float]:
        return _metrics.itl(self.first_commit_s, self.finish_s,
                            self.n_tokens - self.tokens_at_first_commit)

    @property
    def latency_s(self) -> Optional[float]:
        if self.submit_s is None or self.finish_s is None:
            return None
        return self.finish_s - self.submit_s

    @property
    def done(self) -> bool:
        return self.finish_s is not None or self.cancel_s is not None


class RequestTracer:
    """Owns the trace table and the structured event log.

    Every lifecycle transition appends one JSON-able event dict (kind
    ``event``: submit/staged/admitted/prefill_handoff/first_commit/
    retune/finish/cancel) to :attr:`events`; finished traces stay in
    :attr:`traces` for end-of-run reporting.
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter) -> None:
        self._clock = clock
        self.t0 = clock()
        self.wall_t0 = time.time()
        self.traces: Dict[int, RequestTrace] = {}
        self.events: List[dict] = []

    def now(self) -> float:
        return self._clock() - self.t0

    def _event(self, kind: str, uid: int, t: float, **extra) -> None:
        ev = {"event": kind, "uid": uid, "t_s": round(t, 9),
              "wall_s": round(self.wall_t0 + t, 6)}
        ev.update(extra)
        self.events.append(ev)

    def _get(self, uid: int) -> RequestTrace:
        tr = self.traces.get(uid)
        if tr is None:
            tr = RequestTrace(uid=uid)
            self.traces[uid] = tr
        return tr

    # -- lifecycle hooks ---------------------------------------------------

    def on_submit(self, uid: int, prompt_len: int, max_tokens: int) -> None:
        t = self.now()
        tr = self._get(uid)
        tr.submit_s = t
        tr.prompt_len = prompt_len
        tr.max_tokens = max_tokens
        self._event("submit", uid, t, prompt_len=prompt_len, max_tokens=max_tokens)

    def on_staged(self, uid: int, shard: Optional[int] = None) -> None:
        t = self.now()
        tr = self._get(uid)
        tr.staged_s = t
        tr.staged_via_ring = True
        if shard is not None:
            tr.shard = shard
        self._event("staged", uid, t, shard=shard)

    def on_admitted(self, uid: int, slot: int, *, theta: float,
                    prefix_hit_tokens: int = 0, blocks_held: int = 0,
                    via_ring: bool = False) -> None:
        t = self.now()
        tr = self._get(uid)
        tr.admitted_s = t
        tr.slot = slot
        tr.prefix_hit_tokens = prefix_hit_tokens
        tr.blocks_held = blocks_held
        tr.staged_via_ring = tr.staged_via_ring or via_ring
        tr.theta_path.append((t, float(theta)))
        self._event("admitted", uid, t, slot=slot, theta=float(theta),
                    prefix_hit_tokens=prefix_hit_tokens,
                    blocks_held=blocks_held, via_ring=via_ring)

    def on_prefill_handoff(self, uid: int, tokens: int) -> None:
        t = self.now()
        self._get(uid).prefill_handoff_s = t
        self._event("prefill_handoff", uid, t, tokens=tokens)

    def on_first_commit(self, uid: int, tokens: int) -> None:
        """First sync poll whose lengths show committed tokens for this uid.
        Idempotent — later polls do not move the timestamp."""
        tr = self._get(uid)
        if tr.first_commit_s is not None:
            return
        t = self.now()
        tr.first_commit_s = t
        tr.tokens_at_first_commit = tokens
        self._event("first_commit", uid, t, tokens=tokens)

    def on_retune(self, uid: int, theta: float) -> None:
        t = self.now()
        self._get(uid).theta_path.append((t, float(theta)))
        self._event("retune", uid, t, theta=float(theta))

    def on_finish(self, uid: int, *, n_tokens: int, n_cycles: int,
                  n_accepted: int, n_relaxed: int, margin_ema: float,
                  theta: float, blocks_held: int) -> None:
        t = self.now()
        tr = self._get(uid)
        tr.finish_s = t
        tr.n_tokens = n_tokens
        tr.n_cycles = n_cycles
        tr.n_accepted = n_accepted
        tr.n_relaxed = n_relaxed
        tr.margin_ema = float(margin_ema)
        tr.blocks_held = blocks_held
        # A request that finished within its very first harvested group has
        # its first commit observed in the same poll as its finish; if even
        # that was missed (device-side admission + in-group finish), pin
        # first-commit to finish so TTFT degrades to full latency — an
        # honest upper bound — rather than going unreported.
        if tr.first_commit_s is None:
            tr.first_commit_s = t
            tr.tokens_at_first_commit = n_tokens
        self._event("finish", uid, t, n_tokens=n_tokens, n_cycles=n_cycles,
                    n_accepted=n_accepted, n_relaxed=n_relaxed,
                    margin_ema=round(float(margin_ema), 6),
                    theta=float(theta), blocks_held=blocks_held,
                    ttft_s=tr.ttft_s, itl_s=tr.itl_s, latency_s=tr.latency_s)

    def on_cancel(self, uid: int) -> None:
        t = self.now()
        self._get(uid).cancel_s = t
        self._event("cancel", uid, t)

    # -- views -------------------------------------------------------------

    def finished(self) -> List[RequestTrace]:
        return [tr for tr in self.traces.values() if tr.finish_s is not None]
