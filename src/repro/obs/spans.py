"""Tick-phase spans as Chrome trace events, loadable in Perfetto.

:class:`SpanRecorder` generalizes the benchmark-only ``--profile-phases``
fenced timings into an always-available recorder: the scheduler wraps
its tick phases (admit / dispatch / harvest / retune / gather) in
:meth:`span`, and the overlap pipeline publishes its in-flight snapshot
depth through :meth:`counter` so double-buffer occupancy is a visible
counter track.

Spans measure *host wall time around the call* — for async dispatch that
is enqueue cost, not device compute (the benchmark's fenced mode remains
the ground truth for device phase split). Each span also opens a
``jax.profiler.TraceAnnotation`` when available, so the same names show
up inside a full XLA profiler trace.

Export format is the Chrome trace-event JSON array flavor
(``{"traceEvents": [...]}``): ``ph: "X"`` complete events with
microsecond ``ts``/``dur``, ``ph: "C"`` counter events.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Callable, List, Optional

try:  # TraceAnnotation is optional — numpy-only consumers never import jax.
    from jax.profiler import TraceAnnotation as _TraceAnnotation
except Exception:  # pragma: no cover - jax always present in this repo
    _TraceAnnotation = None


class SpanRecorder:
    """Bounded in-memory recorder for Chrome trace events.

    ``max_events`` caps memory for long serves; overflow drops newest
    events and is reported in :attr:`dropped` and the export metadata —
    never silently.
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter,
                 *, process_name: str = "mars-server",
                 max_events: int = 200_000, annotate: bool = True) -> None:
        self._clock = clock
        self.t0 = clock()
        self.process_name = process_name
        self.max_events = max_events
        self.events: List[dict] = []
        self.dropped = 0
        self._annotate = annotate and _TraceAnnotation is not None

    def _now_us(self) -> float:
        return (self._clock() - self.t0) * 1e6

    def _push(self, ev: dict) -> None:
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return
        self.events.append(ev)

    @contextmanager
    def span(self, name: str, tid: int = 0, **args):
        """Record a complete ("X") event around the enclosed block."""
        ann = _TraceAnnotation(name) if self._annotate else None
        if ann is not None:
            ann.__enter__()
        t0 = self._now_us()
        try:
            yield
        finally:
            t1 = self._now_us()
            if ann is not None:
                ann.__exit__(None, None, None)
            ev = {"name": name, "ph": "X", "ts": t0, "dur": t1 - t0,
                  "pid": 1, "tid": tid}
            if args:
                ev["args"] = args
            self._push(ev)

    def counter(self, name: str, value: float) -> None:
        """Record a counter ("C") sample, rendered as a track in Perfetto."""
        self._push({"name": name, "ph": "C", "ts": self._now_us(),
                    "pid": 1, "tid": 0, "args": {name: value}})

    def instant(self, name: str, **args) -> None:
        ev = {"name": name, "ph": "i", "ts": self._now_us(),
              "pid": 1, "tid": 0, "s": "t"}
        if args:
            ev["args"] = args
        self._push(ev)

    def span_names(self) -> List[str]:
        return sorted({e["name"] for e in self.events if e.get("ph") == "X"})

    def chrome_trace(self) -> dict:
        """Full trace object: events plus process-name metadata."""
        meta = [{"name": "process_name", "ph": "M", "pid": 1, "tid": 0,
                 "args": {"name": self.process_name}}]
        out = {"traceEvents": meta + list(self.events),
               "displayTimeUnit": "ms"}
        if self.dropped:
            out["metadata"] = {"dropped_events": self.dropped}
        return out
