"""Dependency-free metrics registry: counters, gauges, windowed histograms.

Pure numpy + stdlib — no prometheus_client, no OpenTelemetry. Instruments
are created through :class:`MetricsRegistry` (get-or-create, insertion
order preserved) and rendered to Prometheus text exposition by
:func:`repro.obs.export.prometheus_text`.

Histograms keep two views of the same observations: cumulative
fixed-bucket counts (what Prometheus expects) and a bounded ring of the
most recent raw values so the host can report windowed quantiles
(p50/p99 TTFT) without a time-series database.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# Latency-flavored default buckets (seconds): 1 ms .. 60 s, roughly 2.5x apart.
DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
    0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0,
)


class Counter:
    """Monotonically non-decreasing float counter."""

    kind = "counter"

    def __init__(self, name: str, help: str = "") -> None:
        self.name = name
        self.help = help
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative increment {amount}")
        self.value += amount


class Gauge:
    """Last-write-wins instantaneous value."""

    kind = "gauge"

    def __init__(self, name: str, help: str = "") -> None:
        self.name = name
        self.help = help
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount


class Histogram:
    """Fixed-bucket cumulative histogram plus a recent-values window.

    ``bucket_counts[i]`` counts observations ``<= bounds[i]`` (cumulative,
    Prometheus ``le`` semantics, with an implicit ``+Inf`` final bucket).
    ``window`` bounds the raw-value ring used for :meth:`percentile`.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = DEFAULT_BUCKETS,
                 window: int = 2048) -> None:
        self.name = name
        self.help = help
        self.bounds: Tuple[float, ...] = tuple(sorted(float(b) for b in buckets))
        if not self.bounds:
            raise ValueError(f"histogram {self.name}: needs at least one bucket")
        self.bucket_counts = np.zeros(len(self.bounds) + 1, dtype=np.int64)
        self.sum = 0.0
        self.count = 0
        self._ring = np.zeros(max(int(window), 1), dtype=np.float64)
        self._ring_n = 0  # total observations ever pushed into the ring

    def observe(self, value: float) -> None:
        v = float(value)
        if math.isnan(v):
            return
        idx = int(np.searchsorted(self.bounds, v, side="left"))
        self.bucket_counts[idx:] += 1
        self.sum += v
        self.count += 1
        self._ring[self._ring_n % self._ring.size] = v
        self._ring_n += 1

    def window_values(self) -> np.ndarray:
        n = min(self._ring_n, self._ring.size)
        return self._ring[:n].copy()

    def percentile(self, q: float) -> Optional[float]:
        """Windowed percentile (q in [0, 100]); None with no observations."""
        vals = self.window_values()
        if vals.size == 0:
            return None
        return float(np.percentile(vals, q))


class MetricsRegistry:
    """Insertion-ordered instrument store with get-or-create semantics.

    ``namespace`` is prefixed onto every instrument name at creation
    (``mars_`` by default), so export needs no further name mangling.
    Thread-safe creation; instrument mutation is single-writer by design
    (the scheduler's host thread).
    """

    def __init__(self, namespace: str = "mars") -> None:
        self.namespace = namespace
        self._metrics: Dict[str, object] = {}
        self._lock = threading.Lock()

    def _full(self, name: str) -> str:
        return f"{self.namespace}_{name}" if self.namespace else name

    def _get_or_create(self, cls, name: str, help: str, **kw):
        full = self._full(name)
        with self._lock:
            m = self._metrics.get(full)
            if m is None:
                m = cls(full, help, **kw)
                self._metrics[full] = m
            elif not isinstance(m, cls):
                raise TypeError(f"metric {full} already registered as {type(m).__name__}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS,
                  window: int = 2048) -> Histogram:
        return self._get_or_create(Histogram, name, help,
                                   buckets=buckets, window=window)

    def metrics(self) -> List[object]:
        with self._lock:
            return list(self._metrics.values())

    def get(self, full_name: str) -> Optional[object]:
        return self._metrics.get(full_name)
