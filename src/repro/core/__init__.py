"""The paper's primary contribution: speculative decoding with Margin-Aware
Speculative Verification (MARS), plus the drafters and engine around it."""
from repro.core.verify import (
    DEFAULT_THETA,
    VerifyBackend,
    VerifyResult,
    mars_relax_mask,
    resolve_backend,
    top2_and_ratio,
    verify_chain,
)
from repro.core.session import (
    CycleOutcome,
    DecodeSession,
    DecodeState,
    EngineConfig,
)
from repro.core.engine import (
    SpecEngine,
    make_ar_generate_fn,
    make_generate_fn,
)
from repro.core.drafter import (
    Committed,
    DraftOutput,
    EagleDrafter,
    IndependentDrafter,
    MedusaDrafter,
    PLDrafter,
    init_eagle_params,
    init_medusa_params,
)
from repro.core.tree import (
    TreeEngineConfig,
    TreeSpecEngine,
    TreeTopology,
    make_caterpillar,
    make_tree_generate_fn,
    verify_tree,
)
from repro.core import metrics

__all__ = [
    "DEFAULT_THETA", "VerifyBackend", "VerifyResult", "mars_relax_mask",
    "resolve_backend", "top2_and_ratio", "verify_chain", "CycleOutcome",
    "DecodeSession", "DecodeState", "EngineConfig", "SpecEngine",
    "make_ar_generate_fn", "make_generate_fn", "Committed", "DraftOutput",
    "EagleDrafter", "IndependentDrafter", "MedusaDrafter", "PLDrafter",
    "init_eagle_params", "init_medusa_params", "metrics", "TreeEngineConfig",
    "TreeSpecEngine", "TreeTopology", "make_caterpillar",
    "make_tree_generate_fn", "verify_tree",
]
