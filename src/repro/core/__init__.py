"""The paper's primary contribution: speculative decoding with Margin-Aware
Speculative Verification (MARS), plus the drafters and engine around it."""
from repro.core.verify import (
    DEFAULT_THETA,
    VerifyResult,
    mars_relax_mask,
    top2_and_ratio,
    verify_chain,
)
from repro.core.engine import (
    EngineConfig,
    SpecEngine,
    make_ar_generate_fn,
    make_generate_fn,
)
from repro.core.drafter import (
    Committed,
    DraftOutput,
    EagleDrafter,
    IndependentDrafter,
    MedusaDrafter,
    PLDrafter,
    init_eagle_params,
    init_medusa_params,
)
from repro.core.tree import (
    TreeEngineConfig,
    TreeSpecEngine,
    make_caterpillar,
    make_tree_generate_fn,
    verify_tree,
)
from repro.core import metrics

__all__ = [
    "DEFAULT_THETA", "VerifyResult", "mars_relax_mask", "top2_and_ratio",
    "verify_chain", "EngineConfig", "SpecEngine", "make_ar_generate_fn",
    "make_generate_fn", "Committed", "DraftOutput", "EagleDrafter",
    "IndependentDrafter", "MedusaDrafter", "PLDrafter", "init_eagle_params",
    "init_medusa_params", "metrics", "TreeEngineConfig",
    "TreeSpecEngine", "make_caterpillar", "make_tree_generate_fn",
    "verify_tree",
]
