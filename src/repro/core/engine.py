"""Chain speculative-decoding engine — a thin wrapper over the shared
:class:`repro.core.session.DecodeSession` engine core.

All draft → parallel-verify → commit mechanics live in ``core/session.py``:
the :class:`~repro.core.session.DecodeState` carry, the jit-traceable
``cycle``, EOS/buffer-commit bookkeeping, and cache rollback.  This module
keeps the historical ``SpecEngine`` / ``make_generate_fn`` entry points (now
topology-aware: ``EngineConfig(topology="tree")`` drafts caterpillar trees
through the very same session) plus the vanilla autoregressive baseline.

Shared ``DecodeSession`` contract (see ``core/session.py`` for details):

* cache-layout invariant — ``cache.index`` counts cached tokens; the
  pending last committed token is not yet cached and opens the next cycle
  (true for the dense ring and the paged block-table layout alike);
* rollback scheme — attention caches rewind their index (under paging the
  slot keeps its admission-reserved blocks mid-flight; the host frees the
  list at harvest), recurrent caches recompute the committed prefix from
  the pre-cycle state under a token mask;
* topology hook — chain vs tree drafts differ only in the strategy object
  that proposes, scores, and verifies candidates each cycle.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.session import (  # noqa: F401  (re-exported API)
    DecodeSession,
    DecodeState,
    EngineConfig,
    make_generate_fn,
)
from repro.models.model import Model


class SpecEngine:
    """Historical chain-engine facade; delegates to :class:`DecodeSession`."""

    def __init__(self, target: Model, drafter, cfg: EngineConfig):
        self.session = DecodeSession(target, drafter, cfg)
        self.target = target
        self.drafter = drafter
        self.cfg = cfg

    def cycle(self, t_params, d_params, carry, theta=None) -> DecodeState:
        return self.session.cycle(t_params, d_params, carry, theta=theta)

    def generate(self, t_params, d_params, prompt, prompt_len, max_new, key,
                 theta=None, encoder_frames=None):
        return self.session.generate(t_params, d_params, prompt, prompt_len,
                                     max_new, key, theta=theta,
                                     encoder_frames=encoder_frames)


# ---------------------------------------------------------------------------
# Vanilla autoregressive baseline (speedup denominator)
# ---------------------------------------------------------------------------

def make_ar_generate_fn(target: Model, *, temperature: float = 1.0,
                        eos_token: Optional[int] = None):
    @functools.partial(jax.jit, static_argnames=("max_new",))
    def generate(t_params, prompt, prompt_len, key, max_new=64):
        b, s = prompt.shape
        l_buf = s + max_new + 1
        buf = jnp.zeros((b, l_buf + 1), jnp.int32).at[:, :s].set(prompt)
        cache = target.init_cache(t_params, b, l_buf)
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        pmask = pos < (prompt_len - 1)[:, None]
        _, cache = target.decode(t_params, prompt, pos, cache, token_mask=pmask)
        last = jnp.take_along_axis(
            prompt, jnp.clip(prompt_len - 1, 0, s - 1)[:, None], 1)[:, 0]
        lengths = prompt_len
        finished = jnp.zeros((b,), bool)

        def body(i, st):
            buf, lengths, finished, cache, last, key = st
            key, k_s = jax.random.split(key)
            active = ~finished
            pos = cache["index"][:, None]
            logits, cache = target.decode(
                t_params, last[:, None], pos, cache,
                token_mask=active[:, None])
            logits = logits[:, -1].astype(jnp.float32)
            if temperature <= 0.0:
                nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            else:
                nxt = jax.random.categorical(
                    k_s, logits / temperature, -1).astype(jnp.int32)
            wslot = jnp.where(active & (lengths < l_buf), lengths, l_buf)
            buf = buf.at[jnp.arange(b), wslot].set(nxt)
            lengths = lengths + active.astype(jnp.int32)
            if eos_token is not None:
                finished = finished | (active & (nxt == eos_token))
            finished = finished | (lengths >= l_buf)
            last = jnp.where(active, nxt, last)
            return (buf, lengths, finished, cache, last, key)

        buf, lengths, finished, _, _, _ = jax.lax.fori_loop(
            0, max_new, body, (buf, lengths, finished, cache, last, key))
        return {"tokens": buf[:, :-1], "lengths": lengths,
                "finished": finished}

    return generate
