"""Speculative-decoding engine: the draft → parallel-verify → commit loop.

The whole generation loop is one ``jax.lax.while_loop`` so it jits end to
end.  Per cycle:

  1. the drafter proposes K tokens continuing from the pending last token;
  2. the target model scores ``[last_token, d_1..d_K]`` in ONE decode pass
     (K+1 positions — this is the memory-bound pass MARS amortises);
  3. the verify rule (strict / MARS, greedy / sampling) accepts a prefix and
     emits a correction-or-bonus token, i.e. ``n_accept + 1`` committed;
  4. caches roll back: attention caches by index rewind; recurrent families
     (ssm / hybrid) re-apply the committed prefix from the pre-cycle state
     with a token mask (state checkpoint + recompute — the standard scheme
     for SSM speculative decoding);
  5. the drafter syncs (index rewind + feature re-grounding).

Cache-layout invariant: ``cache.index`` counts tokens whose kv/state is
stored; the *pending* last committed token is not yet in the cache and is
the first input of the next cycle.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import verify as V
from repro.core.drafter import Committed, DraftOutput
from repro.models.model import Model


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    k: int = 7                       # draft length (paper default)
    rule: str = "mars"               # "strict" | "mars"
    mode: str = "sample"             # "greedy" | "sample"
    theta: float = V.DEFAULT_THETA
    temperature: float = 1.0
    eos_token: Optional[int] = None
    use_kernel: bool = False         # fused Pallas mars_verify
    guard: str = "positive"          # "positive" (paper) | "margin" (ext.)


class SpecEngine:
    def __init__(self, target: Model, drafter, cfg: EngineConfig):
        self.target = target
        self.drafter = drafter
        self.cfg = cfg

    # -- one verify cycle (jit-traceable) ------------------------------------
    def cycle(self, t_params, d_params, carry, theta=None):
        cfg = self.cfg
        k = cfg.k
        theta = cfg.theta if theta is None else theta
        (buf, lengths, finished, t_cache, d_state, last_token, key,
         stats) = carry
        b = last_token.shape[0]
        key, k_draft, k_verify = jax.random.split(key, 3)
        active = ~finished

        extras = {
            "target_params": t_params,
            "tokens_buf": buf,
            "lengths": lengths,
            "index": t_cache["index"],
        }

        # 1. draft
        d_out, d_state = self.drafter.draft(
            d_params, d_state, last_token, extras, k_draft)

        # 2. target parallel pass over [last_token, d_1..d_K]
        base_index = t_cache["index"]
        inputs = jnp.concatenate([last_token[:, None], d_out.tokens], axis=1)
        positions = base_index[:, None] + jnp.arange(k + 1, dtype=jnp.int32)[None]
        mask = jnp.broadcast_to(active[:, None], (b, k + 1))
        if self.target.is_recurrent:
            pre_cache = t_cache
            res_t = self.target.decode(
                t_params, inputs, positions, t_cache, token_mask=mask,
                with_features=self.drafter.wants_features)
        else:
            res_t = self.target.decode(
                t_params, inputs, positions, t_cache, token_mask=mask,
                with_features=self.drafter.wants_features)
        if self.drafter.wants_features:
            logits, t_cache, feats = res_t
        else:
            logits, t_cache = res_t
            feats = None

        # 3. verify
        res = V.verify_chain(
            d_out.tokens, logits, rule=cfg.rule, mode=cfg.mode,
            theta=theta, temperature=cfg.temperature, key=k_verify,
            draft_token_probs=d_out.token_probs,
            draft_full_probs=d_out.full_probs,
            use_kernel=cfg.use_kernel, guard=cfg.guard)

        n_commit = jnp.where(active, res.n_commit, 0)

        # EOS truncation
        if cfg.eos_token is not None:
            pos_k = jnp.arange(k + 1)[None]
            is_eos = (res.out_tokens == cfg.eos_token) & (pos_k < n_commit[:, None])
            any_eos = is_eos.any(axis=1)
            first_eos = jnp.argmax(is_eos, axis=1)
            n_commit = jnp.where(any_eos, jnp.minimum(n_commit, first_eos + 1),
                                 n_commit)
            finished = finished | (any_eos & active)

        # 4. write committed tokens into the buffer (slot L = trash)
        l_buf = buf.shape[1] - 1
        # never count commits past the buffer end (the row finishes anyway)
        n_commit = jnp.minimum(n_commit,
                               jnp.maximum(l_buf - lengths, 0))
        wpos = lengths[:, None] + jnp.arange(k + 1, dtype=jnp.int32)[None]
        wvalid = (jnp.arange(k + 1)[None] < n_commit[:, None]) & (wpos < l_buf)
        wslot = jnp.where(wvalid, wpos, l_buf)
        buf = buf.at[jnp.arange(b)[:, None], wslot].set(res.out_tokens)
        new_lengths = lengths + n_commit
        finished = finished | (new_lengths >= l_buf)

        # 5. cache bookkeeping
        committed = Committed(res.out_tokens, res.n_accept, n_commit,
                              base_index, features=feats, active=active)
        if self.target.is_recurrent:
            # recompute: re-apply [last_token, accepted drafts] from the
            # pre-cycle state; masked tail freezes the state
            rmask = (jnp.arange(k + 1, dtype=jnp.int32)[None]
                     < (res.n_accept + 1)[:, None]) & active[:, None]
            out_r = self.target.decode(
                t_params, inputs, positions, pre_cache, token_mask=rmask)
            t_cache = out_r[1]
        else:
            t_cache = dict(t_cache)
            t_cache["index"] = jnp.where(
                active, base_index + 1 + res.n_accept, base_index)

        d_state = self.drafter.sync(d_params, d_state, committed, extras)

        # pending token for the next cycle
        last_idx = jnp.clip(n_commit - 1, 0, k)
        new_last = jnp.take_along_axis(res.out_tokens, last_idx[:, None], 1)[:, 0]
        last_token = jnp.where(active, new_last, last_token)
        lengths = new_lengths

        stats = {
            "cycles": stats["cycles"] + active.astype(jnp.int32),
            "commits": stats["commits"] + n_commit,
            "accepts": stats["accepts"] + jnp.where(active, res.n_accept, 0),
            "relaxed": stats["relaxed"] + jnp.where(active, res.n_relaxed, 0),
        }
        return (buf, lengths, finished, t_cache, d_state, last_token, key,
                stats)

    # -- full generation ------------------------------------------------------
    def generate(self, t_params, d_params, prompt: jnp.ndarray,
                 prompt_len: jnp.ndarray, max_new: int, key,
                 theta=None, encoder_frames=None) -> Dict[str, Any]:
        """prompt: (B, S) right-padded; prompt_len: (B,) valid lengths."""
        cfg = self.cfg
        b, s = prompt.shape
        l_buf = s + max_new + cfg.k + 2
        buf = jnp.zeros((b, l_buf + 1), jnp.int32)  # +1 trash slot
        buf = buf.at[:, :s].set(prompt)

        t_cache = self.target.init_cache(t_params, b, l_buf,
                                         encoder_frames=encoder_frames)
        d_state = self.drafter.init_state(d_params, b, l_buf)

        # prefill prompt[:-1]; the final prompt token is pending
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        pmask = pos < (prompt_len - 1)[:, None]
        out = self.target.decode(t_params, prompt, pos, t_cache,
                                 token_mask=pmask,
                                 with_features=self.drafter.wants_features)
        if self.drafter.wants_features:
            _, t_cache, pfeats = out
            # ground drafter feature on the feature of the last cached token
            idx = jnp.clip(prompt_len - 2, 0, s - 1)[:, None, None]
            feat0 = jnp.take_along_axis(
                pfeats, jnp.broadcast_to(idx, (b, 1, pfeats.shape[-1])), 1)[:, 0]
            if "feat" in d_state:
                d_state = {**d_state, "feat": feat0.astype(d_state["feat"].dtype)}
        else:
            _, t_cache = out
        d_state = self.drafter.prefill(d_params, d_state, prompt, prompt_len)

        last_token = jnp.take_along_axis(
            prompt, jnp.clip(prompt_len - 1, 0, s - 1)[:, None], 1)[:, 0]
        lengths = prompt_len
        finished = jnp.zeros((b,), bool)
        stats = {k_: jnp.zeros((b,), jnp.int32)
                 for k_ in ("cycles", "commits", "accepts", "relaxed")}
        carry = (buf, lengths, finished, t_cache, d_state, last_token, key,
                 stats)

        max_cycles = max_new  # worst case: 1 committed token per cycle

        def cond(state):
            c = state[2]
            st = state[7]
            return (~c).any() & (st["cycles"].max() < max_cycles)

        def body(state):
            return self.cycle(t_params, d_params, state, theta=theta)

        (buf, lengths, finished, _, _, _, _, stats) = jax.lax.while_loop(
            cond, body, carry)
        return {
            "tokens": buf[:, :-1],
            "lengths": jnp.minimum(lengths, l_buf),
            "finished": finished,
            "stats": stats,
        }


def make_generate_fn(target: Model, drafter, cfg: EngineConfig):
    """Returns a jitted generate(t_params, d_params, prompt, prompt_len, key)."""
    engine = SpecEngine(target, drafter, cfg)

    @functools.partial(jax.jit, static_argnames=("max_new",))
    def generate(t_params, d_params, prompt, prompt_len, key, max_new=64,
                 theta=None, encoder_frames=None):
        if theta is None:
            theta = cfg.theta
        return engine.generate(t_params, d_params, prompt, prompt_len,
                               max_new, key, theta=jnp.asarray(theta),
                               encoder_frames=encoder_frames)

    return generate


# ---------------------------------------------------------------------------
# Vanilla autoregressive baseline (speedup denominator)
# ---------------------------------------------------------------------------

def make_ar_generate_fn(target: Model, *, temperature: float = 1.0,
                        eos_token: Optional[int] = None):
    @functools.partial(jax.jit, static_argnames=("max_new",))
    def generate(t_params, prompt, prompt_len, key, max_new=64):
        b, s = prompt.shape
        l_buf = s + max_new + 1
        buf = jnp.zeros((b, l_buf + 1), jnp.int32).at[:, :s].set(prompt)
        cache = target.init_cache(t_params, b, l_buf)
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        pmask = pos < (prompt_len - 1)[:, None]
        _, cache = target.decode(t_params, prompt, pos, cache, token_mask=pmask)
        last = jnp.take_along_axis(
            prompt, jnp.clip(prompt_len - 1, 0, s - 1)[:, None], 1)[:, 0]
        lengths = prompt_len
        finished = jnp.zeros((b,), bool)

        def body(i, st):
            buf, lengths, finished, cache, last, key = st
            key, k_s = jax.random.split(key)
            active = ~finished
            pos = cache["index"][:, None]
            logits, cache = target.decode(
                t_params, last[:, None], pos, cache,
                token_mask=active[:, None])
            logits = logits[:, -1].astype(jnp.float32)
            if temperature <= 0.0:
                nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            else:
                nxt = jax.random.categorical(
                    k_s, logits / temperature, -1).astype(jnp.int32)
            wslot = jnp.where(active & (lengths < l_buf), lengths, l_buf)
            buf = buf.at[jnp.arange(b), wslot].set(nxt)
            lengths = lengths + active.astype(jnp.int32)
            if eos_token is not None:
                finished = finished | (active & (nxt == eos_token))
            finished = finished | (lengths >= l_buf)
            last = jnp.where(active, nxt, last)
            return (buf, lengths, finished, cache, last, key)

        buf, lengths, finished, _, _, _ = jax.lax.fori_loop(
            0, max_new, body, (buf, lengths, finished, cache, last, key))
        return {"tokens": buf[:, :-1], "lengths": lengths,
                "finished": finished}

    return generate
