"""Acceptance / speedup / latency metrics for speculative decoding."""
from __future__ import annotations

from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np


def tau(stats: Dict[str, jnp.ndarray]) -> float:
    """Average committed tokens per draft–verify cycle (paper's τ)."""
    cycles = np.asarray(stats["cycles"], dtype=np.float64)
    commits = np.asarray(stats["commits"], dtype=np.float64)
    return float(commits.sum() / np.maximum(cycles.sum(), 1.0))


def acceptance_rate(stats: Dict[str, jnp.ndarray], k: int) -> float:
    cycles = np.asarray(stats["cycles"], dtype=np.float64).sum()
    accepts = np.asarray(stats["accepts"], dtype=np.float64).sum()
    return float(accepts / np.maximum(cycles * k, 1.0))


def relax_fraction(stats: Dict[str, jnp.ndarray]) -> float:
    """Fraction of accepted draft tokens that needed MARS relaxation."""
    accepts = np.asarray(stats["accepts"], dtype=np.float64).sum()
    relaxed = np.asarray(stats["relaxed"], dtype=np.float64).sum()
    return float(relaxed / np.maximum(accepts, 1.0))


def analytic_speedup(tau_: float, k: int, *, cost_draft_ratio: float,
                     verify_overhead: float = 1.0) -> float:
    """Standard SD cost model (Leviathan et al.):

      speedup = τ / (K * c + v)

    where c is the per-token draft cost relative to one target forward and v
    the cost of the K+1-token parallel verify relative to one target forward
    (≈1 in the memory-bound decode regime: weights dominate HBM traffic).
    """
    return tau_ / (k * cost_draft_ratio + verify_overhead)


def flops_cost_ratio(draft_params: int, target_params: int) -> float:
    """Per-token draft/target cost proxy from active parameter counts
    (decode is memory-bound; bytes moved ∝ params)."""
    return draft_params / max(target_params, 1)


def ttft(submit_s: Optional[float],
         first_commit_s: Optional[float]) -> Optional[float]:
    """Time to first token: submit → first host-observed commit.

    None when either endpoint was never observed. Clamped at zero so
    clock jitter can never report a negative latency. Shared by the
    serving benchmark and `repro.obs` so latency math lives in one place.
    """
    if submit_s is None or first_commit_s is None:
        return None
    return max(first_commit_s - submit_s, 0.0)


def itl(first_commit_s: Optional[float], finish_s: Optional[float],
        tokens_after_first: int) -> Optional[float]:
    """Mean inter-token latency: (finish - first_commit) / tokens after the
    first commit. None when the request never spanned more than one
    host-observed commit (the interval is then unmeasurable, not zero)."""
    if first_commit_s is None or finish_s is None or tokens_after_first <= 0:
        return None
    return max(finish_s - first_commit_s, 0.0) / tokens_after_first
