"""Drafters for speculative decoding.

Four drafter families, matching the paper's comparison set:

* :class:`IndependentDrafter` — standard speculative sampling (SpS / "SPD"):
  a separate small LM drafts K tokens autoregressively.
* :class:`EagleDrafter` — EAGLE-style feature-conditioned head: one
  transformer block drafting in the target's feature space, re-grounded on
  the target's true features for committed tokens each cycle.
* :class:`MedusaDrafter` — Medusa-style independent offset heads over the
  last committed target feature.
* :class:`PLDrafter` — Prompt-Lookup Decoding: copies the continuation of
  the most recent n-gram match from the generated buffer (no model).

All drafters implement the same jit-friendly protocol:

  init_state(params, batch, max_len)                   -> state
  prefill(params, state, tokens, lengths, slot_mask=)  -> state
  draft(params, state, last_token, extras, key)        -> (DraftOutput, state)
  sync(params, state, committed, extras)               -> state
  reset_slots(state, slot_mask)                        -> state

``slot_mask`` (B,) marks the batch rows being (re)admitted — the shared
``DecodeSession`` uses it both for whole-batch generation (all rows) and for
continuous-batching admission (one slot), so masked rows are never
disturbed.  ``reset_slots`` clears per-row drafter state for those rows.

``extras`` carries engine context: the token buffer + lengths (PLD) and the
target features from the verify pass (EAGLE / Medusa).  MARS — the paper's
contribution — never looks at the drafter: it only changes the verify rule,
which is what makes it plug-and-play across all four (paper §4.5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.model import Model, _init_block, _apply_block


class DraftOutput(NamedTuple):
    tokens: jnp.ndarray                    # (B, K)
    token_probs: jnp.ndarray               # (B, K) drafter prob of its sample
    full_probs: Optional[jnp.ndarray]      # (B, K, V) or None


class Committed(NamedTuple):
    """What the engine learned from one verify cycle."""
    out_tokens: jnp.ndarray                # (B, K+1)
    n_accept: jnp.ndarray                  # (B,)
    n_commit: jnp.ndarray                  # (B,)
    base_index: jnp.ndarray                # (B,) target cache index pre-cycle
    features: Optional[jnp.ndarray] = None  # (B, K+1, d) target features
    active: Optional[jnp.ndarray] = None    # (B,) cycle ran for this row


def _sample(logits, key, temperature):
    """Sample (or argmax at T=0); returns (token, prob_of_token, log_probs)."""
    logits = logits.astype(jnp.float32)
    if temperature <= 0.0:
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        logp = jax.nn.log_softmax(logits, axis=-1)
    else:
        logp = jax.nn.log_softmax(logits / temperature, axis=-1)
        tok = jax.random.categorical(key, logp, axis=-1).astype(jnp.int32)
    p = jnp.exp(jnp.take_along_axis(logp, tok[..., None], axis=-1))[..., 0]
    if temperature <= 0.0:
        p = jnp.ones_like(p)
    return tok, p, logp


# ---------------------------------------------------------------------------
# Independent small-LM drafter (standard speculative sampling)
# ---------------------------------------------------------------------------

class IndependentDrafter:
    wants_features = False

    def __init__(self, model: Model, k: int, *, temperature: float = 1.0,
                 collect_full_probs: bool = False):
        self.model = model
        self.k = k
        self.temperature = temperature
        self.collect_full_probs = collect_full_probs

    def init_state(self, params, batch: int, max_len: int) -> Dict[str, Any]:
        return {"cache": self.model.init_cache(params, batch, max_len)}

    def reset_slots(self, state, slot_mask):
        return {"cache": self.model.reset_slots(state["cache"], slot_mask)}

    def prefill(self, params, state, tokens, lengths, slot_mask=None):
        """Feed prompt[:-1] (the final prompt token stays pending)."""
        b, s = tokens.shape
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        mask = pos < (lengths - 1)[:, None]
        if slot_mask is not None:
            mask = mask & slot_mask[:, None]
        cache = state["cache"]
        _, cache = self.model.decode(params, tokens, pos, cache, token_mask=mask)
        return {"cache": cache}

    def draft(self, params, state, last_token, extras, key):
        cache = state["cache"]
        keys = jax.random.split(key, self.k)

        def step(carry, k_i):
            tok, cache = carry
            pos = cache["index"][:, None]
            logits, cache = self.model.decode(params, tok[:, None], pos, cache)
            nxt, p, logp = _sample(logits[:, -1], k_i, self.temperature)
            full = jnp.exp(logp) if self.collect_full_probs else jnp.zeros((1,))
            return (nxt, cache), (nxt, p, full)

        (_, cache), (toks, probs, fulls) = jax.lax.scan(
            step, (last_token, cache), keys)
        toks = jnp.moveaxis(toks, 0, 1)            # (B, K)
        probs = jnp.moveaxis(probs, 0, 1)
        full = (jnp.moveaxis(fulls, 0, 1) if self.collect_full_probs else None)
        return DraftOutput(toks, probs, full), {"cache": cache}

    def sync(self, params, state, committed: Committed, extras):
        cache = dict(state["cache"])
        # rollback: cache holds [last_token, d1..d_{K-1}] starting at
        # base_index; valid prefix is last_token + accepted drafts
        cache["index"] = committed.base_index + 1 + committed.n_accept
        # when the whole draft was accepted the drafter never processed d_K;
        # feed it (masked elsewhere) so its kv/state exists
        k = committed.out_tokens.shape[1] - 1
        need = committed.n_accept >= k
        if committed.active is not None:
            need = need & committed.active
        d_k = committed.out_tokens[:, k - 1][:, None]  # d_K (last accepted)
        # d_K belongs at base_index + K (slot after d_{K-1})
        pos = (committed.base_index + k)[:, None]
        _, cache = self.model.decode(params, d_k, pos, cache,
                                     token_mask=need[:, None])
        cache["index"] = committed.base_index + 1 + committed.n_accept
        return {"cache": cache}


# ---------------------------------------------------------------------------
# EAGLE-style feature drafter
# ---------------------------------------------------------------------------

def init_eagle_params(cfg: ModelConfig, rng) -> Dict[str, Any]:
    """One transformer block + fusion fc over (token emb, prev feature)."""
    k_fc, k_block = jax.random.split(rng)
    return {
        "fc": L._dense_init(k_fc, (2 * cfg.d_model, cfg.d_model)),
        "block": _init_block(cfg, k_block, moe=False, cross=False),
    }


class EagleDrafter:
    """Chain-EAGLE: drafts in feature space, one block deep.

    The head owns a small KV cache over the fused (emb, feature) stream; it
    reuses the *target's* embedding matrix and LM head (EAGLE's design), and
    its feature carry is re-grounded on the target's true feature for the
    last committed token after every verify cycle.
    """
    wants_features = True

    def __init__(self, target_model: Model, k: int, *,
                 temperature: float = 1.0):
        self.target = target_model
        self.cfg = target_model.cfg
        self.k = k
        self.temperature = temperature

    def init_state(self, params, batch: int, max_len: int) -> Dict[str, Any]:
        cache = L.make_attention_cache(self.cfg, batch, max_len)
        feat = jnp.zeros((batch, self.cfg.d_model), L.dtype_of(self.cfg))
        return {"cache": cache, "feat": feat}

    def reset_slots(self, state, slot_mask):
        # kv entries are masked by stored absolute position, so invalidating
        # the row's positions is a full wipe; the feature carry re-grounds
        # at admission prefill
        cache = dict(state["cache"])
        cache["pos"] = jnp.where(slot_mask[:, None], L._INVALID_POS,
                                 cache["pos"])
        feat = jnp.where(slot_mask[:, None], 0.0, state["feat"])
        return {"cache": cache, "feat": feat.astype(state["feat"].dtype)}

    def _step(self, params, target_params, tok, feat, pos, cache, token_mask=None):
        cfg = self.cfg
        emb = target_params["embedding"][tok].astype(feat.dtype)     # (B,1? d)
        x = jnp.concatenate([emb, feat[:, None]], axis=-1) @ \
            params["fc"].astype(feat.dtype)
        if token_mask is not None:
            pos = jnp.where(token_mask, pos, -1)
        y, new_cache, _ = _apply_block(cfg, params["block"], x, pos, cache=cache)
        new_feat = y[:, 0]
        w = (target_params["embedding"].T if cfg.tie_embeddings
             else target_params["lm_head"]).astype(feat.dtype)
        logits = new_feat @ w
        return logits, new_feat, new_cache

    def prefill(self, params, state, tokens, lengths, slot_mask=None):
        # feed prompt[:-1] token-by-token is wasteful; fuse once: here we
        # simply reset and rely on sync() grounding — the head conditions on
        # the last feature only, plus its own kv of drafted steps.
        return state

    def draft(self, params, state, last_token, extras, key):
        target_params = extras["target_params"]
        cache, feat = state["cache"], state["feat"]
        keys = jax.random.split(key, self.k)

        # explicit python loop (K is small and static) keeps position math simple
        toks, probs = [], []
        pos0 = extras["index"]
        tok = last_token
        for i in range(self.k):
            pos = (pos0 + i)[:, None]
            logits, feat, cache = self._step(
                params, target_params, tok[:, None], feat, pos, cache)
            tok, p, _ = _sample(logits, keys[i], self.temperature)
            toks.append(tok)
            probs.append(p)
        out = DraftOutput(jnp.stack(toks, 1), jnp.stack(probs, 1), None)
        return out, {"cache": cache, "feat": feat}

    def sync(self, params, state, committed: Committed, extras):
        # the head's kv cache is ring-addressed by absolute target positions
        # (supplied each draft call), so no index rewind is needed: stale
        # entries are masked by position and overwritten on the next pass.
        cache = state["cache"]
        # ground the feature carry on the target's true feature at the last
        # position preceding the pending token
        feats = committed.features                         # (B, K+1, d)
        idx = committed.n_accept[:, None, None]            # feature at d_{n}/last
        feat = jnp.take_along_axis(feats, idx, axis=1)[:, 0]
        if committed.active is not None:
            feat = jnp.where(committed.active[:, None], feat, state["feat"])
        return {"cache": cache, "feat": feat.astype(state["feat"].dtype)}


# ---------------------------------------------------------------------------
# Medusa-style offset heads
# ---------------------------------------------------------------------------

def init_medusa_params(cfg: ModelConfig, rng, n_heads: int) -> Dict[str, Any]:
    keys = jax.random.split(rng, n_heads)
    return {
        "heads_w1": jnp.stack([
            L._dense_init(k, (cfg.d_model, cfg.d_model)) for k in keys]),
    }


class MedusaDrafter:
    """Medusa-lite: head h predicts the token at offset h+1 from the last
    committed feature (resblock + target LM head).  K = n_heads drafts."""
    wants_features = True

    def __init__(self, target_model: Model, k: int, *, temperature: float = 1.0):
        self.target = target_model
        self.cfg = target_model.cfg
        self.k = k
        self.temperature = temperature

    def init_state(self, params, batch: int, max_len: int) -> Dict[str, Any]:
        return {"feat": jnp.zeros((batch, self.cfg.d_model),
                                  L.dtype_of(self.cfg))}

    def reset_slots(self, state, slot_mask):
        feat = jnp.where(slot_mask[:, None], 0.0, state["feat"])
        return {"feat": feat.astype(state["feat"].dtype)}

    def prefill(self, params, state, tokens, lengths, slot_mask=None):
        return state

    def draft(self, params, state, last_token, extras, key):
        cfg = self.cfg
        target_params = extras["target_params"]
        feat = state["feat"]
        w = (target_params["embedding"].T if cfg.tie_embeddings
             else target_params["lm_head"]).astype(feat.dtype)
        keys = jax.random.split(key, self.k)
        toks, probs = [], []
        for h in range(self.k):
            wh = params["heads_w1"][h].astype(feat.dtype)
            fh = feat + jax.nn.silu(feat @ wh)
            logits = fh @ w
            tok, p, _ = _sample(logits, keys[h], self.temperature)
            toks.append(tok)
            probs.append(p)
        return DraftOutput(jnp.stack(toks, 1), jnp.stack(probs, 1), None), state

    def sync(self, params, state, committed: Committed, extras):
        feats = committed.features
        idx = committed.n_accept[:, None, None]
        feat = jnp.take_along_axis(feats, idx, axis=1)[:, 0]
        if committed.active is not None:
            feat = jnp.where(committed.active[:, None], feat, state["feat"])
        return {"feat": feat.astype(state["feat"].dtype)}


# ---------------------------------------------------------------------------
# Prompt-Lookup Decoding (no model)
# ---------------------------------------------------------------------------

class PLDrafter:
    """Copies K tokens following the latest match of the trailing n-gram in
    the already-generated buffer (Somasundaram et al., 2024)."""
    wants_features = False

    def __init__(self, k: int, *, ngram: int = 2, max_len: int = 0):
        self.k = k
        self.ngram = ngram

    def init_state(self, params, batch: int, max_len: int) -> Dict[str, Any]:
        return {}

    def reset_slots(self, state, slot_mask):
        return state

    def prefill(self, params, state, tokens, lengths, slot_mask=None):
        return state

    def draft(self, params, state, last_token, extras, key):
        buf = extras["tokens_buf"]            # (B, L) committed + pending last
        lengths = extras["lengths"]           # (B,) committed length
        b, l = buf.shape
        n, k = self.ngram, self.k
        # trailing n-gram ends at the pending last_token (== buf[lengths-1])
        gram_idx = lengths[:, None] - n + jnp.arange(n - 1)[None]
        gram_hist = jnp.take_along_axis(buf, jnp.clip(gram_idx, 0, l - 1), 1)
        gram = (jnp.concatenate([gram_hist, last_token[:, None]], 1)
                if n > 1 else last_token[:, None])

        # match score at every start position i: buf[i:i+n] == gram
        valid_len = l - n + 1
        m = jnp.ones((b, valid_len), bool)
        for j in range(n):
            m &= buf[:, j:valid_len + j] == gram[:, j][:, None]
        # matches must lie strictly before the trailing gram occurrence
        starts = jnp.arange(valid_len)[None]
        m &= (starts + n) <= lengths[:, None] - 1
        # most recent match
        best = jnp.where(m, starts, -1).max(axis=1)          # (B,)
        found = best >= 0
        copy_idx = best[:, None] + n + jnp.arange(k)[None]
        copy_idx = jnp.clip(copy_idx, 0, l - 1)
        toks = jnp.take_along_axis(buf, copy_idx, axis=1)
        # fallback when no match: repeat last token (will be rejected fast)
        toks = jnp.where(found[:, None], toks, last_token[:, None])
        probs = jnp.ones((b, k), jnp.float32)  # deterministic drafter: q = 1
        return DraftOutput(toks.astype(jnp.int32), probs, None), state

    def sync(self, params, state, committed: Committed, extras):
        return state
