"""Tree-draft topology for the shared ``DecodeSession`` engine core.

The paper (§2.3) notes MARS applies on top of tree-based verification; this
module implements the *caterpillar* tree (EAGLE-lite) as a draft-topology
strategy plugged into :class:`repro.core.session.DecodeSession` — the same
session that runs chain decoding and the continuous-batching server, so
tree drafts serve, share the fused Pallas verify kernel, and inherit every
bookkeeping improvement for free.

Topology: a main draft chain of depth K plus ``branch-1`` sibling candidates
at every depth, taken from the drafter's own top-k at that step (no extra
drafter passes).  Verification scores all nodes in ONE virtual target pass
(tree-ancestry attention against the KV cache, nothing written), then:

  1. walk the chain; at the first rejected chain node, try to *rescue* with
     an accepted sibling at that depth (exact-match or MARS-relaxed);
  2. a rescued sibling contributes its own bonus continuation from its
     (already computed!) node logits — this is where trees beat chains;
  3. the session commits the chosen path via its shared recompute rollback
     (a masked decode from the pre-cycle cache — the same pass recurrent
     targets use), so the KV cache only ever contains committed tokens.
     The commit decode is cache-layout agnostic: against a paged target
     cache it scatters the path's KV into the slot's freshly admitted
     blocks through the block table (``repro.models.paging``).

Node layout: node 0 = root (the pending last token, depth 0); depth d >= 1
holds ``branch`` nodes, the first being the chain node.  All exact/relax
decisions route through :class:`repro.core.verify.VerifyBackend`, which
flattens the (B, N, V) node logits to the kernel's (rows, V) layout when the
fused path is selected.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import verify as V
from repro.core.drafter import _sample
from repro.core.session import (CycleOutcome, DecodeSession, DecodeState,
                                EngineConfig)
from repro.models.model import Model


class TreeTemplate(NamedTuple):
    depth: np.ndarray        # (N,) node depth (root = 0)
    parent: np.ndarray       # (N,) parent node index (root = -1)
    is_chain: np.ndarray     # (N,) on the main chain?
    mask: np.ndarray         # (N, N) ancestry-or-self attention mask
    k: int                   # chain depth
    branch: int              # candidates per depth (1 chain + b-1 siblings)


def make_caterpillar(k: int, branch: int) -> TreeTemplate:
    depth = [0]
    parent = [-1]
    is_chain = [True]
    chain_at = {0: 0}
    for d in range(1, k + 1):
        for b in range(branch):
            depth.append(d)
            parent.append(chain_at[d - 1])
            is_chain.append(b == 0)
            if b == 0:
                chain_at[d] = len(depth) - 1
    n = len(depth)
    mask = np.zeros((n, n), bool)
    for i in range(n):
        j = i
        while j >= 0:
            mask[i, j] = True
            j = parent[j]
    return TreeTemplate(np.asarray(depth), np.asarray(parent),
                        np.asarray(is_chain), mask, k, branch)


class TreeDraft(NamedTuple):
    tokens: jnp.ndarray       # (B, N) node tokens (node 0 = last_token)
    token_probs: jnp.ndarray  # (B, N) drafter prob of each node token


def draft_tree_eagle(drafter, params, state, last_token, extras, key,
                     tpl: TreeTemplate) -> Tuple[TreeDraft, Dict]:
    """Chain-draft with the EAGLE-style head, capturing top-``branch``
    candidates at every depth as sibling nodes."""
    target_params = extras["target_params"]
    cache, feat = state["cache"], state["feat"]
    keys = jax.random.split(key, tpl.k)
    b = last_token.shape[0]

    toks = [last_token]                     # node 0 = root
    probs = [jnp.ones((b,), jnp.float32)]
    tok = last_token
    pos0 = extras["index"]
    for d in range(tpl.k):
        pos = (pos0 + d)[:, None]
        logits, feat, cache = drafter._step(
            params, target_params, tok[:, None], feat, pos, cache)
        lf = logits.astype(jnp.float32)
        if drafter.temperature > 0:
            logp = jax.nn.log_softmax(lf / drafter.temperature, -1)
        else:
            logp = jax.nn.log_softmax(lf, -1)
        top_p, top_i = jax.lax.top_k(logp, tpl.branch)
        if drafter.temperature > 0:
            tok, p0, _ = _sample(logits, keys[d], drafter.temperature)
        else:
            tok, p0 = top_i[:, 0].astype(jnp.int32), jnp.ones((b,))
        # chain node first (sampled / argmax), then runner-up candidates as
        # sibling nodes (occasional duplication with a sampled chain token
        # wastes a node but never hurts correctness)
        toks.append(tok)
        probs.append(p0)
        for j in range(1, tpl.branch):
            toks.append(top_i[:, j].astype(jnp.int32))
            probs.append(jnp.exp(top_p[:, j]))
    draft = TreeDraft(jnp.stack(toks, 1), jnp.stack(probs, 1))
    return draft, {"cache": cache, "feat": feat}


def verify_tree(tpl: TreeTemplate, node_tokens: jnp.ndarray,
                node_logits: jnp.ndarray, *, rule: str, mode: str,
                theta, temperature, key,
                node_probs: Optional[jnp.ndarray] = None,
                use_kernel: bool = False, guard: str = "positive",
                backend: Optional[V.VerifyBackend] = None):
    """Choose the committed path.

    node_tokens: (B, N); node_logits: (B, N, V) — logits[i] is the target
    distribution for the *successor* of node i.  ``temperature`` and
    ``theta`` may each be a scalar or a per-row ``(B,)`` vector
    (per-request serving temperature / relaxation threshold).

    Returns (out_tokens (B, K+2), n_commit (B,), n_accept, n_relaxed,
    margin) — ``margin`` is the top-2 logit ratio at the first rejected
    *chain* node (-1 when the chain fully accepted or the guard held no
    valid ratio there), mirroring :class:`repro.core.verify.VerifyResult`.
    """
    b, n, v = node_logits.shape
    k, branch = tpl.k, tpl.branch
    key_acc, key_extra = jax.random.split(key)
    backend = V.resolve_backend(backend, use_kernel=use_kernel, guard=guard)

    parent = jnp.asarray(tpl.parent)
    parent_logits = node_logits[:, jnp.maximum(parent, 0)]   # (B, N, V)

    need_relax = rule == "mars"
    ratio = valid = None
    if mode == "greedy" or need_relax:
        exact, relax_raw, ratio, valid = backend.exact_relax_margin(
            node_tokens, parent_logits, theta)

    if mode == "greedy":
        accept = exact
    else:
        t = V._temp_like(temperature, parent_logits.ndim)
        logp = jax.nn.log_softmax(
            parent_logits.astype(jnp.float32) / jnp.maximum(t, 1e-6), -1)
        p_tok = jnp.exp(jnp.take_along_axis(
            logp, node_tokens[..., None], -1))[..., 0]
        u = jax.random.uniform(key_acc, node_tokens.shape)
        q = node_probs if node_probs is not None else jnp.ones_like(p_tok)
        accept = u * jnp.maximum(q, 1e-30) < p_tok

    relax = jnp.zeros_like(accept)
    if need_relax:
        relax = relax_raw & ~accept
        accept = accept | relax

    # chain walk
    chain_idx = jnp.asarray(np.where(tpl.is_chain)[0][1:])   # depth 1..K
    chain_acc = accept[:, chain_idx]                          # (B, K)
    run = jnp.cumprod(chain_acc.astype(jnp.int32), 1)
    n_chain = jnp.sum(run, 1)                                 # (B,)
    n_relax_chain = jnp.sum(run * relax[:, chain_idx].astype(jnp.int32), 1)

    if ratio is not None:
        margin = V.margin_at_first_rejection(
            ratio[:, chain_idx], valid[:, chain_idx], n_chain, k)
    else:
        margin = jnp.full((b,), -1.0, jnp.float32)

    # sibling rescue at depth n_chain + 1 (if any sibling accepted there)
    # node index of sibling j at depth d: chain nodes are first per depth
    if branch > 1:
        sib_cols = []
        for d in range(1, k + 1):
            base = 1 + (d - 1) * branch
            sib_cols.append([base + j for j in range(1, branch)])
        sib_cols = jnp.asarray(sib_cols)                      # (K, branch-1)
        fail_depth = jnp.minimum(n_chain, k - 1)              # depth (0-based)
        sib_nodes = sib_cols[fail_depth]                      # (B, branch-1)
        sib_acc = jnp.take_along_axis(accept, sib_nodes, 1)   # (B, branch-1)
        sib_rel = jnp.take_along_axis(relax, sib_nodes, 1)
        has_rescue = sib_acc.any(1) & (n_chain < k)
        first_sib = jnp.argmax(sib_acc, 1)
        rescue_node = jnp.take_along_axis(
            sib_nodes, first_sib[:, None], 1)[:, 0]
        rescue_rel = jnp.take_along_axis(
            sib_rel, first_sib[:, None], 1)[:, 0]
    else:                                 # pure chain: nothing to rescue with
        has_rescue = jnp.zeros((b,), bool)
        rescue_node = jnp.zeros((b,), jnp.int32)
        rescue_rel = jnp.zeros((b,), bool)

    # the node whose logits give the extra token:
    #   full chain accepted -> last chain node (bonus)
    #   rescue              -> rescued sibling  (bonus)
    #   else                -> the last accepted chain node (correction)
    chain_idx_pad = jnp.concatenate([jnp.zeros((1,), jnp.int32), chain_idx])
    last_ok_chain = chain_idx_pad[n_chain]                    # (B,)
    extra_src = jnp.where(has_rescue, rescue_node, last_ok_chain)
    src_logits = jnp.take_along_axis(
        node_logits, extra_src[:, None, None], 1)[:, 0]       # (B, V)
    if mode == "greedy":
        extra = jnp.argmax(src_logits, -1).astype(jnp.int32)
    else:
        t = V._temp_like(temperature, src_logits.ndim)
        lf = src_logits.astype(jnp.float32) / jnp.maximum(t, 1e-6)
        extra = jax.random.categorical(key_extra, lf, -1).astype(jnp.int32)

    # assemble committed tokens: chain prefix (+ rescue) + extra
    chain_toks = node_tokens[:, chain_idx]                    # (B, K)
    pos_k = jnp.arange(k + 2)[None]                           # (B, K+2) slots
    out = jnp.zeros((b, k + 2), jnp.int32)
    chain_pad = jnp.concatenate(
        [chain_toks, chain_toks[:, -1:], chain_toks[:, -1:]], 1)
    rescue_tok = jnp.take_along_axis(node_tokens, rescue_node[:, None], 1)[:, 0]
    n_resc = has_rescue.astype(jnp.int32)
    out = jnp.where(pos_k < n_chain[:, None], chain_pad, 0)
    out = jnp.where((pos_k == n_chain[:, None]) & has_rescue[:, None],
                    rescue_tok[:, None], out)
    extra_slot = n_chain + n_resc
    out = jnp.where(pos_k == extra_slot[:, None], extra[:, None], out)
    out = jnp.where(pos_k > extra_slot[:, None], extra[:, None], out)

    n_accept = n_chain + n_resc
    n_commit = n_accept + 1
    n_relaxed = n_relax_chain + (rescue_rel & has_rescue).astype(jnp.int32)
    return out, n_commit, n_accept, n_relaxed, margin


# ---------------------------------------------------------------------------
# Topology strategy for DecodeSession
# ---------------------------------------------------------------------------

class TreeTopology:
    """Caterpillar-tree drafts scored by one virtual (non-writing) target
    pass; the session's shared recompute rollback commits the chosen path."""

    name = "tree"

    def __init__(self, cfg: EngineConfig):
        self.cfg = cfg
        self.tpl = make_caterpillar(cfg.k, cfg.branch)

    @property
    def width(self) -> int:
        return self.tpl.k + 2        # chain prefix + rescue + extra

    @property
    def commit_width(self) -> int:
        """Most tokens one cycle can commit (chain + rescue + extra)."""
        return self.tpl.k + 2

    @property
    def buffer_margin(self) -> int:
        return self.tpl.k + 3

    def run(self, session: DecodeSession, t_params, d_params,
            state: DecodeState, extras, k_draft, k_verify, theta,
            active) -> CycleOutcome:
        cfg, tpl = self.cfg, self.tpl
        target, drafter = session.target, session.drafter
        kk = self.width

        # 1. draft the tree (EAGLE-style head, no extra drafter passes)
        draft, d_state = draft_tree_eagle(
            drafter, d_params, state.d_state, state.last_token, extras,
            k_draft, tpl)

        # 2. score all nodes in one virtual pass (nothing written)
        base_index = state.t_cache["index"]
        positions = base_index[:, None] + jnp.asarray(tpl.depth)[None]
        node_logits = target.decode_virtual(
            t_params, draft.tokens, positions, state.t_cache,
            jnp.asarray(tpl.mask))

        # 3. verify: chain walk + sibling rescue
        out, n_commit, n_accept, n_relaxed, margin = verify_tree(
            tpl, draft.tokens, node_logits, rule=cfg.rule, mode=cfg.mode,
            theta=theta, temperature=state.temperature, key=k_verify,
            node_probs=draft.token_probs, backend=cfg.backend())

        # 4. commit via the shared rollback: the virtual pass never wrote, so
        #    the current cache IS the pre-cycle state to recompute from
        commit_inputs = jnp.concatenate(
            [state.last_token[:, None], out[:, :kk - 1]], 1)
        commit_pos = (base_index[:, None]
                      + jnp.arange(kk, dtype=jnp.int32)[None])
        t_cache, feats = session.rollback(
            t_params, state.t_cache, None, commit_inputs, commit_pos,
            n_accept, active, base_index, scored_in_place=False,
            want_features=drafter.wants_features)

        return CycleOutcome(out, n_accept, n_commit, n_relaxed, t_cache,
                            d_state, base_index, features=feats,
                            margin=margin)


# ---------------------------------------------------------------------------
# Historical entry points (thin wrappers over DecodeSession)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TreeEngineConfig:
    k: int = 5
    branch: int = 3
    rule: str = "mars"
    mode: str = "greedy"
    theta: float = V.DEFAULT_THETA
    temperature: float = 0.0
    use_kernel: bool = False
    guard: str = "positive"

    def to_engine_config(self) -> EngineConfig:
        return EngineConfig(k=self.k, rule=self.rule, mode=self.mode,
                            theta=self.theta, temperature=self.temperature,
                            use_kernel=self.use_kernel, guard=self.guard,
                            topology="tree", branch=self.branch)


class TreeSpecEngine:
    """Tree-draft engine facade for attention-family targets with an
    EAGLE-style drafter; delegates to the shared :class:`DecodeSession`."""

    def __init__(self, target: Model, drafter, cfg: TreeEngineConfig):
        self.cfg = cfg
        self.session = DecodeSession(target, drafter, cfg.to_engine_config())
        self.target = target
        self.drafter = drafter
        self.tpl = self.session.topology.tpl

    def cycle(self, t_params, d_params, carry) -> DecodeState:
        return self.session.cycle(t_params, d_params, carry)

    def generate(self, t_params, d_params, prompt, prompt_len, max_new, key):
        return self.session.generate(t_params, d_params, prompt, prompt_len,
                                     max_new, key)


def make_tree_generate_fn(target: Model, drafter, cfg: TreeEngineConfig):
    session = DecodeSession(target, drafter, cfg.to_engine_config())

    @functools.partial(jax.jit, static_argnames=("max_new",))
    def generate(t_params, d_params, prompt, prompt_len, key, max_new=64):
        return session.generate(t_params, d_params, prompt, prompt_len,
                                max_new, key)

    return generate
