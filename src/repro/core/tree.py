"""Tree-draft speculative decoding with MARS verification.

The paper (§2.3) notes MARS applies on top of tree-based verification; this
module implements it with a *caterpillar* tree (EAGLE-lite): a main draft
chain of depth K plus ``branch-1`` sibling candidates at every depth, taken
from the drafter's own top-k at that step (no extra drafter passes).

Verification scores all nodes in ONE virtual target pass (tree-ancestry
attention against the KV cache, nothing written), then:

  1. walk the chain; at the first rejected chain node, try to *rescue* with
     an accepted sibling at that depth (exact-match or MARS-relaxed);
  2. a rescued sibling contributes its own bonus continuation from its
     (already computed!) node logits — this is where trees beat chains;
  3. commit the chosen path with a masked regular decode from the pre-cycle
     cache (the same recompute pass recurrent targets use), so the KV cache
     only ever contains committed tokens.

Node layout: node 0 = root (the pending last token, depth 0); depth d >= 1
holds ``branch`` nodes, the first being the chain node.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import verify as V
from repro.core.drafter import _sample
from repro.models.model import Model


class TreeTemplate(NamedTuple):
    depth: np.ndarray        # (N,) node depth (root = 0)
    parent: np.ndarray       # (N,) parent node index (root = -1)
    is_chain: np.ndarray     # (N,) on the main chain?
    mask: np.ndarray         # (N, N) ancestry-or-self attention mask
    k: int                   # chain depth
    branch: int              # candidates per depth (1 chain + b-1 siblings)


def make_caterpillar(k: int, branch: int) -> TreeTemplate:
    depth = [0]
    parent = [-1]
    is_chain = [True]
    chain_at = {0: 0}
    for d in range(1, k + 1):
        for b in range(branch):
            depth.append(d)
            parent.append(chain_at[d - 1])
            is_chain.append(b == 0)
            if b == 0:
                chain_at[d] = len(depth) - 1
    n = len(depth)
    mask = np.zeros((n, n), bool)
    for i in range(n):
        j = i
        while j >= 0:
            mask[i, j] = True
            j = parent[j]
    return TreeTemplate(np.asarray(depth), np.asarray(parent),
                        np.asarray(is_chain), mask, k, branch)


class TreeDraft(NamedTuple):
    tokens: jnp.ndarray       # (B, N) node tokens (node 0 = last_token)
    token_probs: jnp.ndarray  # (B, N) drafter prob of each node token


def draft_tree_eagle(drafter, params, state, last_token, extras, key,
                     tpl: TreeTemplate) -> Tuple[TreeDraft, Dict]:
    """Chain-draft with the EAGLE-style head, capturing top-``branch``
    candidates at every depth as sibling nodes."""
    target_params = extras["target_params"]
    cache, feat = state["cache"], state["feat"]
    keys = jax.random.split(key, tpl.k)
    b = last_token.shape[0]
    n = len(tpl.depth)

    toks = [last_token]                     # node 0 = root
    probs = [jnp.ones((b,), jnp.float32)]
    tok = last_token
    pos0 = extras["index"]
    for d in range(tpl.k):
        pos = (pos0 + d)[:, None]
        logits, feat, cache = drafter._step(
            params, target_params, tok[:, None], feat, pos, cache)
        lf = logits.astype(jnp.float32)
        if drafter.temperature > 0:
            logp = jax.nn.log_softmax(lf / drafter.temperature, -1)
        else:
            logp = jax.nn.log_softmax(lf, -1)
        top_p, top_i = jax.lax.top_k(logp, tpl.branch)
        if drafter.temperature > 0:
            tok, p0, _ = _sample(logits, keys[d], drafter.temperature)
        else:
            tok, p0 = top_i[:, 0].astype(jnp.int32), jnp.ones((b,))
        # chain node first (sampled / argmax), then runner-up candidates as
        # sibling nodes (occasional duplication with a sampled chain token
        # wastes a node but never hurts correctness)
        toks.append(tok)
        probs.append(p0)
        for j in range(1, tpl.branch):
            toks.append(top_i[:, j].astype(jnp.int32))
            probs.append(jnp.exp(top_p[:, j]))
    draft = TreeDraft(jnp.stack(toks, 1), jnp.stack(probs, 1))
    return draft, {"cache": cache, "feat": feat}


def verify_tree(tpl: TreeTemplate, node_tokens: jnp.ndarray,
                node_logits: jnp.ndarray, *, rule: str, mode: str,
                theta: float, temperature: float, key,
                node_probs: Optional[jnp.ndarray] = None):
    """Choose the committed path.

    node_tokens: (B, N); node_logits: (B, N, V) — logits[i] is the target
    distribution for the *successor* of node i.

    Returns (out_tokens (B, K+2), n_commit (B,), n_accept, n_relaxed).
    """
    b, n, v = node_logits.shape
    k, branch = tpl.k, tpl.branch
    key_acc, key_extra = jax.random.split(key)

    parent = jnp.asarray(tpl.parent)
    parent_logits = node_logits[:, jnp.maximum(parent, 0)]   # (B, N, V)

    if mode == "greedy":
        top1 = jnp.argmax(parent_logits, -1)
        accept = node_tokens == top1
    else:
        logp = jax.nn.log_softmax(
            parent_logits.astype(jnp.float32)
            / jnp.maximum(temperature, 1e-6), -1)
        p_tok = jnp.exp(jnp.take_along_axis(
            logp, node_tokens[..., None], -1))[..., 0]
        u = jax.random.uniform(key_acc, node_tokens.shape)
        q = node_probs if node_probs is not None else jnp.ones_like(p_tok)
        accept = u * jnp.maximum(q, 1e-30) < p_tok

    relax = jnp.zeros_like(accept)
    if rule == "mars":
        relax = V.mars_relax_mask(node_tokens, parent_logits, theta) & ~accept
        accept = accept | relax

    # chain walk
    chain_idx = jnp.asarray(np.where(tpl.is_chain)[0][1:])   # depth 1..K
    chain_acc = accept[:, chain_idx]                          # (B, K)
    run = jnp.cumprod(chain_acc.astype(jnp.int32), 1)
    n_chain = jnp.sum(run, 1)                                 # (B,)
    n_relax_chain = jnp.sum(run * relax[:, chain_idx].astype(jnp.int32), 1)

    # sibling rescue at depth n_chain + 1 (if any sibling accepted there)
    # node index of sibling j at depth d: chain nodes are first per depth
    sib_cols = []
    for d in range(1, k + 1):
        base = 1 + (d - 1) * branch
        sib_cols.append([base + j for j in range(1, branch)])
    sib_cols = jnp.asarray(sib_cols)                          # (K, branch-1)
    fail_depth = jnp.minimum(n_chain, k - 1)                  # depth idx (0-based)
    sib_nodes = sib_cols[fail_depth]                          # (B, branch-1)
    sib_acc = jnp.take_along_axis(accept, sib_nodes, 1)       # (B, branch-1)
    sib_rel = jnp.take_along_axis(relax, sib_nodes, 1)
    has_rescue = sib_acc.any(1) & (n_chain < k)
    first_sib = jnp.argmax(sib_acc, 1)
    rescue_node = jnp.take_along_axis(sib_nodes, first_sib[:, None], 1)[:, 0]
    rescue_rel = jnp.take_along_axis(sib_rel, first_sib[:, None], 1)[:, 0]

    # the node whose logits give the extra token:
    #   full chain accepted -> last chain node (bonus)
    #   rescue              -> rescued sibling  (bonus)
    #   else                -> the last accepted chain node (correction)
    chain_idx_pad = jnp.concatenate([jnp.zeros((1,), jnp.int32), chain_idx])
    last_ok_chain = chain_idx_pad[n_chain]                    # (B,)
    extra_src = jnp.where(has_rescue, rescue_node, last_ok_chain)
    src_logits = jnp.take_along_axis(
        node_logits, extra_src[:, None, None], 1)[:, 0]       # (B, V)
    if mode == "greedy":
        extra = jnp.argmax(src_logits, -1).astype(jnp.int32)
    else:
        lf = src_logits.astype(jnp.float32) / jnp.maximum(temperature, 1e-6)
        extra = jax.random.categorical(key_extra, lf, -1).astype(jnp.int32)

    # assemble committed tokens: chain prefix (+ rescue) + extra
    chain_toks = node_tokens[:, chain_idx]                    # (B, K)
    pos_k = jnp.arange(k + 2)[None]                           # (B, K+2) slots
    out = jnp.zeros((b, k + 2), jnp.int32)
    chain_pad = jnp.concatenate(
        [chain_toks, chain_toks[:, -1:], chain_toks[:, -1:]], 1)
    rescue_tok = jnp.take_along_axis(node_tokens, rescue_node[:, None], 1)[:, 0]
    n_resc = has_rescue.astype(jnp.int32)
    out = jnp.where(pos_k < n_chain[:, None], chain_pad, 0)
    out = jnp.where((pos_k == n_chain[:, None]) & has_rescue[:, None],
                    rescue_tok[:, None], out)
    extra_slot = n_chain + n_resc
    out = jnp.where(pos_k == extra_slot[:, None], extra[:, None], out)
    out = jnp.where(pos_k > extra_slot[:, None], extra[:, None], out)

    n_accept = n_chain + n_resc
    n_commit = n_accept + 1
    n_relaxed = n_relax_chain + (rescue_rel & has_rescue).astype(jnp.int32)
    return out, n_commit, n_accept, n_relaxed


@dataclasses.dataclass(frozen=True)
class TreeEngineConfig:
    k: int = 5
    branch: int = 3
    rule: str = "mars"
    mode: str = "greedy"
    theta: float = V.DEFAULT_THETA
    temperature: float = 0.0


class TreeSpecEngine:
    """Tree-draft engine for attention-family targets with an EAGLE-style
    drafter (the paper's EAGLE-3 + MARS configuration, tree edition)."""

    def __init__(self, target: Model, drafter, cfg: TreeEngineConfig):
        if target.is_recurrent:
            raise NotImplementedError(
                "tree verification needs attention-family targets; use the "
                "chain engine for ssm/hybrid")
        self.target = target
        self.drafter = drafter
        self.cfg = cfg
        self.tpl = make_caterpillar(cfg.k, cfg.branch)

    def cycle(self, t_params, d_params, carry):
        cfg, tpl = self.cfg, self.tpl
        (buf, lengths, finished, t_cache, d_state, last_token, key,
         stats) = carry
        b = last_token.shape[0]
        key, k_draft, k_verify = jax.random.split(key, 3)
        active = ~finished

        extras = {"target_params": t_params, "tokens_buf": buf,
                  "lengths": lengths, "index": t_cache["index"]}
        draft, d_state = draft_tree_eagle(
            self.drafter, d_params, d_state, last_token, extras, k_draft, tpl)

        base = t_cache["index"]
        positions = base[:, None] + jnp.asarray(tpl.depth)[None]
        node_logits = self.target.decode_virtual(
            t_params, draft.tokens, positions, t_cache,
            jnp.asarray(tpl.mask))

        out, n_commit, n_accept, n_relaxed = verify_tree(
            tpl, draft.tokens, node_logits, rule=cfg.rule, mode=cfg.mode,
            theta=cfg.theta, temperature=cfg.temperature, key=k_verify,
            node_probs=draft.token_probs)
        n_commit = jnp.where(active, n_commit, 0)

        # commit pass: regular masked decode of [last_token, path...] writes
        # the accepted path into the cache (and computes features for sync)
        kk = tpl.k + 2
        commit_inputs = jnp.concatenate([last_token[:, None], out[:, :kk - 1]],
                                        1)
        commit_pos = base[:, None] + jnp.arange(kk, dtype=jnp.int32)[None]
        cmask = (jnp.arange(kk)[None] < n_accept[:, None] + 1) \
            & active[:, None]
        res = self.target.decode(t_params, commit_inputs, commit_pos, t_cache,
                                 token_mask=cmask,
                                 with_features=self.drafter.wants_features)
        if self.drafter.wants_features:
            _, t_cache, feats = res
        else:
            _, t_cache = res
            feats = None
        t_cache = dict(t_cache)
        t_cache["index"] = jnp.where(active, base + 1 + n_accept, base)

        # drafter sync: feature of the last committed (cached) token
        if self.drafter.wants_features and feats is not None:
            idx = jnp.clip(n_accept, 0, kk - 1)[:, None, None]
            feat = jnp.take_along_axis(
                feats, jnp.broadcast_to(idx, (b, 1, feats.shape[-1])), 1)[:, 0]
            feat = jnp.where(active[:, None], feat, d_state["feat"])
            d_state = {**d_state, "feat": feat.astype(d_state["feat"].dtype)}

        # buffer write
        l_buf = buf.shape[1] - 1
        n_commit = jnp.minimum(n_commit, jnp.maximum(l_buf - lengths, 0))
        wpos = lengths[:, None] + jnp.arange(kk, dtype=jnp.int32)[None]
        wvalid = (jnp.arange(kk)[None] < n_commit[:, None]) & (wpos < l_buf)
        wslot = jnp.where(wvalid, wpos, l_buf)
        buf = buf.at[jnp.arange(b)[:, None], wslot].set(out)
        lengths = lengths + n_commit
        finished = finished | (lengths >= l_buf)

        last_idx = jnp.clip(n_commit - 1, 0, kk - 1)
        new_last = jnp.take_along_axis(out, last_idx[:, None], 1)[:, 0]
        last_token = jnp.where(active, new_last, last_token)

        stats = {
            "cycles": stats["cycles"] + active.astype(jnp.int32),
            "commits": stats["commits"] + n_commit,
            "accepts": stats["accepts"] + jnp.where(active, n_accept, 0),
            "relaxed": stats["relaxed"] + jnp.where(active, n_relaxed, 0),
        }
        return (buf, lengths, finished, t_cache, d_state, last_token, key,
                stats)

    def generate(self, t_params, d_params, prompt, prompt_len, max_new, key):
        b, s = prompt.shape
        l_buf = s + max_new + self.cfg.k + 3
        buf = jnp.zeros((b, l_buf + 1), jnp.int32).at[:, :s].set(prompt)
        t_cache = self.target.init_cache(t_params, b, l_buf)
        d_state = self.drafter.init_state(d_params, b, l_buf)

        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        pmask = pos < (prompt_len - 1)[:, None]
        out = self.target.decode(t_params, prompt, pos, t_cache,
                                 token_mask=pmask,
                                 with_features=self.drafter.wants_features)
        if self.drafter.wants_features:
            _, t_cache, pfeats = out
            idx = jnp.clip(prompt_len - 2, 0, s - 1)[:, None, None]
            feat0 = jnp.take_along_axis(
                pfeats, jnp.broadcast_to(idx, (b, 1, pfeats.shape[-1])), 1)[:, 0]
            d_state = {**d_state, "feat": feat0.astype(d_state["feat"].dtype)}
        else:
            _, t_cache = out

        last_token = jnp.take_along_axis(
            prompt, jnp.clip(prompt_len - 1, 0, s - 1)[:, None], 1)[:, 0]
        stats = {k: jnp.zeros((b,), jnp.int32)
                 for k in ("cycles", "commits", "accepts", "relaxed")}
        carry = (buf, prompt_len, jnp.zeros((b,), bool), t_cache, d_state,
                 last_token, key, stats)

        def cond(st):
            return (~st[2]).any() & (st[7]["cycles"].max() < max_new)

        def body(st):
            return self.cycle(t_params, d_params, st)

        (buf, lengths, finished, _, _, _, _, stats) = jax.lax.while_loop(
            cond, body, carry)
        return {"tokens": buf[:, :-1], "lengths": jnp.minimum(lengths, l_buf),
                "finished": finished, "stats": stats}


def make_tree_generate_fn(target: Model, drafter, cfg: TreeEngineConfig):
    engine = TreeSpecEngine(target, drafter, cfg)

    @functools.partial(jax.jit, static_argnames=("max_new",))
    def generate(t_params, d_params, prompt, prompt_len, key, max_new=64):
        return engine.generate(t_params, d_params, prompt, prompt_len,
                               max_new, key)

    return generate
