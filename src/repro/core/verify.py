"""Verification rules for speculative decoding — the paper's contribution.

Three rules, all operating on the target logits computed in one parallel
pass over the draft chunk (paper Alg. 1):

* ``strict greedy``    — accept iff draft == target top-1.
* ``strict sampling``  — Leviathan et al. rejection sampling (lossless).
* ``MARS``             — greedy/sampling base rule + *adaptive relaxation*:
                          also accept when the draft equals the target top-2
                          AND the logit ratio r = z(2)/z(1) exceeds θ
                          (low-margin regime; default θ = 0.9).

The relaxation is only valid in the positive-logit regime the paper observes
(Fig. 4a): we additionally require z(1) > 0 and z(2) > 0 so that
r ∈ (0, 1] — this is the guard MARS' ratio definition presumes.

All functions are vectorised over batch and jit-friendly.  A fused Pallas
kernel implementing the top-2 + ratio + accept decision in one HBM pass over
the logits lives in ``repro.kernels.mars_verify``; this module is the
reference semantics (and the default CPU path).

Implementation selection is centralised in :class:`VerifyBackend`: every
verification path (chain and tree alike) obtains its exact-match and
relaxation masks from one dispatch point that picks the reference jnp path
or the fused Pallas kernel per call.  The kernel operates on a flattened
``(rows, V)`` layout, so chain chunks ``(B, K, V)`` and tree node logits
``(B, N, V)`` share the same code path.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding import constrain

DEFAULT_THETA = 0.9


class VerifyResult(NamedTuple):
    """Outcome of verifying one draft chunk.

    out_tokens : (B, K+1) committed tokens; entries past ``n_commit`` are
                 padding (repeat of the last valid token).
    n_commit   : (B,) number of valid tokens in out_tokens (= n_accept + 1:
                 accepted draft prefix plus correction-or-bonus token).
    n_accept   : (B,) accepted draft tokens.
    n_relaxed  : (B,) accepted positions that needed MARS relaxation.
    margin     : (B,) top-2 logit ratio at the first rejected position
                 (clipped to [1e-4, 1]); -1 when the row has no valid
                 margin sample (full accept, or the guard rejected the
                 ratio).  This is the on-device signal the serving theta
                 controller consumes — no host-side logit recompute.
    """
    out_tokens: jnp.ndarray
    n_commit: jnp.ndarray
    n_accept: jnp.ndarray
    n_relaxed: jnp.ndarray
    margin: jnp.ndarray


def top2_and_ratio(logits: jnp.ndarray, guard: str = "positive"):
    """Return (top1_idx, top2_idx, ratio, valid) for logits (..., V).

    guard="positive" (paper-faithful): ratio = z(2)/z(1), valid only in the
    positive-domain regime the paper observes for large LLMs (Fig. 4a).

    guard="margin" (our small-model extension, DESIGN.md §7): the paper's
    own equivalent form r = 1 - Δ/z(1) generalised with |z(1)|, i.e.
    r = 1 - (z1 - z2)/max(|z1|, eps) — identical to z2/z1 when z1 > 0 and
    sign-safe otherwise.  Needed because sub-100M-parameter targets trained
    briefly do not yet exhibit the positive-logit dominance of 8B+ LLMs."""
    vals, idx = jax.lax.top_k(logits, 2)
    z1, z2 = vals[..., 0], vals[..., 1]
    if guard == "margin":
        valid = jnp.ones_like(z1, bool)
        ratio = 1.0 - (z1 - z2) / jnp.maximum(jnp.abs(z1), 1e-6)
    else:
        valid = (z1 > 0.0) & (z2 > 0.0)
        ratio = jnp.where(valid, z2 / jnp.maximum(z1, 1e-30), 0.0)
    return idx[..., 0], idx[..., 1], ratio, valid


def mars_relax_mask(draft_tokens: jnp.ndarray, target_logits: jnp.ndarray,
                    theta, guard: str = "positive") -> jnp.ndarray:
    """(B, K) mask of positions acceptable via adaptive relaxation.

    ``theta`` is a scalar or a per-row ``(B,)`` vector (the serving layer's
    per-slot thresholds)."""
    _, top2, ratio, valid = top2_and_ratio(target_logits, guard)
    return (draft_tokens == top2) & valid & (ratio > _temp_like(theta,
                                                               ratio.ndim))


# ---------------------------------------------------------------------------
# VerifyBackend — the single reference-vs-kernel dispatch point
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class VerifyBackend:
    """Per-call selection of the verification implementation.

    ``use_kernel=True`` routes the top-2 + accept decision through the fused
    Pallas kernel (``repro.kernels.mars_verify``) whenever its semantics
    apply — the kernel hard-codes the paper's positive-logit guard, so the
    ``guard="margin"`` small-model extension always falls back to the
    reference path.  Inputs may carry any leading shape: ``(B, K)`` chain
    chunks and ``(B, N)`` tree nodes are both flattened to the kernel's
    ``(rows, V)`` layout.
    """
    use_kernel: bool = False
    guard: str = "positive"

    @property
    def kind(self) -> str:
        return ("kernel" if self.use_kernel and self.guard == "positive"
                else "reference")

    def exact_and_relax(self, draft_tokens: jnp.ndarray,
                        target_logits: jnp.ndarray, theta,
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Masks (draft == target top-1) and (MARS-relaxable), any leading
        shape; ``target_logits`` has one trailing vocab axis.  ``theta`` is
        a scalar or a per-row ``(B,)`` vector broadcast over the trailing
        draft positions."""
        exact, relax, _, _ = self.exact_relax_margin(draft_tokens,
                                                     target_logits, theta)
        return exact, relax

    def exact_relax_margin(self, draft_tokens: jnp.ndarray,
                           target_logits: jnp.ndarray, theta):
        """Like :meth:`exact_and_relax` but additionally returns the top-2
        logit ratio and its validity mask per position — the margin signal
        the serving controller accumulates.  Both implementations hand it
        back for free: the reference path already top-k's, and the kernel
        already streams z1/z2 through VMEM."""
        if self.kind == "kernel":
            from repro.kernels import ops as kops
            v = target_logits.shape[-1]
            shape = draft_tokens.shape
            lead = shape[0] if len(shape) > 1 else 1
            d2 = draft_tokens.reshape(lead, -1)
            l2 = target_logits.reshape(lead, -1, v)
            exact, relax, _, _, z1, z2 = kops.mars_verify_stats(d2, l2, theta)
            valid = (z1 > 0.0) & (z2 > 0.0)
            ratio = jnp.where(valid, z2 / jnp.maximum(z1, 1e-30), 0.0)
            rs = lambda x: x.reshape(shape)
            return rs(exact), rs(relax), rs(ratio), rs(valid)
        # one top-k pass yields both masks (top-1 for exact, top-2 + ratio
        # for the relaxation) — no separate argmax scan over the vocab
        top1, top2, ratio, valid = top2_and_ratio(target_logits, self.guard)
        exact = draft_tokens == top1
        relax = ((draft_tokens == top2) & valid
                 & (ratio > _temp_like(theta, ratio.ndim)))
        return exact, relax, ratio, valid


def resolve_backend(backend: Optional[VerifyBackend] = None, *,
                    use_kernel: bool = False, guard: str = "positive",
                    ) -> VerifyBackend:
    """Normalise the (backend | use_kernel/guard kwargs) calling conventions."""
    if backend is not None:
        return backend
    return VerifyBackend(use_kernel=use_kernel, guard=guard)


def _temp_like(temperature, ndim: int) -> jnp.ndarray:
    """Broadcast a scalar or per-row ``(B,)`` temperature against logits of
    rank ``ndim`` (trailing vocab axis).  Per-row temperatures are how the
    serving layer threads ``SamplingParams.temperature`` through the shared
    device-resident carry without a per-request recompile."""
    t = jnp.asarray(temperature, jnp.float32)
    return t.reshape(t.shape + (1,) * (ndim - t.ndim))


def margin_at_first_rejection(ratio, valid, n_accept, k: int):
    """Per-row margin sample: the top-2 logit ratio at the first rejected
    position (clipped to [1e-4, 1] so zero stays a reserved "no sample yet"
    EMA sentinel), or -1 when the row fully accepted / the guard held no
    valid ratio there.  ``ratio``/``valid`` are (B, K); n_accept (B,)."""
    first_rej = jnp.minimum(n_accept, k - 1)[:, None]
    m = jnp.take_along_axis(ratio, first_rej, axis=1)[:, 0]
    mv = jnp.take_along_axis(valid, first_rej, axis=1)[:, 0]
    has_rej = n_accept < k
    return jnp.where(has_rej & mv, jnp.clip(m, 1e-4, 1.0), -1.0)


def _accept_sampling(draft_tokens, target_logits, draft_token_probs,
                     key, temperature):
    """Leviathan accept: u < p(v)/q(v) with p the (temperature-scaled)
    target distribution and q the drafter's probability of its own sample."""
    t = _temp_like(temperature, target_logits.ndim)
    logp = jax.nn.log_softmax(
        target_logits.astype(jnp.float32) / jnp.maximum(t, 1e-6),
        axis=-1)
    p_draft = jnp.exp(
        jnp.take_along_axis(logp, draft_tokens[..., None], axis=-1))[..., 0]
    u = jax.random.uniform(key, draft_tokens.shape)
    return u * jnp.maximum(draft_token_probs, 1e-30) < p_draft


def _correction_token(target_logits_all, n_accept, *, mode, key, temperature,
                      draft_full_probs=None):
    """Token emitted at the first rejected position (or the bonus position
    when the whole draft is accepted).

    target_logits_all: (B, K+1, V) — position K is the bonus distribution.
    For exact lossless sampling the residual (p - q)+ is used when the full
    draft distribution is available; the bonus token always samples from p.
    """
    b, kp1, v = target_logits_all.shape
    k = kp1 - 1
    sel = jnp.take_along_axis(
        target_logits_all, n_accept[:, None, None], axis=1)[:, 0]  # (B, V)
    # the ONE point in verification that needs the full vocab row per slot:
    # under a mesh the accept masks above run on vocab-sharded logits, but
    # the categorical/argmax below samples across the whole vocabulary —
    # annotate the selected row as vocab-unsharded so the all-gather happens
    # here, on (B, V), and not on the (B, K+1, V) chunk (no-op off-mesh)
    sel = constrain(sel, "batch", None)
    if mode == "greedy":
        return jnp.argmax(sel, axis=-1).astype(jnp.int32)

    t = _temp_like(temperature, sel.ndim)
    logp = jax.nn.log_softmax(
        sel.astype(jnp.float32) / jnp.maximum(t, 1e-6), axis=-1)
    p = jnp.exp(logp)
    if draft_full_probs is not None:
        # residual distribution at the rejected position
        qpad = jnp.concatenate(
            [draft_full_probs, jnp.zeros((b, 1, v), draft_full_probs.dtype)],
            axis=1)
        q = jnp.take_along_axis(qpad, n_accept[:, None, None], axis=1)[:, 0]
        is_bonus = (n_accept == k)[:, None]
        resid = jnp.maximum(p - jnp.where(is_bonus, 0.0, q), 0.0)
        resid = resid / jnp.maximum(resid.sum(-1, keepdims=True), 1e-30)
        dist = jnp.log(jnp.maximum(resid, 1e-30))
    else:
        dist = logp
    return jax.random.categorical(key, dist, axis=-1).astype(jnp.int32)


def verify_chain(draft_tokens: jnp.ndarray,
                 target_logits: jnp.ndarray,
                 *,
                 rule: str = "mars",
                 mode: str = "sample",
                 theta=DEFAULT_THETA,
                 temperature=1.0,
                 key: Optional[jnp.ndarray] = None,
                 draft_token_probs: Optional[jnp.ndarray] = None,
                 draft_full_probs: Optional[jnp.ndarray] = None,
                 use_kernel: bool = False,
                 guard: str = "positive",
                 backend: Optional[VerifyBackend] = None,
                 ) -> VerifyResult:
    """Verify a chain draft.

    draft_tokens  : (B, K)
    target_logits : (B, K+1, V); row i is the target distribution for the
                    token *at draft position i* (row K = bonus distribution).
    rule          : "strict" | "mars"
    mode          : "greedy" | "sample"
    theta         : scalar or per-row ``(B,)`` vector — the serving layer
                    passes the per-slot relaxation thresholds it carries on
                    device (same contract as ``temperature``).
    temperature   : scalar or per-row ``(B,)`` vector — the serving layer
                    passes the per-slot temperatures it carries on device.
    backend       : optional :class:`VerifyBackend`; when None one is built
                    from ``use_kernel``/``guard``.
    """
    b, k = draft_tokens.shape
    assert target_logits.shape[1] == k + 1
    if key is None:
        key = jax.random.PRNGKey(0)
    k_acc, k_corr = jax.random.split(key)
    backend = resolve_backend(backend, use_kernel=use_kernel, guard=guard)

    logits_at_draft = target_logits[:, :k]
    need_relax = rule == "mars"
    ratio = valid = None
    if mode == "greedy" or need_relax:
        exact, relax, ratio, valid = backend.exact_relax_margin(
            draft_tokens, logits_at_draft, theta)

    if mode == "greedy":
        accept = exact
    else:
        if draft_token_probs is None:
            raise ValueError("sampling verification needs draft_token_probs")
        accept = _accept_sampling(draft_tokens, logits_at_draft,
                                  draft_token_probs, k_acc, temperature)

    relaxed = jnp.zeros_like(accept)
    if need_relax:
        relaxed = relax & ~accept
        accept = accept | relax

    run = jnp.cumprod(accept.astype(jnp.int32), axis=1)
    n_accept = jnp.sum(run, axis=1)                           # (B,)
    n_relaxed = jnp.sum(run * relaxed.astype(jnp.int32), axis=1)

    if ratio is not None:
        margin = margin_at_first_rejection(ratio, valid, n_accept, k)
    else:           # strict sampling: no top-2 pass ran, no margin signal
        margin = jnp.full((b,), -1.0, jnp.float32)

    extra = _correction_token(
        target_logits, n_accept, mode=mode, key=k_corr,
        temperature=temperature, draft_full_probs=draft_full_probs)

    # assemble out_tokens: accepted draft prefix + extra token + padding
    pos = jnp.arange(k + 1)[None]                             # (1, K+1)
    draft_pad = jnp.concatenate(
        [draft_tokens, draft_tokens[:, -1:]], axis=1)
    out = jnp.where(pos < n_accept[:, None], draft_pad, extra[:, None])
    out = jnp.where(pos > n_accept[:, None], extra[:, None], out)
    n_commit = n_accept + 1
    return VerifyResult(out.astype(jnp.int32), n_commit, n_accept, n_relaxed,
                        margin)
