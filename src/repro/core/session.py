"""Shared speculative-decoding engine core: one draft → verify → commit cycle.

Every consumer of speculative decoding in this repo — ``SpecEngine`` batch
generation, tree decoding, and the continuous-batching ``SpecServer`` — runs
the same cycle over the same carry.  This module owns that cycle once:

* :class:`DecodeState` — the carry pytree (token buffer, lengths, finished
  flags, target cache, drafter state, pending last token, PRNG key, stats,
  per-slot remaining token budget, per-slot verification temperature).
  Budgets and temperatures are *device-resident serving state*: ``cycle``
  clamps commits to the budget and flips ``finished`` on-device, so a
  scheduler can run many fused cycles between host polls.
* :class:`DecodeSession` — prefill (full-batch and slot-masked admission),
  one jit-traceable ``cycle``, EOS/buffer-commit bookkeeping, and cache
  rollback; parameterised by a *draft topology* strategy.
* :class:`ChainTopology` — K-token chain drafts scored with one parallel
  target decode (the pass MARS amortises).
* ``TreeTopology`` (in ``repro.core.tree``) — caterpillar tree drafts scored
  with one virtual tree-attention pass.

Cache-layout invariant: ``cache.index`` counts tokens whose kv/state is
stored; the *pending* last committed token is not yet in the cache and is
the first input of the next cycle.  The target cache may be the dense
per-slot ring or the paged block-table layout
(``init_state(..., paged=PagedCacheConfig(...))``); the session is
layout-agnostic — both satisfy the same invariant.

Rollback scheme (shared by all topologies via :meth:`DecodeSession.rollback`):

* attention-family targets whose score pass wrote draft kv into the cache
  roll back by **index rewind** — stale slots past ``base + 1 + n_accept``
  are masked by position and overwritten later.  Under the paged layout the
  rewind is the device half of a *block-list truncate*: the slot keeps its
  (worst-case, admission-reserved) blocks mid-flight with stale entries
  position-masked inside them, and the host drops the whole list's
  references back to the ``BlockPool`` when it harvests the finished
  request (``paging.used_blocks`` computes the live prefix for finer
  truncation).  With the serving prefix cache a slot's leading blocks may
  be *shared* (refcounted, mapped read-only at ``prefill(start_pos=)``);
  every write — speculative drafts included — lands at positions ≥ the
  cached-prefix start, so the rewind range lies in private blocks only
  and sharing never constrains rollback;
* recurrent targets (ssm / hybrid) and virtual (non-writing) score passes
  **recompute**: re-apply ``[last_token, committed...]`` from the pre-cycle
  state with a token mask, so the cache only ever holds committed tokens.

Topology hook: a topology implements ``buffer_margin`` (buffer slack beyond
``max_new``) and ``run(session, t_params, d_params, state, extras, k_draft,
k_verify, theta, active)`` returning a :class:`CycleOutcome`; the session
reads the cycle width off ``out_tokens`` and applies the shared EOS
truncation, buffer commit, pending-token update, and stats.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import verify as V
from repro.core.drafter import Committed
from repro.models.model import Model
from repro.sharding import constrain

STAT_KEYS = ("cycles", "commits", "accepts", "relaxed")
# Float stats ride the same per-slot dict: ``margin_ema`` is an EMA of the
# top-2 logit ratio at each cycle's first rejection (0 = no sample yet) —
# the on-device margin signal the serving theta controller reads at harvest.
MARGIN_EMA_DECAY = 0.875


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    k: int = 7                       # draft length (paper default)
    rule: str = "mars"               # "strict" | "mars"
    mode: str = "sample"             # "greedy" | "sample"
    theta: float = V.DEFAULT_THETA
    temperature: float = 1.0
    eos_token: Optional[int] = None
    use_kernel: bool = False         # fused Pallas mars_verify
    guard: str = "positive"          # "positive" (paper) | "margin" (ext.)
    topology: str = "chain"          # "chain" | "tree"
    branch: int = 2                  # tree only: candidates per depth

    def backend(self) -> V.VerifyBackend:
        return V.VerifyBackend(use_kernel=self.use_kernel, guard=self.guard)


NO_BUDGET = jnp.int32(2**30)     # "unlimited" per-slot token budget


class DecodeState(NamedTuple):
    """The decode carry.  A NamedTuple so it is simultaneously a pytree
    (while_loop / jit friendly) and positionally unpackable.

    All *per-request serving state* lives here, on device: ``budget``,
    ``temperature``, and ``theta`` extend the historical 8-tuple so a
    scheduler tick never has to round-trip through the host to enforce
    ``max_tokens``, per-request sampling temperature, or the per-request
    MARS relaxation threshold — ``cycle`` clamps commits to the budget,
    decrements it, and flips ``finished`` on-device, and verification reads
    each row's own ``theta`` (set at admission, retuned by the serving
    controller between tick groups — never mid-group)."""
    buf: jnp.ndarray            # (B, L+1) committed tokens (+1 trash slot)
    lengths: jnp.ndarray        # (B,) committed length incl. prompt
    finished: jnp.ndarray       # (B,) bool; True == idle/finished slot
    t_cache: Any                # target cache pytree
    d_state: Any                # drafter state pytree
    last_token: jnp.ndarray     # (B,) pending token (not yet in cache)
    key: jnp.ndarray            # PRNG key
    stats: Dict[str, jnp.ndarray]
    budget: jnp.ndarray         # (B,) remaining new tokens this request may emit
    temperature: jnp.ndarray    # (B,) per-slot verification temperature
    theta: jnp.ndarray          # (B,) per-slot MARS relaxation threshold


class CycleOutcome(NamedTuple):
    """What a topology hands back to the session after one cycle.

    ``d_state`` is pre-sync: the session calls ``drafter.sync`` itself after
    EOS truncation and buffer clamping so the ``Committed`` record carries
    the final ``n_commit`` (the drafter contract)."""
    out_tokens: jnp.ndarray     # (B, W) committed tokens (padded past n_commit)
    n_accept: jnp.ndarray       # (B,) accepted draft tokens
    n_commit: jnp.ndarray       # (B,) valid tokens in out_tokens
    n_relaxed: jnp.ndarray      # (B,) accepts that needed MARS relaxation
    t_cache: Any
    d_state: Any
    base_index: jnp.ndarray     # (B,) target cache index pre-cycle
    features: Any = None        # (B, W, d) target features or None
    margin: Any = None          # (B,) first-rejection top-2 ratio (-1 = none)


# ---------------------------------------------------------------------------
# Topologies
# ---------------------------------------------------------------------------

class ChainTopology:
    """K-token chain drafts, scored by one parallel target decode pass that
    writes into the cache (rolled back afterwards by the session)."""

    name = "chain"

    def __init__(self, cfg: EngineConfig):
        self.cfg = cfg
        self.k = cfg.k

    @property
    def buffer_margin(self) -> int:
        return self.k + 2

    @property
    def commit_width(self) -> int:
        """Most tokens one cycle can commit (accepted chain + correction)."""
        return self.k + 1

    def run(self, session: "DecodeSession", t_params, d_params,
            state: DecodeState, extras, k_draft, k_verify, theta,
            active) -> CycleOutcome:
        cfg = self.cfg
        k = self.k
        target, drafter = session.target, session.drafter
        b = state.last_token.shape[0]

        # 1. draft
        d_out, d_state = drafter.draft(
            d_params, state.d_state, state.last_token, extras, k_draft)

        # 2. target parallel pass over [last_token, d_1..d_K]
        base_index = state.t_cache["index"]
        inputs = jnp.concatenate(
            [state.last_token[:, None], d_out.tokens], axis=1)
        positions = (base_index[:, None]
                     + jnp.arange(k + 1, dtype=jnp.int32)[None])
        mask = jnp.broadcast_to(active[:, None], (b, k + 1))
        pre_cache = state.t_cache
        res_t = target.decode(
            t_params, inputs, positions, state.t_cache, token_mask=mask,
            with_features=drafter.wants_features)
        if drafter.wants_features:
            logits, t_cache, feats = res_t
        else:
            logits, t_cache = res_t
            feats = None

        # 3. verify
        res = V.verify_chain(
            d_out.tokens, logits, rule=cfg.rule, mode=cfg.mode,
            theta=theta, temperature=state.temperature, key=k_verify,
            draft_token_probs=d_out.token_probs,
            draft_full_probs=d_out.full_probs,
            backend=cfg.backend())

        # 4. cache rollback (drafter sync happens in the session, once the
        #    final n_commit is known)
        t_cache, _ = session.rollback(
            t_params, pre_cache, t_cache, inputs, positions, res.n_accept,
            active, base_index, scored_in_place=True, want_features=False)

        return CycleOutcome(res.out_tokens, res.n_accept, res.n_commit,
                            res.n_relaxed, t_cache, d_state, base_index,
                            features=feats, margin=res.margin)


def _make_topology(cfg: EngineConfig):
    if cfg.topology == "chain":
        return ChainTopology(cfg)
    if cfg.topology == "tree":
        from repro.core.tree import TreeTopology
        return TreeTopology(cfg)
    raise ValueError(f"unknown topology {cfg.topology!r}")


# ---------------------------------------------------------------------------
# Session
# ---------------------------------------------------------------------------

class DecodeSession:
    """The shared draft → verify → commit engine core.

    ``SpecEngine``, ``TreeSpecEngine``, and ``SpecServer`` are thin wrappers
    over this class; they share its carry (:class:`DecodeState`), its cycle,
    and its rollback — so a verification or bookkeeping improvement lands in
    every consumer at once.
    """

    def __init__(self, target: Model, drafter, cfg: EngineConfig):
        self.target = target
        self.drafter = drafter
        self.cfg = cfg
        self.topology = _make_topology(cfg)
        if cfg.topology == "tree":
            if target.is_recurrent:
                raise NotImplementedError(
                    "tree verification needs attention-family targets; use "
                    "the chain topology for ssm/hybrid")
            if not hasattr(drafter, "_step"):
                raise TypeError(
                    "tree topology drafts with the EAGLE-style step head; "
                    f"{type(drafter).__name__} does not expose one")

    # -- state construction ---------------------------------------------------
    def init_state(self, t_params, d_params, batch: int, max_len: int, *,
                   key=None, encoder_frames=None, paged=None,
                   paged_shards: int = 1) -> DecodeState:
        """Fresh all-idle carry (``finished`` everywhere); rows come alive
        via :meth:`prefill`.

        ``paged`` (a :class:`repro.models.paging.PagedCacheConfig`) builds
        the target cache over a shared block pool instead of dense per-slot
        rings.  Paged slots start *unmapped*: admission must hand
        :meth:`prefill` the freshly allocated ``block_rows`` before any KV
        can persist.  ``paged_shards`` routes each slot's masked writes to
        a shard-local trash block on a serving mesh.  The drafter keeps its
        own (small, dense) state either way."""
        if key is None:
            key = jax.random.PRNGKey(0)
        return DecodeState(
            buf=jnp.zeros((batch, max_len + 1), jnp.int32),  # +1 trash slot
            lengths=jnp.zeros((batch,), jnp.int32),
            finished=jnp.ones((batch,), bool),
            t_cache=self.target.init_cache(t_params, batch, max_len,
                                           encoder_frames=encoder_frames,
                                           paged=paged,
                                           paged_shards=paged_shards),
            d_state=self.drafter.init_state(d_params, batch, max_len),
            last_token=jnp.zeros((batch,), jnp.int32),
            key=key,
            stats={**{k: jnp.zeros((batch,), jnp.int32) for k in STAT_KEYS},
                   "margin_ema": jnp.zeros((batch,), jnp.float32)},
            budget=jnp.full((batch,), NO_BUDGET, jnp.int32),
            temperature=jnp.full((batch,), self.cfg.temperature, jnp.float32),
            theta=jnp.full((batch,), self.cfg.theta, jnp.float32),
        )

    def prefill(self, t_params, d_params, state: DecodeState,
                prompt: jnp.ndarray, prompt_len: jnp.ndarray,
                slot_mask: Optional[jnp.ndarray] = None,
                budget=None, temperature=None, theta=None,
                block_rows=None, start_pos=None,
                cow_src=None, cow_dst=None,
                decode_tokens=None, decode_off=None) -> DecodeState:
        """Admit prompts into the rows of ``slot_mask`` (None = all rows).

        Resets the admitted rows' caches, writes the prompt into the buffer,
        prefills ``prompt[:-1]`` with a slot-masked decode (the final prompt
        token stays pending), and grounds feature-carrying drafters.  Rows
        outside the mask are untouched, so mid-flight admissions never
        disturb in-flight neighbours.

        ``budget`` (scalar or (B,)) sets the admitted rows' remaining-token
        budget (None = unlimited); ``temperature`` (scalar or (B,)) their
        verification temperature and ``theta`` (scalar or (B,)) their MARS
        relaxation threshold (None = the config defaults).  All three live
        in the device carry, so admission is the only time the host
        supplies per-request serving state (the serving theta controller
        may later retune ``theta`` between tick groups).

        ``block_rows`` (B, max_blocks) maps the admitted rows of a *paged*
        target cache to their freshly allocated physical blocks before the
        prompt KV is written; the scheduler allocates them from its
        ``BlockPool`` and frees them again at harvest.

        Cached-prefix admission (serving prefix cache, paged caches only):
        ``start_pos`` (B,) says the first ``start_pos[b]`` prompt tokens of
        each admitted row already have KV in the pool — their blocks ride
        in read-only through ``block_rows`` — so the prompt decode is
        *partial*: it runs from the divergence point only, with the cached
        positions seeded valid and ``index`` pre-set to ``start_pos``.
        ``cow_src``/``cow_dst`` (B,) clone a partially matching shared tail
        block into the slot's private block *before* any write lands
        (copy-on-write); slots with nothing to clone pass their trash id
        for both.  ``decode_tokens`` (B, W) + ``decode_off`` (scalar)
        restrict the prompt decode to the host-sliced window
        ``prompt[:, off:off+W]`` — the un-cached tail across all admitted
        rows, which is where the prefill FLOPs are actually saved (the jit
        re-specialises per window width, so callers bucket W); the caller
        guarantees ``off + W == S`` and ``off <= min(start_pos)`` over
        admitted rows.
        """
        state = DecodeState(*state)
        b, s = prompt.shape
        if slot_mask is None:
            slot_mask = jnp.ones((b,), bool)
        if budget is None:
            budget = NO_BUDGET
        if temperature is None:
            temperature = self.cfg.temperature
        if theta is None:
            theta = self.cfg.theta
        budget_row = jnp.broadcast_to(
            jnp.asarray(budget, jnp.int32), (b,))
        temp_row = jnp.broadcast_to(
            jnp.asarray(temperature, jnp.float32), (b,))
        theta_row = jnp.broadcast_to(
            jnp.asarray(theta, jnp.float32), (b,))
        new_budget = jnp.where(slot_mask, budget_row, state.budget)
        new_temp = jnp.where(slot_mask, temp_row, state.temperature)
        new_theta = jnp.where(slot_mask, theta_row, state.theta)

        t_cache = self.target.reset_slots(state.t_cache, slot_mask)
        if block_rows is not None:
            # map the admitted rows' block tables BEFORE the prompt decode
            # below — a paged slot left unmapped drops its writes into the
            # trash block
            t_cache = self.target.assign_blocks(t_cache, slot_mask,
                                                block_rows)
        if cow_src is not None:
            t_cache = self.target.clone_blocks(
                t_cache, jnp.asarray(cow_src, jnp.int32),
                jnp.asarray(cow_dst, jnp.int32))
        start = None
        if start_pos is not None:
            start = jnp.asarray(start_pos, jnp.int32)
            t_cache = self.target.seed_prefix(t_cache, slot_mask, start)
        d_state = self.drafter.reset_slots(state.d_state, slot_mask)

        width = state.buf.shape[1]
        row = jnp.pad(prompt, ((0, 0), (0, width - s)))
        buf = constrain(jnp.where(slot_mask[:, None], row, state.buf),
                        "batch", None)
        lengths = jnp.where(slot_mask, prompt_len, state.lengths)
        finished = jnp.where(slot_mask, False, state.finished)
        stats = {k: jnp.where(slot_mask, 0, v)
                 for k, v in state.stats.items()}

        if decode_tokens is None:
            off = jnp.int32(0)
            tok_win, w = prompt, s
        else:
            off = jnp.asarray(decode_off, jnp.int32)
            tok_win = decode_tokens
            w = tok_win.shape[1]
        pos = off + jnp.broadcast_to(
            jnp.arange(w, dtype=jnp.int32)[None], (b, w))
        pmask = slot_mask[:, None] & (pos < (prompt_len - 1)[:, None])
        if start is not None:
            # cached prefix: decode only from each row's divergence point
            pmask = pmask & (pos >= start[:, None])
        out = self.target.decode(t_params, tok_win, pos, t_cache,
                                 token_mask=pmask,
                                 with_features=self.drafter.wants_features)
        if self.drafter.wants_features:
            _, t_cache, pfeats = out
            # ground the drafter feature on the last *cached* prompt token
            # (window-relative; the scheduler never lets a cached prefix
            # swallow it for feature-carrying drafters)
            idx = jnp.clip(prompt_len - 2 - off, 0, w - 1)[:, None, None]
            feat0 = jnp.take_along_axis(
                pfeats, jnp.broadcast_to(idx, (b, 1, pfeats.shape[-1])),
                1)[:, 0]
            if "feat" in d_state:
                feat = jnp.where(slot_mask[:, None],
                                 feat0.astype(d_state["feat"].dtype),
                                 d_state["feat"])
                d_state = {**d_state, "feat": feat}
        else:
            _, t_cache = out
        d_state = self.drafter.prefill(d_params, d_state, prompt, prompt_len,
                                       slot_mask=slot_mask)

        last = jnp.take_along_axis(
            prompt, jnp.clip(prompt_len - 1, 0, s - 1)[:, None], 1)[:, 0]
        last_token = jnp.where(slot_mask, last, state.last_token)
        return DecodeState(buf, lengths, finished, t_cache, d_state,
                           last_token, state.key, stats,
                           new_budget, new_temp, new_theta)

    # -- cache rollback (shared by all topologies) ----------------------------
    def rollback(self, t_params, pre_cache, post_cache, inputs, positions,
                 n_accept, active, base_index, *, scored_in_place: bool,
                 want_features: bool):
        """Bring the target cache to exactly the committed prefix.

        ``scored_in_place`` marks that the score pass wrote the draft chunk
        into ``post_cache``; attention families then roll back by index
        rewind.  Recurrent families — and virtual score passes that never
        wrote (``post_cache`` is None) — re-apply ``inputs[:, :n_accept+1]``
        from ``pre_cache`` with a token mask instead.  Returns
        ``(cache, features-or-None)``; features cover ``inputs`` rows when a
        recompute ran with ``want_features``.
        """
        if scored_in_place and not self.target.is_recurrent:
            cache = dict(post_cache)
            cache["index"] = jnp.where(
                active, base_index + 1 + n_accept, base_index)
            return cache, None
        w = inputs.shape[1]
        rmask = ((jnp.arange(w, dtype=jnp.int32)[None]
                  < (n_accept + 1)[:, None]) & active[:, None])
        res = self.target.decode(t_params, inputs, positions, pre_cache,
                                 token_mask=rmask,
                                 with_features=want_features)
        if want_features:
            _, cache, feats = res
        else:
            (_, cache), feats = res, None
        cache = dict(cache)
        cache["index"] = jnp.where(
            active, base_index + 1 + n_accept, base_index)
        return cache, feats

    # -- one verify cycle (jit-traceable) -------------------------------------
    def cycle(self, t_params, d_params, state, theta=None) -> DecodeState:
        """``theta=None`` (the serving path) verifies each row against its
        own carried ``state.theta``; an explicit scalar-or-(B,) override
        (the offline sweep path) wins without touching the carry."""
        cfg = self.cfg
        state = DecodeState(*state)
        theta = state.theta if theta is None else theta
        b = state.last_token.shape[0]
        key, k_draft, k_verify = jax.random.split(state.key, 3)
        active = ~state.finished
        finished = state.finished

        extras = {
            "target_params": t_params,
            "tokens_buf": state.buf,
            "lengths": state.lengths,
            "index": state.t_cache["index"],
        }
        out = self.topology.run(self, t_params, d_params, state, extras,
                                k_draft, k_verify, theta, active)

        n_commit = jnp.where(active, out.n_commit, 0)
        w = out.out_tokens.shape[1]
        pos_k = jnp.arange(w, dtype=jnp.int32)[None]

        # EOS truncation
        if cfg.eos_token is not None:
            is_eos = ((out.out_tokens == cfg.eos_token)
                      & (pos_k < n_commit[:, None]))
            any_eos = is_eos.any(axis=1)
            first_eos = jnp.argmax(is_eos, axis=1)
            n_commit = jnp.where(any_eos,
                                 jnp.minimum(n_commit, first_eos + 1),
                                 n_commit)
            finished = finished | (any_eos & active)

        # commit tokens into the buffer (slot L = trash)
        l_buf = state.buf.shape[1] - 1
        # never count commits past the buffer end (the row finishes anyway)
        n_commit = jnp.minimum(n_commit,
                               jnp.maximum(l_buf - state.lengths, 0))
        # budget clamp: a request never emits more than its remaining token
        # budget; exhaustion flips ``finished`` on-device, so the serving
        # tick needs no host round-trip to enforce ``max_tokens``
        n_commit = jnp.minimum(n_commit, jnp.maximum(state.budget, 0))
        budget = state.budget - jnp.where(active, n_commit, 0)
        finished = finished | (active & (budget <= 0))
        wpos = state.lengths[:, None] + pos_k
        wvalid = (pos_k < n_commit[:, None]) & (wpos < l_buf)
        wslot = jnp.where(wvalid, wpos, l_buf)
        buf = state.buf.at[jnp.arange(b)[:, None], wslot].set(out.out_tokens)
        lengths = state.lengths + n_commit
        finished = finished | (lengths >= l_buf)
        # under a serving mesh the slot-indexed carry stays partitioned on
        # the data axis across cycles (no-op outside a rules context)
        buf = constrain(buf, "batch", None)
        lengths = constrain(lengths, "batch")
        finished = constrain(finished, "batch")
        budget = constrain(budget, "batch")

        # drafter sync sees the final (EOS-truncated, buffer-clamped)
        # n_commit, per the Committed contract
        committed = Committed(out.out_tokens, out.n_accept, n_commit,
                              out.base_index, features=out.features,
                              active=active)
        d_state = self.drafter.sync(d_params, out.d_state, committed, extras)

        # pending token for the next cycle; rows whose clamps forced
        # n_commit == 0 committed nothing, so out_tokens[:, 0] is garbage
        # for them — keep their previous pending token
        last_idx = jnp.clip(n_commit - 1, 0, w - 1)
        new_last = jnp.take_along_axis(
            out.out_tokens, last_idx[:, None], 1)[:, 0]
        last_token = jnp.where(active & (n_commit > 0), new_last,
                               state.last_token)

        stats = {
            "cycles": state.stats["cycles"] + active.astype(jnp.int32),
            "commits": state.stats["commits"] + n_commit,
            "accepts": state.stats["accepts"]
            + jnp.where(active, out.n_accept, 0),
            "relaxed": state.stats["relaxed"]
            + jnp.where(active, out.n_relaxed, 0),
        }
        # margin EMA: rows with a valid first-rejection ratio fold it in
        # (first sample replaces the 0 "unseen" sentinel); full-accept
        # cycles and strict-sampling verifies leave the EMA untouched
        ema = state.stats["margin_ema"]
        if out.margin is not None:
            sample = out.margin.astype(jnp.float32)
            folded = jnp.where(ema > 0,
                               MARGIN_EMA_DECAY * ema
                               + (1.0 - MARGIN_EMA_DECAY) * sample,
                               sample)
            ema = jnp.where(active & (sample >= 0), folded, ema)
            ema = constrain(ema, "batch")
        stats["margin_ema"] = ema
        return DecodeState(buf, lengths, finished, out.t_cache, d_state,
                           last_token, key, stats, budget,
                           state.temperature, state.theta)

    # -- fused multi-cycle group (jit-traceable) ------------------------------
    def run_group(self, t_params, d_params, state: DecodeState,
                  steps) -> DecodeState:
        """Run up to ``steps`` cycles as one fused ``lax.while_loop``.

        This is the body the serving tick dispatches: the carry is the
        whole :class:`DecodeState`, so a jit wrapper can donate it and the
        group runs device-side with zero host transfers.  The loop exits
        early on-device once every slot is finished, so an oversized
        ``steps`` costs nothing.  The scheduler's ring-refill variant
        (:func:`repro.serving.admission_ring.fused_cycles_with_refill`)
        wraps this same ``cycle`` with an in-loop masked prefill.
        """
        def cond(carry):
            i, st = carry
            return (i < steps) & (~DecodeState(*st).finished).any()

        def body(carry):
            i, st = carry
            return i + 1, tuple(self.cycle(t_params, d_params,
                                           DecodeState(*st)))

        _, out = jax.lax.while_loop(cond, body,
                                    (jnp.int32(0), tuple(state)))
        return DecodeState(*out)

    # -- full generation ------------------------------------------------------
    def generate(self, t_params, d_params, prompt: jnp.ndarray,
                 prompt_len: jnp.ndarray, max_new: int, key,
                 theta=None, encoder_frames=None,
                 paged=None) -> Dict[str, Any]:
        """prompt: (B, S) right-padded; prompt_len: (B,) valid lengths.

        ``paged`` (a :class:`repro.models.paging.PagedCacheConfig`) routes
        the target cache through the paged pool with a dense-equivalent
        static block assignment (``paging.full_tables``) — the offline path
        the fidelity harnesses use to measure a quantized pool
        (``kv_dtype="int8"``/``"fp8"``) against the dense cache; the
        config's ``n_blocks`` is overridden with the exact static-pool
        size."""
        b, s = prompt.shape
        l_buf = s + max_new + self.topology.buffer_margin
        block_rows = None
        if paged is not None and self.target.cfg.family != "ssm":
            # pure-ssm caches carry no pool/table leaves (zero-block
            # layout), so the static assignment below would be meaningless
            # there; everyone else gets a dense-equivalent table, bounded
            # by the sliding window when the config has one (the table is
            # then a ring of blocks that wraps).
            from repro.models.paging import full_tables
            mb = paged.table_blocks(l_buf,
                                    self.target.cfg.sliding_window or 0)
            paged = dataclasses.replace(paged, n_blocks=1 + b * mb)
            block_rows = full_tables(b, mb)
        state = self.init_state(t_params, d_params, b, l_buf, key=key,
                                encoder_frames=encoder_frames, paged=paged)
        state = self.prefill(t_params, d_params, state, prompt, prompt_len,
                             budget=max_new, block_rows=block_rows)

        max_cycles = max_new  # worst case: 1 committed token per cycle

        def cond(st):
            st = DecodeState(*st)
            return (~st.finished).any() & (st.stats["cycles"].max()
                                           < max_cycles)

        def body(st):
            return self.cycle(t_params, d_params, st, theta=theta)

        final = DecodeState(*jax.lax.while_loop(cond, body, state))
        return {
            "tokens": final.buf[:, :-1],
            "lengths": jnp.minimum(final.lengths, l_buf),
            "finished": final.finished,
            "stats": final.stats,
        }


def make_generate_fn(target: Model, drafter, cfg: EngineConfig, *,
                     paged=None):
    """Returns a jitted generate(t_params, d_params, prompt, prompt_len, key)
    for any topology the config names.  ``paged`` (a
    :class:`repro.models.paging.PagedCacheConfig`) makes every generation
    run through the paged pool — the fidelity harnesses' lever for
    comparing quantized KV storage against the dense baseline."""
    session = DecodeSession(target, drafter, cfg)

    @functools.partial(jax.jit, static_argnames=("max_new",))
    def generate(t_params, d_params, prompt, prompt_len, key, max_new=64,
                 theta=None, encoder_frames=None):
        if theta is None:
            theta = cfg.theta
        return session.generate(t_params, d_params, prompt, prompt_len,
                                max_new, key, theta=jnp.asarray(theta),
                                encoder_frames=encoder_frames, paged=paged)

    return generate
