"""Serving launcher: spin up a continuous-batching MARS server.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch granite-8b --smoke --rule mars --theta 0.9 \
        --slots 4 --requests 8

    # tree-draft serving (EAGLE-style drafter, caterpillar tree)
    PYTHONPATH=src python -m repro.launch.serve \
        --arch granite-8b --smoke --topology tree --branch 2 --k 3

    # mesh-partitioned tick: 2-way slot sharding x 2-way tensor parallelism
    # (on CPU force host devices BEFORE jax imports)
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m repro.launch.serve \
        --arch granite-8b --smoke --mesh 2,2

With ``--smoke`` the reduced config is instantiated with random weights
(engine demo); otherwise checkpoints are loaded from --ckpt-dir (trained
with repro.launch.train).  Both topologies run through the same shared
``DecodeSession`` engine core inside the server.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.checkpoint import latest_step, load_checkpoint
from repro.configs import get_config, get_smoke, list_archs
from repro.configs.base import ModelConfig
from repro.core import (EagleDrafter, EngineConfig, IndependentDrafter,
                        init_eagle_params)
from repro.models import build_model
from repro.serving import Request, SamplingParams, ServerConfig, SpecServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config, random weights")
    ap.add_argument("--ckpt-dir", default="experiments/models")
    ap.add_argument("--rule", default="mars", choices=["mars", "strict"])
    ap.add_argument("--theta", type=float, default=0.9)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--topology", default="chain", choices=["chain", "tree"])
    ap.add_argument("--branch", type=int, default=2,
                    help="tree topology: candidates per depth")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-tokens", type=int, default=32)
    ap.add_argument("--steps-per-sync", type=int, default=4,
                    help="max fused verify cycles per host poll when an "
                         "EOS token can preempt a slot early")
    ap.add_argument("--cache", default="dense", choices=["dense", "paged"],
                    help="KV layout: dense per-slot rings, or paged block "
                         "tables over a shared pool (admission gated by "
                         "pool headroom; see docs/SERVING.md)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged: tokens per KV block")
    ap.add_argument("--pool-blocks", type=int, default=0,
                    help="paged: physical blocks in the shared pool "
                         "(0 = dense-equivalent capacity)")
    ap.add_argument("--kv-dtype", default="bf16",
                    choices=["bf16", "int8", "fp8"],
                    help="paged only: KV pool storage dtype; int8/fp8 store "
                         "quantized blocks with per-token-head scales in a "
                         "parallel pool (equal HBM admits ~2x the requests; "
                         "see docs/SERVING.md)")
    ap.add_argument("--prefix-cache", default="off", choices=["off", "on"],
                    help="paged only: share published KV blocks between "
                         "requests with common token prefixes (refcounted "
                         "read-only mapping + copy-on-write; admission "
                         "prefills from the divergence point only)")
    ap.add_argument("--min-match-blocks", type=int, default=1,
                    help="prefix cache: smallest cached run (in blocks) "
                         "worth mapping shared")
    ap.add_argument("--prefix-cache-max-blocks", type=int, default=0,
                    help="prefix cache: cap on published-but-free blocks "
                         "parked in the reclaimable LRU (0 = bounded only "
                         "by the pool)")
    ap.add_argument("--prefix-cache-ttl", type=float, default=0.0,
                    help="prefix cache: seconds an unused parked block "
                         "survives before reclamation (0 = no TTL)")
    ap.add_argument("--theta-mode", default="fixed",
                    choices=["fixed", "adaptive"],
                    help="fixed: every slot verifies at --theta; adaptive: "
                         "a per-slot controller retunes theta at each sync "
                         "from on-device margin/acceptance stats "
                         "(see docs/SERVING.md)")
    ap.add_argument("--theta-min", type=float, default=0.6,
                    help="adaptive: most-relaxed threshold queue pressure "
                         "may reach")
    ap.add_argument("--theta-max", type=float, default=0.99,
                    help="adaptive: strictest threshold tightening may "
                         "reach")
    ap.add_argument("--relax-budget", type=float, default=0.25,
                    help="adaptive: tolerated relaxed share of accepted "
                         "tokens before a slot is tightened")
    ap.add_argument("--adaptive-k", action="store_true",
                    help="adaptive + chain only: let the controller drop "
                         "to a half-K draft when acceptance is low")
    ap.add_argument("--mesh", default=None, metavar="DATA,MODEL",
                    help="partition the serving tick over a (data, model) "
                         "mesh: slots shard over data, target/drafter "
                         "tensor dims over model (needs data*model "
                         "devices; see docs/SERVING.md)")
    ap.add_argument("--overlap", action="store_true",
                    help="double-buffer fused groups: keep two dispatches "
                         "in flight and harvest each group one group late, "
                         "overlapping drafter compute with the D2H read")
    ap.add_argument("--ring-depth", type=int, default=0,
                    help="admission-ring depth (0 = off): stage up to this "
                         "many queued prompts on device so the fused group "
                         "refills freed slots mid-group")
    ap.add_argument("--prefill-worker", action="store_true",
                    help="paged only: prefill cold prompts into pool "
                         "blocks with a separate jitted worker program so "
                         "admission decodes never widen for a cold admit")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write Prometheus text-exposition metrics here at "
                         "the end of the run (enables telemetry; see "
                         "docs/OBSERVABILITY.md)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Perfetto-loadable Chrome trace-event "
                         "JSON of the tick spans (admit/dispatch/harvest/"
                         "retune/gather) here")
    ap.add_argument("--events-out", default=None, metavar="PATH",
                    help="write the per-request lifecycle event log "
                         "(JSONL: submit/staged/admitted/first_commit/"
                         "retune/finish) here")
    args = ap.parse_args()

    mesh_shape = None
    if args.mesh:
        try:
            mesh_shape = tuple(int(x) for x in args.mesh.split(","))
            assert len(mesh_shape) == 2 and min(mesh_shape) >= 1
        except (ValueError, AssertionError):
            raise SystemExit(f"--mesh expects DATA,MODEL (got {args.mesh!r})")
        if args.slots % mesh_shape[0]:
            raise SystemExit(
                f"--slots {args.slots} must be divisible by the mesh data "
                f"axis ({mesh_shape[0]}) so every shard owns whole slots")

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    cfg = dataclasses.replace(cfg, dtype="float32")
    if args.prefix_cache == "on" and args.cache != "paged":
        raise SystemExit("--prefix-cache on requires --cache paged "
                         "(prefix reuse shares physical KV blocks)")
    if args.cache == "paged":
        # launcher-level fail-fast, kept for any future family the paged
        # layouts don't cover (every current family pages — hybrids page
        # their attention sub-cache, sliding-window layers wrap a ring of
        # blocks, pure-ssm routes through with a zero-block table)
        from repro.models.paging import paged_unsupported_reason
        reason = paged_unsupported_reason(cfg)
        if reason is not None:
            raise SystemExit(
                f"--cache paged is incompatible with --arch {args.arch}: "
                f"{reason}; use --cache dense")
    if args.kv_dtype != "bf16":
        if cfg.family == "ssm":
            raise SystemExit(f"--kv-dtype {args.kv_dtype} is unavailable "
                             f"for --arch {args.arch}: a pure-ssm target "
                             "has no attention KV pool to quantize")
        if args.cache != "paged":
            raise SystemExit(f"--kv-dtype {args.kv_dtype} requires --cache "
                             "paged (quantized storage lives in the block "
                             "pool); use --cache paged or --kv-dtype bf16")
        from repro.models.paging import kv_dtype_unsupported_reason
        reason = kv_dtype_unsupported_reason(args.kv_dtype)
        if reason is not None:
            raise SystemExit(
                f"--kv-dtype {args.kv_dtype} is unavailable for "
                f"--arch {args.arch}: {reason}")
    target = build_model(cfg)
    t_params = target.init(jax.random.PRNGKey(0))
    if not args.smoke:
        step = latest_step(args.ckpt_dir, name=args.arch)
        if step is None:
            raise SystemExit(f"no checkpoint for {args.arch} in "
                             f"{args.ckpt_dir}; train one or use --smoke")
        t_params = load_checkpoint(args.ckpt_dir, step, t_params,
                                   name=args.arch)

    # NOTE: the drafter is randomly initialised in both modes — this
    # launcher demos the serving engine; only the target loads checkpoints.
    # A random drafter just drives tau toward 1 (drafts mostly rejected).
    if args.topology == "tree":
        # tree drafts need the EAGLE-style step head
        drafter = EagleDrafter(target, k=args.k,
                               temperature=args.temperature)
        d_params = init_eagle_params(cfg, jax.random.PRNGKey(1))
        if not args.smoke:
            print("warning: serving with a randomly initialised EAGLE head "
                  "(no drafter checkpoint support); expect tau ~= 1")
    else:
        d_cfg = ModelConfig(name="draft", family="dense", n_layers=1,
                            d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
                            vocab_size=cfg.vocab_size, dtype="float32")
        draft = build_model(d_cfg)
        drafter = IndependentDrafter(draft, k=args.k,
                                     temperature=args.temperature)
        d_params = draft.init(jax.random.PRNGKey(1))

    telemetry = None
    if args.metrics_out or args.trace_out or args.events_out:
        from repro.obs import ServerTelemetry
        telemetry = ServerTelemetry()

    server = SpecServer(
        target, drafter, t_params, d_params,
        EngineConfig(k=args.k, rule=args.rule, theta=args.theta,
                     mode="sample" if args.temperature > 0 else "greedy",
                     temperature=args.temperature,
                     topology=args.topology, branch=args.branch),
        ServerConfig(slots=args.slots, max_len=256, max_prompt_len=32,
                     steps_per_sync=args.steps_per_sync, cache=args.cache,
                     block_size=args.block_size,
                     pool_blocks=args.pool_blocks, mesh=mesh_shape,
                     kv_dtype=args.kv_dtype,
                     prefix_cache=args.prefix_cache,
                     min_match_blocks=args.min_match_blocks,
                     prefix_cache_max_blocks=args.prefix_cache_max_blocks,
                     prefix_cache_ttl_s=args.prefix_cache_ttl,
                     theta_mode=args.theta_mode, theta_min=args.theta_min,
                     theta_max=args.theta_max,
                     relax_budget=args.relax_budget,
                     adaptive_k=args.adaptive_k,
                     overlap=args.overlap, ring_depth=args.ring_depth,
                     prefill_worker=args.prefill_worker),
        telemetry=telemetry)

    # per-request sampling params ride the device carry: each request may
    # ask for its own temperature and token budget
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        server.submit(Request(
            uid=i, prompt=rng.integers(3, cfg.vocab_size, 12).astype(np.int32),
            params=SamplingParams(max_tokens=args.max_tokens,
                                  temperature=args.temperature)))
    mesh_note = (f", mesh={mesh_shape[0]}x{mesh_shape[1]}" if mesh_shape
                 else "")
    kv_note = f", kv={args.kv_dtype}" if args.kv_dtype != "bf16" else ""
    theta_note = (f"θ=adaptive[{args.theta_min},{args.theta_max}]"
                  if args.theta_mode == "adaptive" else f"θ={args.theta}")
    print(f"serving {args.requests} requests "
          f"({args.topology}, {args.rule}, {theta_note}, K={args.k}, "
          f"cache={args.cache}{kv_note}{mesh_note}) ...")
    for r in sorted(server.run(), key=lambda r: r.uid):
        print(f"  req {r.uid:2d}: {len(r.tokens):3d} tokens "
              f"tau={r.tau:4.2f} latency={r.latency_s:5.2f}s")
    print(f"host syncs: {server.host_syncs} across {server.step_calls} "
          f"fused tick groups (tick loop itself is sync-free)")
    if args.overlap or args.ring_depth or args.prefill_worker:
        st = server.stats
        worker_note = (f", worker fills={server.worker.stats['fills']}"
                       if server.worker is not None else "")
        print(f"pipeline: ring refills={st['ring_refills']}, slot idle "
              f"ticks={st['slot_idle_ticks']}, harvest "
              f"gathers={st['gather_calls']}{worker_note}")
    if server.controller is not None:
        print(f"theta controller: {server.theta_retunes} retune dispatches, "
              f"final slot thetas "
              f"{np.round(server.slot_theta, 3).tolist()}")
    if server.prefix is not None:
        s = server.prefix.summary()
        print(f"prefix cache: hit rate {s['hit_rate']:.0%}, "
              f"{s['tokens_reused']}/{s['tokens_total']} prompt tokens "
              f"reused, {s['blocks_shared']} shared block mappings, "
              f"{s['cow_clones']} COW clones")
    if telemetry is not None:
        telemetry.write(args.metrics_out, args.trace_out, args.events_out)
        ts = telemetry.summary()

        def _ms(v):
            return f"{v * 1e3:.1f}ms" if v is not None else "n/a"
        print(f"telemetry: {ts['finished']} finished, TTFT "
              f"p50={_ms(ts['ttft_p50_s'])} p99={_ms(ts['ttft_p99_s'])}, "
              f"ITL p50={_ms(ts['itl_p50_s'])}, "
              f"{ts['span_events']} span events")
        if server.controller is not None:
            cs = server.controller.summary()
            print(f"  controller: {cs['updates']} updates, "
                  f"{cs['slots_tightened']} slot-steps tightened, "
                  f"{cs['slots_relaxed']} relaxed")
        for flag, path in (("--metrics-out", args.metrics_out),
                           ("--trace-out", args.trace_out),
                           ("--events-out", args.events_out)):
            if path:
                print(f"  wrote {flag[2:]}: {path}")


if __name__ == "__main__":
    main()
