"""Production mesh construction.

Function (not module-level constant) so importing never touches jax device
state.  The dry-run forces 512 host-platform devices; the single-pod mesh
uses the first 256 of them.

``make_serving_mesh`` is the serving-scale counterpart: a small
``(data, model)`` mesh sized to whatever devices exist, used by
``repro.serving.SpecServer`` to partition the sync-free tick (slots across
``data``, tensor parallelism across ``model``).  On CPU-only hosts the
usual way to get ≥2 devices is forcing host-platform devices *before jax is
imported*::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 python ...

(``host_device_count_flag`` builds that string; tier-1 mesh tests and the
serving benchmark's ``--mesh`` mode apply it via subprocess env / pre-import
environ mutation respectively.)
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def host_device_count_flag(n: int) -> str:
    """The XLA flag forcing ``n`` host-platform devices.  Must be in
    ``XLA_FLAGS`` before jax is imported — it cannot be applied
    retroactively, which is why the helpers here only *format* it."""
    return f"--xla_force_host_platform_device_count={n}"


def make_serving_mesh(data: int = 1, model: int = 1) -> Mesh:
    """A ``(data, model)`` mesh over the first ``data * model`` devices.

    ``data`` shards batch slots (embarrassingly parallel — each shard owns
    ``slots / data`` full requests), ``model`` shards the target/drafter
    tensor dims (heads / ff / vocab where divisible).  Raises with the
    host-device-forcing recipe when the process has too few devices."""
    if data < 1 or model < 1:
        raise ValueError(f"mesh axes must be >= 1, got ({data}, {model})")
    n = data * model
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"serving mesh ({data}, {model}) needs {n} devices, have "
            f"{len(devices)}; on CPU set XLA_FLAGS="
            f"{host_device_count_flag(n)} before importing jax")
    dev = np.asarray(devices[:n]).reshape(data, model)
    return Mesh(dev, ("data", "model"))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devices)} — the dry-run entrypoint "
            "must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax")
    dev = np.asarray(devices[:n]).reshape(shape)
    return Mesh(dev, axes)


def make_debug_mesh(shape=(1, 1), axes=("data", "model")) -> Mesh:
    """Tiny mesh over the real devices (tests on 1 CPU device)."""
    n = int(np.prod(shape))
    dev = np.asarray(jax.devices()[:n]).reshape(shape)
    return Mesh(dev, axes)
