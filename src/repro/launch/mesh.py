"""Production mesh construction.

Function (not module-level constant) so importing never touches jax device
state.  The dry-run forces 512 host-platform devices; the single-pod mesh
uses the first 256 of them.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devices)} — the dry-run entrypoint "
            "must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax")
    dev = np.asarray(devices[:n]).reshape(shape)
    return Mesh(dev, axes)


def make_debug_mesh(shape=(1, 1), axes=("data", "model")) -> Mesh:
    """Tiny mesh over the real devices (tests on 1 CPU device)."""
    n = int(np.prod(shape))
    dev = np.asarray(jax.devices()[:n]).reshape(shape)
    return Mesh(dev, axes)
