"""Sharding plan for the production mesh: logical-rule resolution per
(arch × shape), cache partition specs, and the abstract case builder used by
the dry-run.  Importable WITHOUT forcing 512 devices (tests use it too)."""
import dataclasses
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, get_shape, list_archs, SHAPES
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import build_model
from repro.optim import adamw
from repro.sharding import axis_rules, param_specs
from repro.sharding.rules import single_pod_rules
from repro.train.step import make_train_step

# Per-arch logical-rule overrides (see DESIGN.md §5):
#   dbrx: expert ff additionally sharded over "data" (weights don't fit TP16)
#   granite-moe: 40 experts ∤ 16 -> replicate experts; 24 heads ∤ 16 ->
#     replicate head activations (weights still shard on flat dims)
#   whisper: 20 heads ∤ 16 -> same
ARCH_OVERRIDES: Dict[str, Dict[str, Any]] = {
    "dbrx-132b": {"ff": "data"},
    # 40 experts ∤ 16, 24 heads ∤ 16, vocab 49155 ∤ 16
    "granite-moe-3b-a800m": {"experts": None, "heads": None, "ff": "model",
                             "vocab": None},
    # 20 heads ∤ 16; vocab 51866 ∤ 16 (133 MB table — replicate)
    "whisper-large-v3": {"heads": None, "vocab": None},
}

# Full-attention archs get a sliding-window variant for long_500k
FULL_ATTN_ARCHS = {"chatglm3-6b", "deepseek-67b", "starcoder2-15b",
                   "granite-8b", "chameleon-34b", "dbrx-132b"}
LONG_WINDOW = 4096


# §Perf variants — named rule tweaks applied on top of the baseline plan.
# Baselines are always recorded WITHOUT a variant; the perf loop re-lowers
# with one of these and compares roofline terms.
VARIANTS: Dict[str, Dict[str, Any]] = {
    # H4: see MODEL_VARIANTS["experts_pad48"] — re-enable expert sharding
    "experts_pad48": {"experts": "model", "ff": None},
    # H2: keep embedding/lm_head d_model dim unsharded during training so
    # the head matmul does not emit data-axis partial-sum logit all-reduces
    "head_nofsdp": {"fsdp_head": None},
    # H1: mlstm state sharded on batch only (dk-axis sharding forces a
    # per-layer state all-gather in the recurrence einsum)
    "mlstm_state_batch": {"mlstm_state_axis": None},
    # combinable: replicate the whole cache length (diagnostic)
    "kv_unsharded": {"kv_seq": None},
    # H3: shard kv cache length on data instead of model for decode
    "kv_on_data": {"kv_seq": "data"},
    # H1 iteration 3: tensor parallelism off entirely (weights replicated,
    # batch-parallel only) — for small models at decode the per-layer
    # model<->data activation all-to-alls cost more than re-reading weights
    "no_tp": {"heads": None, "ssm_heads": None, "ff": None, "vocab": None,
              "experts": None, "mlstm_state_axis": None, "kv_seq": None},
}

# Variants that change the MODEL (not the sharding rules): applied as
# dataclasses.replace on the arch config at build time.
MODEL_VARIANTS: Dict[str, Dict[str, Any]] = {
    # H4: see MODEL_VARIANTS["experts_pad48"] — re-enable expert sharding
    "experts_pad48": {"experts": "model", "ff": None},
    # H3: parallel attention+FFN residual -> one TP all-reduce per layer
    "parallel_block": {"parallel_residual": True},
    # H3 iteration 3: dynamic_update_slice cache writes (uniform index)
    "uniform_slots": {"cache_uniform_slots": True},
    # H3 combined best
    "verify_opt": {"parallel_residual": True, "cache_uniform_slots": True},
    # H4 (granite-moe): pad 40 experts to 48 so the expert dim shards on the
    # 16-way model axis (3/chip) — dispatch stays shard-local instead of
    # broadcasting every token's contribution to all replicas
    "experts_pad48": {"n_experts": 48},
}


def rules_for(arch: str, shape: ShapeConfig, *, multi_pod: bool,
              variant: Optional[str] = None):
    batch_axes: Any = ("pod", "data") if multi_pod else ("data",)
    if shape.global_batch == 1:
        batch_axes = None
    kind = shape.kind
    rules = single_pod_rules()
    rules["batch"] = batch_axes
    rules["fsdp"] = (("pod", "data") if multi_pod else ("data",)) \
        if kind == "train" else None
    rules["fsdp_head"] = rules["fsdp"]
    if kind == "decode":
        rules["kv_seq"] = "model" if shape.name == "decode_32k" else "data"
    else:
        rules["kv_seq"] = None
    ov = dict(ARCH_OVERRIDES.get(arch, {}))
    if kind == "train" and ov.get("ff") == "data":
        ov["ff"] = None          # fsdp already owns "data" for weights
    rules.update(ov)
    if variant and variant in VARIANTS:
        rules.update(VARIANTS[variant])
    return rules


def _bf16_structs(tree):
    def cast(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(x.shape, jnp.bfloat16)
        return jax.ShapeDtypeStruct(x.shape, x.dtype)
    return jax.tree.map(cast, tree)


def _cache_spec_for_path(path: str, ndim: int, rules) -> P:
    b = rules.get("batch")
    kv = rules.get("kv_seq")
    sh = rules.get("ssm_heads")
    kvh = rules.get("kv_heads")

    def pad(spec):
        return P(*([None] * (ndim - len(spec)) + list(spec)))

    if path.endswith("index"):
        return P(b)
    if "cross_k" in path or "cross_v" in path:
        return pad([b, None, None, None])
    # paged layout: the pool is partitioned under BOTH serving axes —
    # physical blocks across "data" (each data shard's slots reference only
    # the block range its per-shard free list owns), KV heads across
    # "model"; tables and logical positions are slot-indexed like the carry.
    # The same leaf names cover every paged family: a hybrid's attention
    # sub-cache and a sliding-window ring-of-blocks table differ only in
    # width, and pure-ssm caches simply have no pool/table leaves (their
    # recurrent leaves match the mamba/mlstm/slstm patterns below)
    if path.endswith("k_pool") or path.endswith("v_pool"):
        return pad([rules.get("pool_blocks"), None, kvh, None])
    # quantized pool: the scale pool shards exactly like its parent —
    # physical blocks on "data", KV heads on "model" (no head_dim)
    if path.endswith("k_scale") or path.endswith("v_scale"):
        return pad([rules.get("pool_blocks"), None, kvh])
    if path.endswith("table"):
        return pad([b, None])
    if path.endswith("trash"):            # per-slot trash block id
        return pad([b])
    if path.endswith("/k") or path.endswith("/v"):
        return pad([b, kv, kvh, None])
    if path.endswith("pos"):
        return pad([b, kv])
    if path.endswith("feat"):                     # EAGLE/Medusa drafter state
        return pad([b, None])
    if "mamba/conv" in path:
        return pad([b, None, sh])
    if "mamba/state" in path:
        return pad([b, sh, None, None])
    if "mlstm/state" in path:
        st = rules.get("mlstm_state_axis", sh)
        return pad([b, None, st, None])   # shard dk (baseline)
    if "mlstm/m" in path:
        return pad([b, None])
    if "slstm/" in path:
        return pad([b, None])
    return P()


def cache_specs(cache_struct, rules):
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_struct)
    specs = []
    for pth, leaf in flat:
        name = "/".join(str(getattr(k, "key", k)) for k in pth)
        specs.append(_cache_spec_for_path(name, leaf.ndim, rules))
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# Serving carry partition specs (the sharded sync-free tick)
# ---------------------------------------------------------------------------

# DecodeState fields whose leading dim is the batch-slot dim.
_SLOT_FIELDS = ("buf", "lengths", "finished", "last_token", "budget",
                "temperature", "theta", "stats")


def decode_state_specs(state, rules):
    """PartitionSpec pytree for a :class:`repro.core.session.DecodeState`
    carry under ``rules``: every slot-indexed field (token buffer, lengths,
    finished flags, budgets, temperatures, thetas, stats) shards its
    leading dim on the batch axes; the target cache and drafter state resolve per leaf via
    :func:`cache_specs` path matching (incl. the paged pool); the PRNG key
    is replicated.  Returns the same NamedTuple type with specs as leaves.
    """
    b = rules.get("batch")

    def slot_spec(leaf):
        return P(*([b] + [None] * (leaf.ndim - 1)))

    out = {}
    for name, sub in state._asdict().items():
        if name in ("t_cache", "d_state"):
            out[name] = cache_specs(sub, rules)
        elif name in _SLOT_FIELDS:
            out[name] = jax.tree.map(slot_spec, sub)
        else:                                    # PRNG key and friends
            out[name] = jax.tree.map(lambda _: P(), sub)
    return type(state)(**out)


def tree_shardings(tree, specs, mesh):
    """Zip a value pytree with a same-structure PartitionSpec pytree into
    NamedShardings, sanitising each spec per-dim against the leaf shape —
    non-dividing mappings are dropped so the result is valid for
    ``device_put``/``in_shardings``/``out_shardings`` (which reject uneven
    shardings) even when e.g. a drafter's KV-head count does not divide the
    model axis."""
    from repro.sharding.rules import sanitize_spec

    flat_t, treedef = jax.tree_util.tree_flatten(tree)
    flat_s = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    assert len(flat_t) == len(flat_s), "specs tree does not mirror values"
    out = [NamedSharding(mesh, sanitize_spec(sp, leaf.shape, mesh))
           for leaf, sp in zip(flat_t, flat_s)]
    return jax.tree_util.tree_unflatten(treedef, out)


def decode_state_shardings(state, mesh, rules):
    """NamedSharding pytree for the serving carry (see
    :func:`decode_state_specs` / :func:`tree_shardings`)."""
    return tree_shardings(state, decode_state_specs(state, rules), mesh)


def param_shardings(params, mesh, rules):
    """NamedSharding pytree for a param tree under ``rules`` (path-matched
    via :func:`repro.sharding.param_specs`, shape-sanitised)."""
    from repro.sharding import axis_rules, param_specs

    with axis_rules(rules):
        specs = param_specs(params, mesh=mesh)
    return tree_shardings(params, specs, mesh)


def replicated_shardings(tree, mesh):
    """Fully replicated NamedShardings mirroring ``tree`` — the plan for
    host-facing serving side-cars that every shard must see whole: the
    device-side admission ring (staged prompts are consumed by whichever
    data shard owns the freed slot) and the pipelined tick's harvest
    snapshots (the host reads them without a gather)."""
    repl = NamedSharding(mesh, P())
    return jax.tree_util.tree_map(lambda _: repl, tree)


def build_case(arch: str, shape_name: str, *, multi_pod: bool,
               verify_tokens: int = 1, variant=None):
    """Returns (fn, arg_structs, in_specs, rules, meta)."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    rules = rules_for(arch, shape, multi_pod=multi_pod, variant=variant)

    window = None
    if shape.name == "long_500k" and arch in FULL_ATTN_ARCHS:
        window = LONG_WINDOW
    if variant and variant in MODEL_VARIANTS:
        cfg = dataclasses.replace(cfg, **MODEL_VARIANTS[variant])
    model = build_model(cfg, sliding_window=window)

    rng = jax.random.PRNGKey(0)
    params_struct = jax.eval_shape(model.init, rng)
    b, s = shape.global_batch, shape.seq_len

    meta = {"arch": arch, "shape": shape_name, "variant": variant,
            "kind": shape.kind, "multi_pod": multi_pod,
            "verify_tokens": verify_tokens,
            "params": int(sum(np.prod(x.shape)
                              for x in jax.tree.leaves(params_struct))),
            "window": window}

    with axis_rules(rules):
        pspecs = param_specs(params_struct)

    if shape.kind == "train":
        tx = adamw(1e-4)
        opt_struct = jax.eval_shape(tx.init, params_struct)
        # mu/nu mirror param specs; step replicated
        from repro.optim.adamw import AdamWState
        with axis_rules(rules):
            opt_specs = AdamWState(P(), param_specs(params_struct),
                                   param_specs(params_struct))
        batch_struct = {"tokens": jax.ShapeDtypeStruct((b, s + 1), jnp.int32)}
        if cfg.family == "audio":
            batch_struct["encoder_frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
        bspecs = {"tokens": P(rules["batch"], None)}
        if cfg.family == "audio":
            bspecs["encoder_frames"] = P(rules["batch"], None, None)
        remat_policy = "dots" if variant == "remat_dots" else None
        step = make_train_step(model, tx, remat=True,
                               remat_policy=remat_policy)

        def fn(params, opt_state, batch):
            return step(params, opt_state, batch)

        args = (params_struct, opt_struct, batch_struct)
        specs = (pspecs, opt_specs, bspecs)

    elif shape.kind == "prefill":
        params_struct = _bf16_structs(params_struct)
        batch_struct = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cfg.family == "audio":
            batch_struct["encoder_frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
        bspecs = {"tokens": P(rules["batch"], None)}
        if cfg.family == "audio":
            bspecs["encoder_frames"] = P(rules["batch"], None, None)

        def fn(params, batch):
            logits, aux = model.forward(params, batch)
            return logits

        args = (params_struct, batch_struct)
        specs = (pspecs, bspecs)

    else:  # decode
        params_struct = _bf16_structs(params_struct)
        enc_struct = None
        if cfg.family == "audio":
            enc_struct = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
        cache_struct = jax.eval_shape(
            lambda p, f: model.init_cache(p, b, s, encoder_frames=f),
            params_struct, enc_struct)
        cspecs = cache_specs(cache_struct, rules)
        t = verify_tokens
        tok_struct = jax.ShapeDtypeStruct((b, t), jnp.int32)

        def fn(params, tokens, cache):
            positions = cache["index"][:, None] + \
                jnp.arange(t, dtype=jnp.int32)[None]
            logits, new_cache = model.decode(params, tokens, positions, cache)
            return logits, new_cache

        args = (params_struct, tok_struct, cache_struct)
        specs = (pspecs, P(rules["batch"], None), cspecs)

    return fn, args, specs, rules, meta, model


def model_flops(cfg: ModelConfig, shape: ShapeConfig,
                verify_tokens: int = 1) -> float:
    """6·N·D (train) / 2·N_active·D (inference) reference FLOPs."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch * verify_tokens


