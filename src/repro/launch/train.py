"""Training launcher: train a (reduced) assigned architecture on the
synthetic corpus and checkpoint it for the serving launcher.

    PYTHONPATH=src python -m repro.launch.train \
        --arch granite-8b --steps 200 --batch 8 --seq 64

Full configs are exercised through the multi-pod dry-run
(repro.launch.dryrun); this launcher runs REAL steps at CPU scale.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.checkpoint import save_checkpoint
from repro.configs import get_smoke, list_archs
from repro.data import MarkovCorpus, make_lm_batches
from repro.models import build_model
from repro.train import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--vocab", type=int, default=64)
    ap.add_argument("--corpus-temp", type=float, default=1.2)
    ap.add_argument("--ckpt-dir", default="experiments/models")
    ap.add_argument("--remat", action="store_true")
    args = ap.parse_args()

    cfg = dataclasses.replace(get_smoke(args.arch), dtype="float32",
                              vocab_size=args.vocab)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"training {args.arch} (reduced, {n:,} params) "
          f"for {args.steps} steps ...")

    corpus = MarkovCorpus(vocab_size=args.vocab,
                          temperature=args.corpus_temp, seed=0)
    trainer = Trainer(model, TrainerConfig(
        lr=args.lr, warmup_steps=min(20, args.steps // 10 + 1),
        total_steps=args.steps, log_every=max(args.steps // 10, 1),
        remat=args.remat))
    batches = make_lm_batches(corpus, batch=args.batch, seq_len=args.seq,
                              n_batches=args.steps)
    params, hist = trainer.fit(params, batches)
    path = save_checkpoint(args.ckpt_dir, args.steps, params, name=args.arch)
    print(f"checkpoint: {path}")
    print(f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
