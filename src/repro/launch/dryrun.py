import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production mesh and extract roofline inputs.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b \
        --shape decode_32k [--multi-pod] [--out experiments/dryrun]

For every combination this:
  1. builds the 16x16 (or 2x16x16) mesh over 512 forced host devices;
  2. builds abstract params / optimizer / cache / batch (ShapeDtypeStruct —
     nothing is allocated);
  3. jit-lowers the right step (train_step / forward-prefill / serve_step)
     with explicit NamedShardings from the logical axis rules;
  4. ``.compile()`` — a sharding mismatch, OOM-at-compile or unsupported
     collective fails here, which is the point of the exercise;
  5. records memory_analysis / cost_analysis / collective bytes to JSON.

NOTE the XLA_FLAGS assignment above MUST run before jax initialises — this
module must not be imported after jax.devices() has been called elsewhere.
Smoke tests and benchmarks do NOT import this module, so they see 1 device.
"""
import argparse
import dataclasses
import json
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, get_shape, list_archs, SHAPES
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.optim import adamw
from repro.sharding import axis_rules, param_specs
from repro.sharding.rules import single_pod_rules
from repro.train.step import make_train_step
from repro.launch.shardplan import (ARCH_OVERRIDES, FULL_ATTN_ARCHS,
    LONG_WINDOW, build_case, cache_specs, model_flops, rules_for)
from repro.utils.costs import analytic_bytes, analytic_flops
from repro.utils.hlo import (collective_bytes,
    collective_bytes_loop_aware, duplicate_collectives)
from repro.utils.lowering import dryrun_lowering

# TPU v5e constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

def run_case(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: Optional[str] = None, verify_tokens: int = 1,
             save_hlo: bool = False, variant: Optional[str] = None,
             ) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    fn, args, specs, rules, meta, model = build_case(
        arch, shape_name, multi_pod=multi_pod, verify_tokens=verify_tokens,
        variant=variant)
    shape = get_shape(shape_name)

    def to_shardings(spec_tree, arg_tree):
        return jax.tree.map(
            lambda s, a: NamedSharding(mesh, s if isinstance(s, P) else P()),
            spec_tree, arg_tree,
            is_leaf=lambda x: isinstance(x, P) or x is None)

    t0 = time.time()
    result: Dict[str, Any] = dict(meta, mesh="2x16x16" if multi_pod else "16x16",
                                  chips=n_chips, ok=False)
    # decode_32k lowers with python-unrolled layers + loop-free attention
    # (exact HLO costs); the other shapes keep the production lax.scan
    # lowering (fast compiles) and correct in-loop collectives by trip count
    # (utils.hlo.collective_bytes_loop_aware) — compute/memory terms use the
    # analytic model either way.
    attn_chunk = (1 << 22) if shape.kind == "decode" else None
    unroll = shape.name == "decode_32k"
    try:
        in_shardings = tuple(to_shardings(s, a)
                             for s, a in zip(specs, args))
        with jax.set_mesh(mesh):
            with axis_rules(rules), dryrun_lowering(
                    unroll_layers=unroll, attn_chunk=attn_chunk):
                lowered = jax.jit(fn, in_shardings=in_shardings).lower(*args)
                t_lower = time.time() - t0
                compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        if unroll:
            coll, counts = collective_bytes(hlo, default_group=16)
        else:
            coll, counts = collective_bytes_loop_aware(hlo, default_group=16)
        dup = duplicate_collectives(hlo)

        cfg_full = get_config(arch)
        flops = float(cost.get("flops", 0.0))
        bytes_acc = float(cost.get("bytes accessed", 0.0))
        a_flops = analytic_flops(cfg_full, shape, window=meta["window"],
                                 verify_tokens=verify_tokens)
        a_bytes = analytic_bytes(cfg_full, shape, window=meta["window"],
                                 verify_tokens=verify_tokens)
        mflops = model_flops(cfg_full, shape, verify_tokens)
        coll_total = float(sum(coll.values()))

        # roofline terms (per-chip seconds).  Compute/memory use whichever of
        # {HLO, analytic} is LARGER: HLO undercounts loop bodies, the
        # analytic model can miss compiler-introduced work — max() is the
        # honest bound.  Collectives come from the (loop-free-layers) HLO.
        eff_flops = max(flops, a_flops)
        eff_bytes = max(bytes_acc, a_bytes)

        result.update(
            ok=True,
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            hlo_flops=flops, hlo_bytes=bytes_acc,
            analytic_flops=a_flops, analytic_bytes=a_bytes,
            model_flops=mflops,
            flops_ratio=(mflops / eff_flops if eff_flops else None),
            collective_bytes=coll, collective_counts=counts,
            collective_bytes_total=coll_total,
            duplicate_collectives=dup,
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_bytes": getattr(
                    mem, "generated_code_size_in_bytes", None),
            },
            roofline={
                "compute_s": eff_flops / n_chips / PEAK_FLOPS,
                "memory_s": eff_bytes / n_chips / HBM_BW,
                # collective bytes are already per-participant estimates
                "collective_s": coll_total / ICI_BW,
            },
        )
        terms = result["roofline"]
        result["bottleneck"] = max(terms, key=lambda k: terms[k])
        if save_hlo and out_dir:
            os.makedirs(out_dir, exist_ok=True)
            with open(os.path.join(
                    out_dir, f"{arch}_{shape_name}_"
                    f"{'mp' if multi_pod else 'sp'}.hlo.txt"), "w") as f:
                f.write(hlo)
    except Exception as e:  # noqa: BLE001 — report compile failures
        result["error"] = f"{type(e).__name__}: {e}"[:2000]

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        vtag = f"_{variant}" if variant else ""
        ttag = f"_t{verify_tokens}" if verify_tokens != 1 else ""
        fname = (f"{arch}_{shape_name}_{'mp' if multi_pod else 'sp'}"
                 f"{vtag}{ttag}.json")
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(result, f, indent=2, default=str)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list_archs() + [None])
    ap.add_argument("--shape", default=None,
                    choices=[s.name for s in SHAPES] + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--verify-tokens", type=int, default=1)
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--variant", default=None)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else [s.name for s in SHAPES]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                if args.skip_existing and args.out:
                    vtag = f"_{args.variant}" if args.variant else ""
                    ttag = (f"_t{args.verify_tokens}"
                            if args.verify_tokens != 1 else "")
                    fname = os.path.join(
                        args.out, f"{arch}_{shape}_"
                        f"{'mp' if mp else 'sp'}{vtag}{ttag}.json")
                    if os.path.exists(fname):
                        try:
                            ok = json.load(open(fname)).get("ok")
                        except Exception:
                            ok = False
                        if ok:
                            print(f"SKIP {arch} × {shape} × "
                                  f"{'2x16x16' if mp else '16x16'}")
                            continue
                r = run_case(arch, shape, multi_pod=mp, out_dir=args.out,
                             verify_tokens=args.verify_tokens,
                             save_hlo=args.save_hlo, variant=args.variant)
                tag = f"{arch} × {shape} × {'2x16x16' if mp else '16x16'}"
                if r["ok"]:
                    rf = r["roofline"]
                    print(f"OK   {tag}: bottleneck={r['bottleneck']} "
                          f"compute={rf['compute_s']:.3e}s "
                          f"memory={rf['memory_s']:.3e}s "
                          f"coll={rf['collective_s']:.3e}s "
                          f"(compile {r['compile_s']}s)")
                else:
                    n_fail += 1
                    print(f"FAIL {tag}: {r['error'][:300]}")
    if n_fail:
        raise SystemExit(f"{n_fail} dry-run failures")


if __name__ == "__main__":
    main()
