"""AdamW + gradient clipping in pure JAX (optax is not in this environment).

API mirrors optax: ``tx = adamw(lr_schedule)``, ``state = tx.init(params)``,
``updates, state = tx.update(grads, state, params)``."""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Union

import jax
import jax.numpy as jnp

Schedule = Union[float, Callable[[jnp.ndarray], jnp.ndarray]]


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: object
    nu: object


@dataclasses.dataclass(frozen=True)
class Transform:
    init: Callable
    update: Callable


def _lr_at(lr: Schedule, step):
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


def adamw(lr: Schedule, *, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          max_grad_norm: float = 1.0) -> Transform:
    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return AdamWState(jnp.zeros((), jnp.int32), z,
                          jax.tree.map(jnp.zeros_like, z))

    def update(grads, state: AdamWState, params):
        step = state.step + 1
        if max_grad_norm > 0:
            grads = clip_by_global_norm(grads, max_grad_norm)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr_t = _lr_at(lr, step)

        def upd(m, v, p):
            mhat = m / bc1
            vhat = v / bc2
            u = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay > 0 and p.ndim >= 2:   # decay matrices only
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr_t * u).astype(p.dtype)

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, AdamWState(step, mu, nu)

    return Transform(init, update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))
