from repro.optim.adamw import adamw, apply_updates, clip_by_global_norm
from repro.optim.schedule import cosine_schedule, linear_warmup

__all__ = ["adamw", "apply_updates", "clip_by_global_norm",
           "cosine_schedule", "linear_warmup"]
