"""Dry-run lowering mode.

XLA's ``cost_analysis`` counts a while-loop body ONCE regardless of trip
count, so a depth-L scanned model reports ~1/L of its FLOPs.  For the
roofline extraction the dry-run activates this mode, which makes the model
code (a) python-unroll the *layer* loops so per-layer work is counted
exactly, and (b) widen attention chunks so decode attention is a single
block (loop-free, exact).  Production execution keeps ``lax.scan``.

Prefill/train attention & SSD chunk loops intentionally stay scanned (their
unrolled HLO would be quadratic in blocks); their compute/memory terms are
supplemented analytically in ``repro.utils.costs`` — collectives are
unaffected because no collective ops live inside those chunk loops (the
kv_seq axis is only sharded for decode shapes, which are loop-free here).
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional

import jax
import jax.numpy as jnp

_UNROLL = contextvars.ContextVar("repro_unroll_layers", default=False)
_ATTN_CHUNK = contextvars.ContextVar("repro_attn_chunk", default=None)


@contextlib.contextmanager
def dryrun_lowering(*, unroll_layers: bool = True,
                    attn_chunk: Optional[int] = None):
    t1 = _UNROLL.set(unroll_layers)
    t2 = _ATTN_CHUNK.set(attn_chunk)
    try:
        yield
    finally:
        _UNROLL.reset(t1)
        _ATTN_CHUNK.reset(t2)


def unroll_layers() -> bool:
    return _UNROLL.get()


def attn_chunk_override() -> Optional[int]:
    return _ATTN_CHUNK.get()


def maybe_scan(body, carry, xs):
    """lax.scan in production; python unroll in dry-run lowering mode."""
    if not _UNROLL.get():
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        stacked = None
    return carry, stacked
