"""Analytic FLOP / byte models per architecture family.

XLA's cost_analysis counts while-loop bodies once, so for the scanned
prefill/train chunk loops the HLO numbers undercount.  These closed-form
models supply the roofline compute/memory terms; HLO numbers are reported
alongside (exact for the loop-free decode lowering).

All numbers are whole-program (sum over chips); the roofline divides by
chip count.  Conventions:

* matmul FLOPs = 2 * params_touched * tokens (fwd), x3 for train (bwd).
* attention FLOPs = 4 * Σ_ctx * H * hd  (QK^T + AV, causal-exact).
* SSD/mLSTM intra-chunk ≈ 4 * heads * chunk/2 * (N + P) per token plus the
  O(N*P) state update.
* bytes: weights read once per step (FSDP gathers don't change HBM reads),
  KV cache fully streamed per decode step, activations ~c_act tensors of
  (tokens, d) per layer for prefill (x3 + optimizer traffic for train).
"""
from __future__ import annotations

from typing import Optional

from repro.configs.base import ModelConfig, ShapeConfig

_DT = {"bfloat16": 2, "float32": 4, "float16": 2}


def _attn_ctx_sum(s: int, window: int) -> float:
    """Σ_pos ctx(pos) for causal (optionally windowed) self-attention."""
    if window and window < s:
        return window * s - window * (window - 1) / 2.0
    return s * (s + 1) / 2.0


def _n_attn_layers(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.hybrid_attn_every
    if cfg.family == "ssm":
        return 0
    return cfg.n_layers


def _matmul_params(cfg: ModelConfig, *, active: bool = True) -> float:
    n = cfg.active_param_count() if active else cfg.param_count()
    emb_gather = cfg.vocab_size * cfg.d_model
    if not cfg.tie_embeddings:
        n -= emb_gather          # the gather-side table is not a matmul
    return float(n)


def _ssm_flops_per_token(cfg: ModelConfig) -> float:
    """Per-token recurrence FLOPs (excluding projections, already counted)."""
    if cfg.family == "hybrid":
        nh, n, p, q = cfg.n_ssm_heads, cfg.ssm_state, cfg.ssm_head_dim, cfg.ssm_chunk
        n_ssm_layers = cfg.n_layers
        per = nh * (2 * (q / 2) * (n + p) + 6 * n * p)
        return per * n_ssm_layers
    if cfg.family == "ssm":
        din = 2 * cfg.d_model
        nh = cfg.n_heads
        dk = din // nh
        q = cfg.ssm_chunk
        n_m = cfg.n_layers - cfg.n_layers // cfg.slstm_every
        per = nh * (2 * (q / 2) * (dk + dk) + 6 * dk * dk)
        return per * n_m
    return 0.0


def analytic_flops(cfg: ModelConfig, shape: ShapeConfig, *,
                   window: Optional[int] = None,
                   verify_tokens: int = 1) -> float:
    w = window if window is not None else cfg.sliding_window
    h, hd = cfg.n_heads, cfg.head_dim
    la = _n_attn_layers(cfg)

    if shape.kind in ("train", "prefill"):
        tokens = shape.global_batch * shape.seq_len
        mm = 2.0 * _matmul_params(cfg) * tokens
        attn = 4.0 * _attn_ctx_sum(shape.seq_len, w) * h * hd * la \
            * shape.global_batch
        if cfg.family == "audio":
            se = cfg.encoder_seq_len
            attn += 4.0 * se * se * h * hd * cfg.n_encoder_layers \
                * shape.global_batch                      # encoder, non-causal
            attn += 4.0 * shape.seq_len * se * h * hd * cfg.n_layers \
                * shape.global_batch                      # cross attention
            mm += 2.0 * _matmul_params(cfg) * 0           # enc counted in params
        ssm = _ssm_flops_per_token(cfg) * tokens
        total = mm + attn + ssm
        return 3.0 * total if shape.kind == "train" else total

    # decode: verify_tokens new tokens against a seq_len context
    tokens = shape.global_batch * verify_tokens
    ctx = min(shape.seq_len, w) if w else shape.seq_len
    mm = 2.0 * _matmul_params(cfg) * tokens
    attn = 4.0 * ctx * h * hd * la * tokens
    if cfg.family == "audio":
        attn += 4.0 * cfg.encoder_seq_len * h * hd * cfg.n_layers * tokens
    ssm = 0.0
    if cfg.family == "hybrid":
        ssm = cfg.n_layers * cfg.n_ssm_heads * 6 * cfg.ssm_state \
            * cfg.ssm_head_dim * tokens
    elif cfg.family == "ssm":
        din = 2 * cfg.d_model
        dk = din // cfg.n_heads
        n_m = cfg.n_layers - cfg.n_layers // cfg.slstm_every
        ssm = n_m * cfg.n_heads * 6 * dk * dk * tokens
    return mm + attn + ssm


def cache_bytes(cfg: ModelConfig, shape: ShapeConfig, *,
                window: Optional[int] = None) -> float:
    w = window if window is not None else cfg.sliding_window
    b = shape.global_batch
    dt = _DT.get(cfg.dtype, 2)
    total = 0.0
    la = _n_attn_layers(cfg)
    if la:
        length = min(shape.seq_len, w) if w else shape.seq_len
        total += 2.0 * la * b * length * cfg.n_kv_heads * cfg.head_dim * dt
    if cfg.family == "hybrid":
        total += cfg.n_layers * b * cfg.n_ssm_heads * cfg.ssm_state \
            * cfg.ssm_head_dim * 4
    if cfg.family == "ssm":
        din = 2 * cfg.d_model
        dk = din // cfg.n_heads
        n_m = cfg.n_layers - cfg.n_layers // cfg.slstm_every
        total += n_m * cfg.n_heads * b * dk * (dk + 1) * 4
    if cfg.family == "audio":
        total += 2.0 * cfg.n_layers * b * cfg.encoder_seq_len \
            * cfg.n_kv_heads * cfg.head_dim * dt
    return total


def analytic_bytes(cfg: ModelConfig, shape: ShapeConfig, *,
                   window: Optional[int] = None,
                   verify_tokens: int = 1) -> float:
    dt = _DT.get(cfg.dtype, 2)
    params = cfg.param_count()

    if shape.kind == "decode":
        # weights once + cache streamed once + new kv written
        return params * dt + cache_bytes(cfg, shape, window=window) \
            + shape.global_batch * verify_tokens * cfg.d_model * dt * 4

    tokens = shape.global_batch * shape.seq_len
    c_act = 8  # residual/attn/ffn intermediates per layer (write+read)
    act = tokens * cfg.d_model * dt * c_act * cfg.n_layers
    logits = tokens * cfg.vocab_size * dt
    if shape.kind == "prefill":
        return params * dt + act + logits
    # train: fwd read + bwd read + grad write (bf16-ish) + fp32 master/opt
    opt = params * 4 * 4          # p32, g32, mu, nu read+write amortised
    return params * dt * 3 + opt + 2.5 * act + 3 * logits
