"""HLO-text analysis: collective byte accounting for the roofline.

``compiled.cost_analysis()`` reports FLOPs and HBM bytes but not collective
traffic, so we parse the (post-SPMD-partitioning) HLO text and sum, per
collective kind, the bytes each op moves over ICI using ring-algorithm
estimates:

  all-gather       out_bytes * (N-1)/N      (each chip receives out*(N-1)/N)
  reduce-scatter   in_bytes  * (N-1)/N
  all-reduce       2 * bytes * (N-1)/N      (ring RS + AG)
  all-to-all       bytes * (N-1)/N
  collective-permute  bytes

N is taken from the op's replica_groups when parseable, else the worst-case
mesh axis size supplied by the caller.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\(|\w).*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", )
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str, *, default_group: int = 16,
                     ) -> Tuple[Dict[str, int], Dict[str, int]]:
    """Returns (ici_bytes_per_kind, op_counts).

    ici bytes are per-participating-device estimates (ring algorithms)."""
    bytes_by_kind: Dict[str, int] = defaultdict(int)
    counts: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_text, kind = m.group(1), m.group(2)
        size = _shape_bytes(shape_text)
        if size == 0:
            continue
        n = default_group
        g = _GROUPS_RE.search(line)
        if g:
            n = max(len(g.group(1).split(",")), 1)
        else:
            g2 = _GROUPS_V2_RE.search(line)
            if g2:
                n = max(int(g2.group(2)), 1)
        frac = (n - 1) / n
        if kind == "all-gather":
            moved = size * frac
        elif kind == "reduce-scatter":
            moved = size * frac  # size parsed is the (larger) input? output —
            # HLO lists the output; input = output * n
            moved = size * (n - 1)
        elif kind == "all-reduce":
            moved = 2 * size * frac
        elif kind == "all-to-all":
            moved = size * frac
        else:  # collective-permute
            moved = size
        bytes_by_kind[kind] += int(moved)
        counts[kind] += 1
    return dict(bytes_by_kind), dict(counts)


_COMP_START = re.compile(r"^(?:ENTRY )?(%[\w\.\-]+)\s*\(.*\)\s*->.*\{")
_WHILE_RE = re.compile(
    r"while\(.*?\), condition=(%[\w\.\-]+), body=(%[\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str):
    comps = {}
    name, buf = None, []
    for line in hlo_text.splitlines():
        m = _COMP_START.match(line.strip()) if "{" in line else None
        if m and not line.strip().startswith("%fused"):
            # keep fused computations attributed to their caller region? No:
            # collectives never appear inside fusions, so skipping is safe.
            pass
        m = _COMP_START.match(line.strip())
        if m:
            name = m.group(1)
            buf = []
            comps[name] = buf
            continue
        if line.strip() == "}":
            name = None
            continue
        if name is not None:
            buf.append(line)
    return {k: "\n".join(v) for k, v in comps.items()}


def collective_bytes_loop_aware(hlo_text: str, *, default_group: int = 16,
                                ) -> Tuple[Dict[str, int], Dict[str, int]]:
    """Like :func:`collective_bytes` but multiplies collectives inside
    while-loop bodies by the loop trip count (XLA HLO lists a scan body
    once; the production scan-based lowering would otherwise undercount
    per-layer collectives by n_layers)."""
    comps = _split_computations(hlo_text)

    # find whiles and their trip counts
    trips: Dict[str, int] = {}

    def cond_trip(cond_name: str) -> int:
        consts = [int(c) for c in _CONST_RE.findall(comps.get(cond_name, ""))]
        return max(consts) if consts else 1

    # iterate to fixed point to compose nested loops
    for _ in range(4):
        for cname, body in comps.items():
            outer = trips.get(cname, 1)
            for m in _WHILE_RE.finditer(body):
                cond, bodyn = m.group(1), m.group(2)
                trips[bodyn] = max(trips.get(bodyn, 1),
                                   outer * cond_trip(cond))

    total_b: Dict[str, int] = {}
    total_c: Dict[str, int] = {}
    for cname, body in comps.items():
        mult = trips.get(cname, 1)
        b, c = collective_bytes(body, default_group=default_group)
        for k, v in b.items():
            total_b[k] = total_b.get(k, 0) + v * mult
        for k, v in c.items():
            total_c[k] = total_c.get(k, 0) + v * mult
    return total_b, total_c


def duplicate_collectives(hlo_text: str) -> int:
    """Count textually identical collective ops (same operands+shape) — a
    quick redundancy smell used by the §Perf loop."""
    seen = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        sig = re.sub(r"%\S+? ", "", line.strip())
        sig = re.sub(r"^\s*%\S+\s*=", "", sig)
        seen[sig] += 1
    return sum(c - 1 for c in seen.values() if c > 1)
