from repro.serving.admission_ring import AdmissionRing
from repro.serving.controller import ControllerConfig, ThetaController
from repro.serving.prefill_worker import PrefillWorker
from repro.serving.prefix_cache import PrefixCache, PrefixMatch, PrefixStats
from repro.serving.scheduler import (
    Request,
    Response,
    SamplingParams,
    SpecServer,
    ServerConfig,
)

__all__ = ["Request", "Response", "SamplingParams", "SpecServer",
           "ServerConfig", "PrefixCache", "PrefixMatch", "PrefixStats",
           "ControllerConfig", "ThetaController", "AdmissionRing",
           "PrefillWorker"]
