from repro.serving.controller import ControllerConfig, ThetaController
from repro.serving.prefix_cache import PrefixCache, PrefixMatch, PrefixStats
from repro.serving.scheduler import (
    Request,
    Response,
    SamplingParams,
    SpecServer,
    ServerConfig,
)

__all__ = ["Request", "Response", "SamplingParams", "SpecServer",
           "ServerConfig", "PrefixCache", "PrefixMatch", "PrefixStats",
           "ControllerConfig", "ThetaController"]
