from repro.serving.scheduler import (
    Request,
    Response,
    SamplingParams,
    SpecServer,
    ServerConfig,
)

__all__ = ["Request", "Response", "SamplingParams", "SpecServer",
           "ServerConfig"]
