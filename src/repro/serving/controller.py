"""Online margin/acceptance controller for per-slot adaptive verification.

MARS's knob — the relaxation threshold θ — is a *quality/latency dial*:
lower θ relaxes more near-tie rejections (more tokens per cycle, more drift
from the strict-greedy output), higher θ converges to strict verification.
The repo historically picked one θ offline (``benchmarks/table4_theta.py``)
and broadcast it to every request; this module closes the loop instead.

:class:`ThetaController` is a pure host-side policy over the per-slot
statistics the device carry already accumulates (``DecodeState.stats``):

* ``relaxed`` / ``accepts``  — the *relaxed-accept share*: the fraction of
  accepted draft tokens that needed MARS relaxation.  This is the quality
  proxy: every relaxed accept is a token strict verification would have
  rejected, so the share is held against ``relax_budget``.
* ``margin_ema``             — the on-device EMA of the top-2 logit ratio
  at each cycle's first rejection.  Rejections with ratio just *below* the
  current θ are exactly the ones a small θ drop would convert into
  accepts, so the EMA marks the productive operating point.
* ``accepts`` / ``cycles``   — accepts-per-cycle, the throughput signal
  that (optionally) drives the draft-length bucket.

The update is a clamped proportional law, deliberately monotone in its
inputs (tested in ``tests/test_adaptive_theta.py``):

    θ' = clip(θ + gain·(relax_share − relax_budget)
                − pressure_gain·pressure
                + margin_gain·(margin_ema − θ),            # when EMA valid
              θ_min, θ_max)

so a slot relaxing past its quality budget is tightened (θ ↑), admission
*queue pressure* relaxes every live slot toward ``theta_min`` (trading
marginal fidelity for latency — ∂θ'/∂pressure = −pressure_gain < 0), and a
valid margin EMA pulls θ toward where the target's actual near-ties sit.

The controller runs entirely at the harvest boundary on rows
:meth:`SpecServer.sync` already transfers — the sync-free tick contract is
untouched, and retunes reach the device as one host→device scatter into the
carry's ``theta`` row (never mid-group).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    theta_min: float = 0.6       # most-relaxed threshold pressure may reach
    theta_max: float = 0.99      # strictest threshold tightening may reach
    relax_budget: float = 0.25   # tolerated relaxed share of accepted tokens
    gain: float = 0.15           # proportional gain on the budget error
    pressure_gain: float = 0.08  # θ drop per unit of admission-queue pressure
    margin_gain: float = 0.25    # pull toward the observed margin EMA
    # draft-length buckets (chain topology only): when accepts-per-cycle
    # sits below ``k_shrink_frac`` of the short bucket, drafting the full K
    # is wasted target work — dispatch the pre-jitted short-K program.
    k_shrink_frac: float = 0.5


class ThetaController:
    """Pure per-slot θ policy; all methods are host-side numpy and
    side-effect free (the scheduler owns dispatching the result)."""

    def __init__(self, cfg: Optional[ControllerConfig] = None):
        self.cfg = cfg or ControllerConfig()
        if not (0.0 < self.cfg.theta_min <= self.cfg.theta_max <= 1.0):
            raise ValueError(
                f"need 0 < theta_min <= theta_max <= 1, got "
                f"[{self.cfg.theta_min}, {self.cfg.theta_max}]")
        # observability tallies (host-only, read by repro.obs / launchers;
        # the policy itself never consults them — update() stays pure in
        # its inputs)
        self.updates = 0           # update() calls
        self.slots_tightened = 0   # slot-steps where theta moved up
        self.slots_relaxed = 0     # slot-steps where theta moved down
        self.last_pressure = 0.0

    def clamp(self, theta):
        return float(np.clip(theta, self.cfg.theta_min, self.cfg.theta_max))

    def update(self, theta, relax_share, margin_ema, pressure: float):
        """One retune step over the live slots.

        theta       : (n,) current per-slot thresholds
        relax_share : (n,) relaxed / max(accepts, 1) since admission
        margin_ema  : (n,) device margin EMA (0 = no sample yet)
        pressure    : scalar >= 0 admission-queue pressure (queued work per
                      slot; 0 = no queue)

        Returns the new (n,) thresholds, clipped to [theta_min, theta_max].
        Monotone: pressure up => theta down, relax_share up => theta up.
        """
        cfg = self.cfg
        theta = np.asarray(theta, np.float64)
        relax_share = np.asarray(relax_share, np.float64)
        margin_ema = np.asarray(margin_ema, np.float64)
        step = cfg.gain * (relax_share - cfg.relax_budget)
        step -= cfg.pressure_gain * max(float(pressure), 0.0)
        guided = margin_ema > 0
        step = np.where(guided, step + cfg.margin_gain * (margin_ema - theta),
                        step)
        new = np.clip(theta + step, cfg.theta_min, cfg.theta_max)
        self.updates += 1
        self.slots_tightened += int(np.sum(new > theta + 1e-12))
        self.slots_relaxed += int(np.sum(new < theta - 1e-12))
        self.last_pressure = max(float(pressure), 0.0)
        return new

    def summary(self) -> dict:
        """Telemetry rollup of the controller's activity (exported by
        launchers next to the server's own counters)."""
        return {"updates": self.updates,
                "slots_tightened": self.slots_tightened,
                "slots_relaxed": self.slots_relaxed,
                "last_pressure": self.last_pressure}

    def choose_k(self, accepts_per_cycle: float, k_full: int,
                 k_short: int) -> int:
        """Width bucket for the next tick group: fall back to the short
        draft when observed accepts-per-cycle can't even fill it."""
        if accepts_per_cycle < self.cfg.k_shrink_frac * k_short:
            return k_short
        return k_full
