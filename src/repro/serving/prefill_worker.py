"""Disaggregated prefill: fill pool blocks for cold prompts off the
decode path.

The prefix cache stopped *cached* prompts from paying cold prefills, but
one genuinely cold admit still widens the admission decode window for
every warm sibling sharing the pass (``prefill_window_ratio`` in
``BENCH_serving.json``).  The prefill worker kills that coupling: before
a cold request enters the batched admission prefill, its own jitted
program — separate from the tick program, optionally pinned to a
dedicated mesh slice via ``ServerConfig.prefill_mesh`` — decodes the
prompt body into the slot's freshly allocated pool blocks through a
batch-1 :func:`repro.models.paging.worker_cache_view`.  The admission
pass then treats those positions exactly like a cached prefix: blocks
ride in via the table row, positions are seeded valid, and the decode
window shrinks to the pending tail (the final prompt token, plus the
feature-grounding token for feature-carrying drafters).

Handoff contract
----------------
* The worker writes only blocks the host just allocated for the target
  slot — never a live slot's rows, never shared (refcounted > 1) prefix
  blocks: a partially matching shared tail is COW-cloned *inside the
  worker program* before any write lands.
* Device dispatches execute in submission order, so the admission (or
  ring-refill) program that maps the blocks is queued after the fill
  and reads complete KV — no fence, no host sync.
* The worker is decode-cache only: the drafter's prompt prefill still
  runs in the admission pass (it is recurrent over the whole prompt and
  cheap by construction).
* Eligibility: paged cache, non-recurrent family, no sliding window
  (a wrapped ring is not reconstructible from a seeded position row),
  no encoder cross-attention leaves.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.model import Model
from repro.models.paging import merge_worker_pool, worker_cache_view


def worker_unsupported_reason(target: Model, cache: str) -> Optional[str]:
    """Why the prefill worker cannot serve this config (None = it can)."""
    if cache != "paged":
        return ("prefill disaggregation hands off physical pool blocks, "
                "which the dense per-slot ring does not have")
    if target.is_recurrent:
        return ("recurrent state is order-dependent and lives in the "
                "carry, so a detached prefill cannot hand it off")
    if target.cfg.sliding_window:
        return ("a sliding-window block ring wraps, so seeded positions "
                "cannot reconstruct the worker's write layout")
    if target.cfg.family == "audio":
        return ("encoder cross-attention leaves are per-request and "
                "outside the block pool")
    return None


class PrefillWorker:
    """One jitted fill program over the serving carry's pool leaves.

    ``fill()`` decodes prompt positions ``[start, usable)`` of one cold
    request into the blocks of ``row`` and returns the carry with the
    written pools merged back; every per-slot leaf (and the whole
    drafter side) passes through untouched, so a fill can run while the
    previous tick group is still in flight.
    """

    def __init__(self, target: Model, prompt_width: int, *, mesh=None,
                 state_shardings=None, t_shardings=None):
        self.target = target
        self.prompt_width = int(prompt_width)
        self.fills = 0              # worker dispatches
        self.filled_tokens = 0      # prompt positions taken off decode
        # host wall seconds spent in fill() dispatches (enqueue cost —
        # the async dispatch returns before device compute finishes);
        # telemetry reports it next to the scheduler's tick spans
        self.fill_wall_s = 0.0

        def _fill(tp, state, tokens, row, start, usable,
                  cow_src, cow_dst, trash_id):
            cache = state.t_cache
            view = {"index": jnp.zeros((1,), jnp.int32),
                    "layers": worker_cache_view(cache["layers"], row,
                                                trash_id)}
            # COW before any write: a partially matching shared tail
            # block is cloned into the slot's first private block
            # (trash -> trash when there is nothing to clone)
            view = target.clone_blocks(view,
                                       jnp.reshape(cow_src, (1,)),
                                       jnp.reshape(cow_dst, (1,)))
            # cached positions [0, start) rode in shared: mark them
            # valid so the fill's attention sees the whole prefix
            view = target.seed_prefix(view, jnp.ones((1,), bool),
                                      jnp.reshape(start, (1,)))
            s = tokens.shape[0]
            pos = jnp.arange(s, dtype=jnp.int32)[None]
            tmask = (pos >= start) & (pos < usable)
            _, view = target.decode(tp, tokens[None], pos, view,
                                    token_mask=tmask)
            new_cache = {**cache,
                         "layers": merge_worker_pool(cache["layers"],
                                                     view["layers"])}
            return state._replace(t_cache=new_cache)

        if mesh is None:
            self._fill = jax.jit(_fill, donate_argnums=(1,))
        else:
            repl = NamedSharding(mesh, P())
            self._fill = jax.jit(
                _fill, donate_argnums=(1,),
                in_shardings=(t_shardings, state_shardings,
                              repl, repl, repl, repl, repl, repl, repl),
                out_shardings=state_shardings)

    def fill(self, t_params, state, tokens: np.ndarray, row: np.ndarray,
             start: int, usable: int, cow_src: int, cow_dst: int,
             trash_id: int):
        """Dispatch one fill (host half).  ``tokens`` is the padded
        (prompt_width,) prompt row; positions ``[start, usable)`` are
        written.  Returns the new carry; the caller still owns response
        assembly and the admission prefill of the ``[usable, plen)``
        tail."""
        self.fills += 1
        self.filled_tokens += max(int(usable) - int(start), 0)
        t0 = time.perf_counter()
        out = self._fill(t_params, state,
                         np.asarray(tokens, np.int32),
                         np.asarray(row, np.int32),
                         np.int32(start), np.int32(usable),
                         np.int32(cow_src), np.int32(cow_dst),
                         np.int32(trash_id))
        self.fill_wall_s += time.perf_counter() - t0
        return out

    @property
    def stats(self) -> dict:
        return {"fills": self.fills, "filled_tokens": self.filled_tokens,
                "fill_wall_s": self.fill_wall_s}
