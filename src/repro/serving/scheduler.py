"""Continuous-batching speculative-decoding server.

vLLM-style slot scheduler specialised for draft–verify cycles: a fixed
number of batch slots share one jitted verify-cycle program; finished slots
are refilled from the waiting queue between cycles.  Admission resets the
slot's cache rows (attention pos invalidation / recurrent state zeroing) and
prefills the prompt with a slot-masked decode, so admissions never disturb
in-flight neighbours.

Host-side logic (queueing, detokenisation) is deliberately thin; all the
per-token work happens in two jitted programs: ``_prefill`` and the engine's
``cycle``.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import EngineConfig, SpecEngine
from repro.models.model import Model


@dataclasses.dataclass
class SamplingParams:
    max_tokens: int = 128
    temperature: float = 1.0


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                     # (S,) int32
    params: SamplingParams = dataclasses.field(default_factory=SamplingParams)


@dataclasses.dataclass
class Response:
    uid: int
    tokens: np.ndarray
    n_cycles: int
    n_committed: int
    latency_s: float

    @property
    def tau(self) -> float:
        return self.n_committed / max(self.n_cycles, 1)


@dataclasses.dataclass
class ServerConfig:
    slots: int = 4
    max_len: int = 512
    max_prompt_len: int = 128


class SpecServer:
    def __init__(self, target: Model, drafter, t_params, d_params,
                 engine_cfg: EngineConfig, cfg: ServerConfig):
        self.engine = SpecEngine(target, drafter, engine_cfg)
        self.target, self.drafter = target, drafter
        self.t_params, self.d_params = t_params, d_params
        self.cfg = cfg
        self.ecfg = engine_cfg

        b, l = cfg.slots, cfg.max_len
        self.buf = jnp.zeros((b, l + 1), jnp.int32)
        self.lengths = jnp.zeros((b,), jnp.int32)
        self.finished = jnp.ones((b,), bool)      # all idle initially
        self.budget = np.zeros((b,), np.int64)    # host-side per-slot budget
        self.t_cache = target.init_cache(t_params, b, l)
        self.d_state = drafter.init_state(d_params, b, l)
        self.last_token = jnp.zeros((b,), jnp.int32)
        self.key = jax.random.PRNGKey(0)
        self.stats = {k: jnp.zeros((b,), jnp.int32)
                      for k in ("cycles", "commits", "accepts", "relaxed")}

        self.queue: deque[Request] = deque()
        self.slot_req: List[Optional[Request]] = [None] * b
        self.slot_t0 = np.zeros((b,), np.float64)
        self.slot_base_len = np.zeros((b,), np.int64)
        self.slot_base_stats = {k: np.zeros((b,), np.int64)
                                for k in self.stats}
        self._responses: List[Response] = []

        self._cycle = jax.jit(self._cycle_impl)
        self._prefill = jax.jit(self._prefill_impl)

    # ------------------------------------------------------------------
    def _cycle_impl(self, t_params, d_params, carry):
        return self.engine.cycle(t_params, d_params, carry)

    def _prefill_impl(self, t_params, d_params, carry, prompt, plen, slot):
        """Admit one request into slot: reset caches, write prompt, prefill."""
        (buf, lengths, finished, t_cache, d_state, last_token, key,
         stats) = carry
        b = lengths.shape[0]
        smask = jnp.arange(b) == slot

        t_cache = self.target.reset_slots(t_cache, smask)
        if hasattr(self.drafter, "model"):
            d_cache = self.drafter.model.reset_slots(d_state["cache"], smask)
            d_state = {**d_state, "cache": d_cache}

        s = prompt.shape[0]
        # write prompt into the slot's buffer row
        row = jnp.where(jnp.arange(buf.shape[1]) < s,
                        jnp.pad(prompt, (0, buf.shape[1] - s)), 0)
        buf = jnp.where(smask[:, None], row[None], buf)
        lengths = jnp.where(smask, plen, lengths)
        finished = jnp.where(smask, False, finished)
        stats = {k: jnp.where(smask, 0, v) for k, v in stats.items()}

        # slot-masked prefill of prompt[:-1]
        tokens = jnp.broadcast_to(prompt[None], (b, s))
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        pmask = smask[:, None] & (pos < plen - 1)
        out = self.target.decode(self.t_params, tokens, pos, t_cache,
                                 token_mask=pmask,
                                 with_features=self.drafter.wants_features)
        if self.drafter.wants_features:
            _, new_t_cache, feats = out
            idx = jnp.clip(plen - 2, 0, s - 1)
            f0 = jnp.take_along_axis(
                feats, jnp.full((b, 1, feats.shape[-1]), idx, jnp.int32), 1)[:, 0]
            if "feat" in d_state:
                feat = jnp.where(smask[:, None],
                                 f0.astype(d_state["feat"].dtype),
                                 d_state["feat"])
                d_state = {**d_state, "feat": feat}
        else:
            _, new_t_cache = out
        t_cache = new_t_cache

        if hasattr(self.drafter, "model"):
            _, d_cache = self.drafter.model.decode(
                self.d_params, tokens, pos, d_state["cache"],
                token_mask=pmask)
            d_state = {**d_state, "cache": d_cache}

        last = prompt[jnp.clip(plen - 1, 0, s - 1)]
        last_token = jnp.where(smask, last, last_token)
        return (buf, lengths, finished, t_cache, d_state, last_token, key,
                stats)

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _carry(self):
        return (self.buf, self.lengths, self.finished, self.t_cache,
                self.d_state, self.last_token, self.key, self.stats)

    def _set_carry(self, carry):
        (self.buf, self.lengths, self.finished, self.t_cache, self.d_state,
         self.last_token, self.key, self.stats) = carry

    def _admit(self):
        finished = np.asarray(self.finished)
        for slot in range(self.cfg.slots):
            if not finished[slot]:
                continue
            if self.slot_req[slot] is not None:
                self._harvest(slot)
            if self.queue:
                req = self.queue.popleft()
                s = self.cfg.max_prompt_len
                prompt = np.zeros((s,), np.int32)
                plen = min(len(req.prompt), s)
                prompt[:plen] = req.prompt[:plen]
                carry = self._prefill(
                    self.t_params, self.d_params, self._carry(),
                    jnp.asarray(prompt), jnp.int32(plen), jnp.int32(slot))
                self._set_carry(carry)
                self.slot_req[slot] = req
                self.slot_t0[slot] = time.time()
                self.slot_base_len[slot] = plen
                self.budget[slot] = req.params.max_tokens
                for k in self.stats:
                    self.slot_base_stats[k][slot] = int(
                        np.asarray(self.stats[k])[slot])

    def _harvest(self, slot: int):
        req = self.slot_req[slot]
        if req is None:
            return
        toks = np.asarray(self.buf)[slot, :int(np.asarray(self.lengths)[slot])]
        cyc = int(np.asarray(self.stats["cycles"])[slot]
                  - self.slot_base_stats["cycles"][slot])
        com = int(np.asarray(self.stats["commits"])[slot]
                  - self.slot_base_stats["commits"][slot])
        self._responses.append(Response(
            uid=req.uid,
            tokens=toks[int(self.slot_base_len[slot]):],
            n_cycles=cyc, n_committed=com,
            latency_s=time.time() - self.slot_t0[slot]))
        self.slot_req[slot] = None

    def step(self):
        """One scheduler tick: admit, run one verify cycle, mark budget."""
        self._admit()
        if all(r is None for r in self.slot_req):
            return
        carry = self._cycle(self.t_params, self.d_params, self._carry())
        self._set_carry(carry)
        # budget exhaustion -> finish slot
        lengths = np.asarray(self.lengths)
        fin = np.asarray(self.finished).copy()
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            produced = lengths[slot] - self.slot_base_len[slot]
            if produced >= self.budget[slot]:
                fin[slot] = True
        self.finished = jnp.asarray(fin)

    def run(self, *, max_ticks: int = 10_000) -> List[Response]:
        for _ in range(max_ticks):
            if not self.queue and all(r is None for r in self.slot_req):
                break
            self.step()
            # harvest finished
            finished = np.asarray(self.finished)
            for slot, req in enumerate(self.slot_req):
                if req is not None and finished[slot]:
                    self._harvest(slot)
        out, self._responses = self._responses, []
        return out
