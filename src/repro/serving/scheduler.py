"""Continuous-batching speculative-decoding server.

vLLM-style slot scheduler specialised for draft–verify cycles: a fixed
number of batch slots share one jitted verify-cycle program; finished slots
are refilled from the waiting queue between cycles.

All device-side state and logic belong to the shared
:class:`repro.core.session.DecodeSession` engine core — the server holds one
:class:`~repro.core.session.DecodeState` carry and runs exactly two jitted
programs over it: the session's slot-masked ``prefill`` (admission: cache
row reset + prompt prefill, neighbours untouched) and the session's
``cycle``.  Because the topology is a session-level strategy, the server
serves chain AND tree drafts with the same scheduler: pass
``EngineConfig(topology="tree", branch=...)`` with an EAGLE-style drafter.

The session contract the server relies on (see ``core/session.py``):
``cache.index`` counts cached tokens (the pending last token is not yet
cached); rollback is index-rewind for attention caches and masked recompute
for recurrent ones; ``finished == True`` marks an idle slot safe to reuse.

Host-side logic (queueing, budgets, detokenisation) is deliberately thin.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.session import DecodeSession, EngineConfig
from repro.models.model import Model


@dataclasses.dataclass
class SamplingParams:
    max_tokens: int = 128
    temperature: float = 1.0


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                     # (S,) int32
    params: SamplingParams = dataclasses.field(default_factory=SamplingParams)


@dataclasses.dataclass
class Response:
    uid: int
    tokens: np.ndarray
    n_cycles: int
    n_committed: int
    latency_s: float

    @property
    def tau(self) -> float:
        return self.n_committed / max(self.n_cycles, 1)


@dataclasses.dataclass
class ServerConfig:
    slots: int = 4
    max_len: int = 512
    max_prompt_len: int = 128


class SpecServer:
    def __init__(self, target: Model, drafter, t_params, d_params,
                 engine_cfg: EngineConfig, cfg: ServerConfig):
        self.session = DecodeSession(target, drafter, engine_cfg)
        self.target, self.drafter = target, drafter
        self.t_params, self.d_params = t_params, d_params
        self.cfg = cfg
        self.ecfg = engine_cfg

        b = cfg.slots
        self.state = self.session.init_state(t_params, d_params, b,
                                             cfg.max_len)
        self.budget = np.zeros((b,), np.int64)    # host-side per-slot budget

        self.queue: deque[Request] = deque()
        self.slot_req: List[Optional[Request]] = [None] * b
        self.slot_t0 = np.zeros((b,), np.float64)
        self.slot_base_len = np.zeros((b,), np.int64)
        self.slot_base_stats = {k: np.zeros((b,), np.int64)
                                for k in self.state.stats}
        self._responses: List[Response] = []

        self._cycle = jax.jit(
            lambda tp, dp, st: self.session.cycle(tp, dp, st))
        self._prefill = jax.jit(self._prefill_impl)

    # -- host views of the carry -----------------------------------------
    @property
    def buf(self):
        return self.state.buf

    @property
    def lengths(self):
        return self.state.lengths

    @property
    def finished(self):
        return self.state.finished

    @property
    def stats(self):
        return self.state.stats

    # ------------------------------------------------------------------
    def _prefill_impl(self, t_params, d_params, state, prompt, plen, slot):
        """Admit one request into ``slot`` via the session's slot-masked
        prefill (broadcast the single prompt row; only the slot row lands)."""
        b = self.cfg.slots
        smask = jnp.arange(b) == slot
        prompt_b = jnp.broadcast_to(prompt[None], (b, prompt.shape[0]))
        plen_b = jnp.full((b,), plen, jnp.int32)
        return self.session.prefill(t_params, d_params, state, prompt_b,
                                    plen_b, slot_mask=smask)

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        finished = np.asarray(self.state.finished)
        for slot in range(self.cfg.slots):
            if not finished[slot]:
                continue
            if self.slot_req[slot] is not None:
                self._harvest(slot)
            if self.queue:
                req = self.queue.popleft()
                s = self.cfg.max_prompt_len
                prompt = np.zeros((s,), np.int32)
                plen = min(len(req.prompt), s)
                prompt[:plen] = req.prompt[:plen]
                self.state = self._prefill(
                    self.t_params, self.d_params, self.state,
                    jnp.asarray(prompt), jnp.int32(plen), jnp.int32(slot))
                self.slot_req[slot] = req
                self.slot_t0[slot] = time.time()
                self.slot_base_len[slot] = plen
                self.budget[slot] = req.params.max_tokens
                for k in self.state.stats:
                    self.slot_base_stats[k][slot] = int(
                        np.asarray(self.state.stats[k])[slot])

    def _harvest(self, slot: int):
        req = self.slot_req[slot]
        if req is None:
            return
        toks = np.asarray(self.state.buf)[
            slot, :int(np.asarray(self.state.lengths)[slot])]
        cyc = int(np.asarray(self.state.stats["cycles"])[slot]
                  - self.slot_base_stats["cycles"][slot])
        com = int(np.asarray(self.state.stats["commits"])[slot]
                  - self.slot_base_stats["commits"][slot])
        self._responses.append(Response(
            uid=req.uid,
            tokens=toks[int(self.slot_base_len[slot]):],
            n_cycles=cyc, n_committed=com,
            latency_s=time.time() - self.slot_t0[slot]))
        self.slot_req[slot] = None

    def step(self):
        """One scheduler tick: admit, run one verify cycle, mark budget."""
        self._admit()
        if all(r is None for r in self.slot_req):
            return
        self.state = self._cycle(self.t_params, self.d_params, self.state)
        # budget exhaustion -> finish slot
        lengths = np.asarray(self.state.lengths)
        fin = np.asarray(self.state.finished).copy()
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            produced = lengths[slot] - self.slot_base_len[slot]
            if produced >= self.budget[slot]:
                fin[slot] = True
        self.state = self.state._replace(finished=jnp.asarray(fin))

    def run(self, *, max_ticks: int = 10_000) -> List[Response]:
        for _ in range(max_ticks):
            if not self.queue and all(r is None for r in self.slot_req):
                break
            self.step()
            # harvest finished
            finished = np.asarray(self.state.finished)
            for slot, req in enumerate(self.slot_req):
                if req is not None and finished[slot]:
                    self._harvest(slot)
        out, self._responses = self._responses, []
        return out
