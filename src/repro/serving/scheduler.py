"""Continuous-batching speculative-decoding server — device-resident.

vLLM-style slot scheduler specialised for draft–verify cycles: a fixed
number of batch slots share one jitted program; finished slots are refilled
from the waiting queue between *sync points*, not between cycles.

The device-resident contract
----------------------------

Everything a verify cycle needs to run — and to *stop* — lives in the
:class:`~repro.core.session.DecodeState` carry on device: the token buffer,
lengths, finished flags, caches, the pending token, and (since this
scheduler was rewritten) the per-slot remaining token ``budget`` and
per-slot verification ``temperature``.  ``DecodeSession.cycle`` clamps each
commit to the budget, decrements it, and flips ``finished`` on-device, so:

* the tick loop is **sync-free** — :meth:`SpecServer.step` dispatches
  ``steps_per_sync`` fused cycles (one ``lax.fori_loop`` jit with the carry
  donated, so buffers are reused rather than copied) and performs **zero**
  device→host transfers;
* the host may observe the carry only at :meth:`SpecServer.sync`: one small
  poll of the ``finished`` flags, then — only when something finished — a
  single ``device_get`` of the finished rows (tokens, lengths, stats);
* the host *writes* serving state only at admission: one slot-masked
  ``prefill`` call admits **all** refillable slots at once, carrying each
  request's prompt, ``max_tokens`` budget, and temperature into the masked
  rows (in-flight neighbours are untouched).

``host_syncs`` counts every device→host transfer the server performs; tests
and ``benchmarks/serving_throughput.py`` assert it stays zero across
``step()`` and grows only at sync points.

Because the topology is a session-level strategy, the server serves chain
AND tree drafts with the same scheduler: pass
``EngineConfig(topology="tree", branch=...)`` with an EAGLE-style drafter.

KV layout is a config choice (``ServerConfig.cache``): ``"dense"`` reserves
a ``max_len`` ring per slot; ``"paged"`` backs slots with fixed-size blocks
from one shared pool (``repro.models.paging``).  Under paging, admission is
gated by **pool headroom** — the host :class:`~repro.models.paging.BlockPool`
allocates each request's worst-case block count up front (so mid-cycle
rollback never allocates), the admission prefill maps the slot's table rows,
and harvest returns the finished slot's whole block list to the pool.
Long-context configs therefore admit as many concurrent requests as their
*declared* footprints (prompt + ``max_tokens`` + overhang) fit in the pool,
rather than one per worst-case ``max_len`` reservation.

Prefix cache (``ServerConfig.prefix_cache="on"``, paged only)
-------------------------------------------------------------
Admission additionally runs a longest-prefix match of the tokenized prompt
against the host :class:`~repro.serving.prefix_cache.PrefixCache`: fully
matching KV blocks are mapped **read-only** into the new slot's table (one
pool refcount each — shared blocks are counted once in headroom, which is
where the extra admitted concurrency comes from), a partially matching
tail block is copy-on-write cloned inside the admission program, and the
prefill runs *from the divergence point only*
(``DecodeSession.prefill(start_pos=...)``), over a token window sliced to
the un-cached tail.  The prompt's full blocks are published right after
the admission dispatch (they hold committed content by definition), the
generated history's at harvest; a same-prefix follower request observed in
the same admission pass is deferred one tick so it can ride the freshly
published blocks instead of paying a duplicate cold prefill.  Because
every slot's writes land at positions ≥ its ``start_pos``, shared blocks
are never written — speculative rollback remains an index rewind into
private blocks only.

Adaptive verification (``ServerConfig.theta_mode="adaptive"``)
---------------------------------------------------------------
The MARS threshold θ is a per-slot ``(B,)`` row of the carry (seeded from
``SamplingParams.theta`` at admission), and every verify cycle reads its
own row — so different in-flight requests run at different strictness with
zero extra transfers.  A host-side
:class:`~repro.serving.controller.ThetaController` closes the loop at each
sync boundary: the finished-flag poll additionally carries the per-slot
``accepts``/``relaxed`` counters and the on-device margin EMA (same single
transfer), the controller retunes every live slot within
``[theta_min, theta_max]`` — tightening slots whose relaxed-accept share
exceeds ``relax_budget``, relaxing everyone under admission-queue
pressure — and one host→device write lands the new θ row in the carry.
``theta_mode="fixed"`` never constructs a controller and stays
token-identical to the pre-adaptive server.  ``adaptive_k=True`` (chain
topology) additionally lets the controller pick the next group's draft
length between pre-jitted full-K and half-K tick programs.

Host-side logic (queueing, response assembly, detokenisation, block
accounting) is deliberately thin and never feeds back into the carry
mid-flight.

Mesh partitioning (``ServerConfig.mesh``)
-----------------------------------------
The whole tick group is ONE jitted program over the carry, so scaling it is
a *partitioning* problem, not a scheduling one: ``mesh=(data, model)``
builds a :func:`repro.launch.mesh.make_serving_mesh` and runs the same
three entry points SPMD over it.  Slot-indexed carry fields (``buf``,
``lengths``, ``budget``, ``temperature``, ``finished``, block tables) shard
their leading dim on ``data`` — each data shard owns ``slots/data`` whole
requests and the cycles for different shards run concurrently; target and
drafter params (heads / ff / vocab, where divisible) shard on ``model``
per ``repro.sharding.serving_rules``; the paged ``k_pool``/``v_pool`` is
partitioned under both (physical blocks on ``data``, KV heads on
``model``).  Admission stays host-driven but becomes sharding-aware: the
host picks global slot ids exactly as before (the slot-masked prefill
admits each shard's rows locally), and the paged free list becomes a
:class:`~repro.models.paging.ShardedBlockPool` so every slot's block ids
stay inside the pool range of the data shard that owns the slot.  The
device-resident contract is mesh-invariant: ``step()`` still performs zero
device→host transfers, and greedy outputs are token-identical to the
single-device path (data sharding only re-partitions slot-parallel work;
see ``tests/test_mesh_serving.py``).
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from collections import deque
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.session import DecodeSession, DecodeState, EngineConfig
from repro.models.model import Model
from repro.models.paging import (BlockPool, PagedCacheConfig,
                                 ShardedBlockPool,
                                 kv_dtype_unsupported_reason,
                                 paged_unsupported_reason, pool_block_bytes,
                                 slot_trash_blocks)
from repro.serving.admission_ring import (NO_COW, fused_cycles_with_refill,
                                          make_ring, ring_push)
from repro.serving.prefill_worker import (PrefillWorker,
                                          worker_unsupported_reason)
from repro.serving.prefix_cache import PrefixCache
from repro.sharding import axis_rules, serving_rules


@dataclasses.dataclass
class SamplingParams:
    max_tokens: int = 128
    temperature: float = 1.0
    # Per-request MARS relaxation threshold; None inherits the engine
    # default.  Under ``theta_mode="adaptive"`` this seeds the slot's
    # controller state (clamped to [theta_min, theta_max]) and the
    # controller retunes it from there.
    theta: Optional[float] = None


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                     # (S,) int32
    params: SamplingParams = dataclasses.field(default_factory=SamplingParams)


@dataclasses.dataclass
class Response:
    uid: int
    tokens: np.ndarray
    n_cycles: int
    n_committed: int
    latency_s: float
    n_accepted: int = 0        # accepted draft tokens (fidelity reporting)

    @property
    def tau(self) -> float:
        return self.n_committed / max(self.n_cycles, 1)


@dataclasses.dataclass
class _StagedEntry:
    """Host twin of one on-device admission-ring entry (FIFO with the
    ring: entry ``i`` of this deque is ring index ``head_host + i``).
    Everything the host must re-learn when the device consumes the entry
    lives here — the Request for response assembly, the block table the
    slot inherits, and the ledger values admission would have written."""
    req: Request
    ptoks: np.ndarray          # (max_prompt_len,) padded prompt row
    plen: int
    blocks: List[int]          # shared + private physical blocks
    shard: int
    match_start: int           # cached-prefix tokens (ledger/accounting)
    theta: float
    t0: float


@dataclasses.dataclass
class ServerConfig:
    slots: int = 4
    max_len: int = 512
    max_prompt_len: int = 128
    # Cap on fused verify cycles per dispatch when EOS can preempt a slot
    # early.  Without an EOS token the cap is ignored: a cycle commits at
    # most ``commit_width`` tokens, so the host can bound — from budgets it
    # already knows — how many cycles must pass before ANY slot can finish,
    # and fuses exactly that many (zero wasted cycles, zero early polls).
    steps_per_sync: int = 4
    # KV layout: "dense" reserves a full max_len ring per slot; "paged"
    # backs every slot with blocks from one shared pool, so admission is
    # gated by *pool headroom* (actual KV written) rather than worst-case
    # per-slot reservation — long-context configs admit more concurrent
    # requests at equal device memory.  Sizing guide: docs/SERVING.md.
    cache: str = "dense"                # "dense" | "paged"
    block_size: int = 16                # paged: tokens per KV block
    pool_blocks: int = 0                # paged: physical blocks incl. trash;
                                        # 0 = dense-equivalent capacity
                                        # (dense-equivalent BYTES when
                                        # kv_dtype is quantized)
    # Pool storage mode (paged only): "bf16" keeps the model's activation
    # dtype; "int8"/"fp8" store low-bit blocks with per-token per-head amax
    # scales in a parallel scale pool (repro.models.paging).  Quantized
    # pools fit ~2-4x the blocks in the same HBM, so pool_blocks=0 sizes
    # the pool in BYTES (dense-equivalent budget / quantized block bytes)
    # and admission rises accordingly.  Sizing guide: docs/SERVING.md.
    kv_dtype: str = "bf16"              # "bf16" | "int8" | "fp8"
    # (data, model) serving-mesh shape; None/(1,1) = single device.  Slots
    # shard over "data" (slots % data == 0 required), target/drafter tensor
    # dims over "model"; the paged pool is partitioned under both (rounded
    # up to a data-divisible block count).  Sizing guide: docs/SERVING.md.
    mesh: Optional[Tuple[int, int]] = None
    # Prefix cache (paged only): "on" shares published KV blocks between
    # requests with common token prefixes — admission maps them read-only,
    # prefills from the divergence point, and copy-on-write clones a
    # partially matching tail block.  Blocks then outlive requests: freed
    # published blocks park in a reclaimable LRU until allocation pressure
    # evicts them.  Sizing guide: docs/SERVING.md.
    prefix_cache: str = "off"           # "off" | "on"
    # Smallest cached run (in blocks) worth mapping shared — tiny matches
    # cost table bookkeeping + a COW clone for near-zero prefill savings.
    min_match_blocks: int = 1
    # Caps on the reclaimable LRU of published-but-free blocks: at most
    # ``prefix_cache_max_blocks`` parked blocks (0 = bounded only by the
    # pool itself), each reclaimed after ``prefix_cache_ttl_s`` seconds
    # unused (0 = no TTL).  Oldest-first either way; eviction only touches
    # blocks nobody references, so it can never stall an in-flight slot.
    prefix_cache_max_blocks: int = 0
    prefix_cache_ttl_s: float = 0.0
    # Per-slot adaptive verification: "fixed" broadcasts EngineConfig.theta
    # (token-identical to the pre-controller server); "adaptive" runs a
    # ThetaController (repro.serving.controller) at every sync boundary,
    # retuning each live slot's theta within [theta_min, theta_max] from
    # the on-device relaxed-accept share and margin EMA, and relaxing
    # everyone under admission-queue pressure.
    theta_mode: str = "fixed"           # "fixed" | "adaptive"
    theta_min: float = 0.6
    theta_max: float = 0.99
    relax_budget: float = 0.25          # tolerated relaxed accept share
    # Adaptive draft length (chain topology + theta_mode="adaptive" only):
    # pre-jit a second tick program over a half-K drafter and let the
    # controller pick the width bucket per group from observed
    # accepts-per-cycle — low-acceptance phases stop paying full-K drafts.
    adaptive_k: bool = False
    # Pipelined tick (docs/ARCHITECTURE.md "Pipelined tick"):
    # ``overlap=True`` double-buffers the dispatch pipeline — step() keeps
    # up to two in-flight fused groups (the donated carry alternates
    # between the two buffer generations) plus a non-donated snapshot of
    # each group's harvest view, and sync() only blocks on the OLDER
    # group, so group N+1's drafter compute overlaps group N's harvest
    # D2H.  Token-identical to the serial tick under greedy decoding.
    overlap: bool = False
    # Device-side admission ring depth (0 = off): the host stages up to
    # ``ring_depth`` queued prompts on device and the fused group body
    # refills freed slots mid-group via a masked in-loop prefill
    # (repro.serving.admission_ring) — no slot idles waiting for a sync.
    ring_depth: int = 0
    # Disaggregated prefill (paged, non-recurrent, non-windowed): a
    # separate jitted PrefillWorker program fills a cold prompt's pool
    # blocks BEFORE the admission pass, which then maps them like a
    # cached prefix — one cold admit no longer widens the batched decode
    # window for every warm sibling in the pass.
    prefill_worker: bool = False
    # Mesh slice for the worker's fill program; today it must equal
    # ``mesh`` (the pool leaves live on the serving mesh), but the knob
    # keeps the placement explicit and future-proofs a dedicated slice.
    prefill_mesh: Optional[Tuple[int, int]] = None
    # Cross-shard work stealing (mesh admission): order free slots by
    # their shard's live-request load (then pool headroom), so a drained
    # shard's slots take head-of-queue requests that would otherwise wait
    # on a loaded shard.  FIFO over requests is preserved — stealing only
    # reorders which SLOT admits next, never which request.
    shard_steal: bool = True


class SpecServer:
    def __init__(self, target: Model, drafter, t_params, d_params,
                 engine_cfg: EngineConfig, cfg: ServerConfig,
                 *, telemetry=None):
        self.session = DecodeSession(target, drafter, engine_cfg)
        self.target, self.drafter = target, drafter
        self.t_params, self.d_params = t_params, d_params
        self.cfg = cfg
        self.ecfg = engine_cfg
        # Optional repro.obs.ServerTelemetry: lifecycle hooks + tick spans.
        # Every call site is None-guarded and consumes only host-resident
        # values the sync poll already transferred — telemetry can never
        # add a device→host transfer (tests/test_observability.py pins
        # this in both serial and overlap modes).
        self.obs = telemetry

        b = cfg.slots
        if cfg.cache not in ("dense", "paged"):
            raise ValueError(f"unknown cache layout {cfg.cache!r}")
        if cfg.prefix_cache not in ("off", "on"):
            raise ValueError(f"unknown prefix_cache mode "
                             f"{cfg.prefix_cache!r} (off|on)")
        if cfg.cache == "paged":
            # fail fast, BEFORE any device state is built, should a future
            # family ever be unsupported (every current family pages: see
            # the per-family layouts in repro.models.paging — hybrids page
            # their attention sub-cache, sliding-window layers get a
            # window-bounded ring of blocks, pure-ssm routes through with
            # a zero-block table)
            reason = paged_unsupported_reason(target.cfg)
            if reason is not None:
                raise ValueError(
                    f"ServerConfig(cache='paged') is incompatible with "
                    f"arch {target.cfg.name!r}: {reason}; use "
                    f"cache='dense'")
        # kv_dtype validation mirrors the paged check: one actionable error
        # naming the arch/backend before any device state exists
        reason = kv_dtype_unsupported_reason(cfg.kv_dtype)
        if reason is not None:
            raise ValueError(
                f"ServerConfig(kv_dtype={cfg.kv_dtype!r}) cannot serve "
                f"arch {target.cfg.name!r}: {reason}")
        if cfg.kv_dtype != "bf16" and cfg.cache != "paged":
            raise ValueError(
                f"ServerConfig(kv_dtype={cfg.kv_dtype!r}) requires "
                f"cache='paged': quantized storage lives in the shared "
                f"block pool's scale-pool layout, which the dense per-slot "
                f"ring does not have")
        if cfg.kv_dtype != "bf16" and target.cfg.family == "ssm":
            raise ValueError(
                f"ServerConfig(kv_dtype={cfg.kv_dtype!r}) cannot serve "
                f"arch {target.cfg.name!r}: a pure-ssm target has no "
                f"attention KV pool to quantize (its recurrent state "
                f"stays dense in the carry)")
        if cfg.prefix_cache == "on":
            if cfg.cache != "paged":
                raise ValueError(
                    "ServerConfig(prefix_cache='on') requires "
                    "cache='paged': prefix reuse shares physical KV "
                    "blocks, which dense per-slot rings do not have")
            if target.is_recurrent:
                raise ValueError(
                    f"prefix_cache='on' is incompatible with arch "
                    f"{target.cfg.name!r}: its recurrent state cannot be "
                    "reconstructed from shared KV blocks")
            if target.cfg.sliding_window:
                raise ValueError(
                    f"prefix_cache='on' is incompatible with arch "
                    f"{target.cfg.name!r}: its sliding-window ring wraps "
                    f"(window={target.cfg.sliding_window}), so a block's "
                    "content is not a pure function of the token prefix — "
                    "published entries could alias across requests")

        if cfg.theta_mode not in ("fixed", "adaptive"):
            raise ValueError(f"unknown theta_mode {cfg.theta_mode!r} "
                             f"(fixed|adaptive)")
        self.controller = None
        if cfg.theta_mode == "adaptive":
            from repro.serving.controller import (ControllerConfig,
                                                  ThetaController)
            self.controller = ThetaController(ControllerConfig(
                theta_min=cfg.theta_min, theta_max=cfg.theta_max,
                relax_budget=cfg.relax_budget))
        if cfg.adaptive_k:
            if self.controller is None:
                raise ValueError("ServerConfig(adaptive_k=True) requires "
                                 "theta_mode='adaptive': the width bucket "
                                 "is picked by the same controller")
            if engine_cfg.topology != "chain":
                raise ValueError("adaptive_k supports the chain topology "
                                 "only (tree templates bake their own K)")
        if cfg.ring_depth < 0:
            raise ValueError(f"ring_depth={cfg.ring_depth} must be >= 0 "
                             f"(0 = device-side admission off)")
        if cfg.prefill_worker:
            reason = worker_unsupported_reason(target, cfg.cache)
            if reason is not None:
                raise ValueError(
                    f"ServerConfig(prefill_worker=True) cannot serve arch "
                    f"{target.cfg.name!r}: {reason}")
        if cfg.prefill_mesh is not None:
            if not cfg.prefill_worker:
                raise ValueError("ServerConfig(prefill_mesh=...) requires "
                                 "prefill_worker=True")
            if tuple(cfg.prefill_mesh) != tuple(cfg.mesh or (1, 1)):
                raise ValueError(
                    f"prefill_mesh={tuple(cfg.prefill_mesh)} must equal "
                    f"mesh={tuple(cfg.mesh or (1, 1))}: the worker writes "
                    f"the serving pool's own leaves, so its program must "
                    f"run where they live")

        # -- serving mesh (tentpole): partition the tick over (data, model)
        mesh_shape = tuple(cfg.mesh) if cfg.mesh else (1, 1)
        self.mesh = None
        self.data_shards = 1
        self.rules = None
        if mesh_shape != (1, 1):
            if b % mesh_shape[0]:
                raise ValueError(
                    f"slots={b} must be divisible by the data axis "
                    f"({mesh_shape[0]}) so every shard owns whole slots")
            from repro.launch.mesh import make_serving_mesh
            self.mesh = make_serving_mesh(*mesh_shape)
            self.data_shards = mesh_shape[0]
            self.rules = serving_rules()
        self._slots_per_shard = b // self.data_shards

        if cfg.cache == "paged" and target.cfg.family == "ssm":
            # zero-block layout: a pure-ssm cache carries no pool/table
            # leaves, so the paged server keeps the host pool empty and
            # gates admission on free slots only — requests never wait on
            # (nonexistent) pool headroom.  The dense-branch internals
            # below are exactly the right host state for that.
            self.paged = None
            self.max_blocks = 1          # dummy block_rows width
            self.pool = None
            self.slot_blocks: List[List[int]] = [[] for _ in range(b)]
            self.trash_ids = np.zeros((b,), np.int32)
            self.prefix = None
        elif cfg.cache == "paged":
            # sliding-window configs wrap their tables modulo the window,
            # so both the per-slot table width and the default pool size
            # are bounded by the window, not the context length
            window = target.cfg.sliding_window or 0
            ring_blocks = PagedCacheConfig(
                block_size=cfg.block_size).table_blocks(cfg.max_len, window)
            n_blocks = cfg.pool_blocks or 1 + b * ring_blocks
            if not cfg.pool_blocks and cfg.kv_dtype != "bf16":
                # size in BYTES for honest equal-HBM accounting: the
                # dense-equivalent budget above, refitted at the quantized
                # block cost — an int8 pool gets ~2-4x the blocks of the
                # unquantized default instead of silently shrinking to its
                # block count
                budget = n_blocks * pool_block_bytes(
                    target.cfg, cfg.block_size, "bf16")
                n_blocks = max(n_blocks, budget // pool_block_bytes(
                    target.cfg, cfg.block_size, cfg.kv_dtype))
            # the pool's block dim shards on "data": round to divisible
            n_blocks = -(-n_blocks // self.data_shards) * self.data_shards
            self.paged = PagedCacheConfig(block_size=cfg.block_size,
                                          n_blocks=n_blocks,
                                          kv_dtype=cfg.kv_dtype)
            self.max_blocks = self.paged.table_blocks(cfg.max_len, window)
            # physical blocks currently owned by each slot (host ledger;
            # the device only ever sees them through the table rows).  On a
            # mesh the free list is per-data-shard so a slot's block ids
            # never leave the pool partition of the shard that owns it.
            caps = dict(max_cached=cfg.prefix_cache_max_blocks,
                        ttl_s=cfg.prefix_cache_ttl_s)
            self.pool = (ShardedBlockPool(n_blocks, self.data_shards, **caps)
                         if self.data_shards > 1
                         else BlockPool(n_blocks, **caps))
            self.slot_blocks: List[List[int]] = [[] for _ in range(b)]
            # per-slot trash block: the reserved first block of the slot's
            # own pool partition (block 0 on one device), so masked and
            # unmapped writes scatter shard-locally
            self.trash_ids = np.asarray(
                slot_trash_blocks(b, n_blocks, self.data_shards))
            self.prefix = (PrefixCache(self.pool, cfg.block_size,
                                       n_shards=self.data_shards,
                                       min_match_blocks=cfg.min_match_blocks,
                                       kv_dtype=cfg.kv_dtype)
                           if cfg.prefix_cache == "on" else None)
        else:
            self.paged = None
            self.max_blocks = 1          # dummy block_rows width
            self.pool = None
            self.slot_blocks = [[] for _ in range(b)]
            self.trash_ids = np.zeros((b,), np.int32)
            self.prefix = None
        # host ledger of each slot's cached-prefix start (tokens whose KV
        # rode in shared) plus two prefill-cost counters the benchmark
        # reports: ``prefill_tokens`` sums per-request USEFUL positions
        # decoded (the KV work skipped by cached prefixes — the roofline
        # metric), ``prefill_window_tokens`` sums slots x window-width per
        # admission dispatch (the batched program's actual compute,
        # including masked rows — a cold admit sharing a pass with cached
        # ones forces the full window on everyone, so the two diverge on
        # mixed batches)
        self.slot_start = np.zeros((b,), np.int64)
        self.prefill_tokens = 0
        self.prefill_window_tokens = 0
        self.state = self.session.init_state(t_params, d_params, b,
                                             cfg.max_len, paged=self.paged,
                                             paged_shards=self.data_shards)
        # Host cache of the newest already-harvested per-slot stats rows.
        # Under ``overlap`` the ``stats`` property reads THIS instead of
        # polling the device: a fresh device_get mid-pipeline would stall
        # the double buffer and mutate ``host_syncs`` accounting for a
        # debug peek.  Refreshed in ``_apply_poll`` from transfers the
        # sync already pays for.
        self._stats_host = {
            k: np.zeros((b,), np.float32 if k == "margin_ema" else np.int64)
            for k in self.state.stats}
        if self.mesh is not None:
            from repro.launch.shardplan import (decode_state_shardings,
                                                param_shardings)
            self._state_shardings = decode_state_shardings(
                self.state, self.mesh, self.rules)
            self._t_shardings = param_shardings(t_params, self.mesh,
                                                self.rules)
            self._d_shardings = param_shardings(d_params, self.mesh,
                                                self.rules)
            # params placed once; every dispatch reuses the committed copies
            self.t_params = jax.device_put(t_params, self._t_shardings)
            self.d_params = jax.device_put(d_params, self._d_shardings)
            self.state = jax.device_put(self.state, self._state_shardings)

        # -- pipelined tick state (overlap / ring / worker) ----------------
        self._overlap = cfg.overlap
        # snapshots of in-flight groups' harvest views, oldest first; with
        # overlap on, sync() drains all but the newest (still-running) one
        self._pending: deque = deque()
        self._stepped = False      # a group was dispatched since last sync
        self.gather_calls = 0      # finished-row gathers dispatched
        # ticks x slots that sat idle while admissible work was waiting
        # (queued or staged) — the ring exists to pin this at zero
        self.slot_idle_ticks = 0
        self.ring_refills = 0      # device-side slot refills consumed
        self._ring = None
        self._ring_shardings = None
        self._ring_staged: deque = deque()   # host twins of staged entries
        self._ring_head_host = 0             # consumptions processed
        # slots the newest dispatched-but-unprocessed group may refill from
        # the ring: host admission must not race the device for them (the
        # double-claim would overwrite the refilled occupant's row)
        self._refill_inflight: set = set()
        # per-slot activation epoch: the dispatch index whose group first
        # ran the slot's CURRENT occupant.  A lagged snapshot (dispatch
        # idx < activation) predates the occupant — its rows belong to a
        # predecessor, so the harvest/refresh paths must skip the slot
        self._step_idx = 0
        self._slot_active_from = np.zeros((b,), np.int64)
        if cfg.ring_depth:
            self._ring = make_ring(cfg.ring_depth, cfg.max_prompt_len,
                                   self.max_blocks,
                                   int(self.state.buf.shape[1]))
            if self.mesh is not None:
                from repro.launch.shardplan import replicated_shardings
                self._ring_shardings = replicated_shardings(self._ring,
                                                            self.mesh)
                self._ring = jax.device_put(self._ring, self._ring_shardings)
        self.worker = None
        if cfg.prefill_worker:
            self.worker = PrefillWorker(
                target, cfg.max_prompt_len, mesh=self.mesh,
                state_shardings=(self._state_shardings
                                 if self.mesh is not None else None),
                t_shardings=(self._t_shardings
                             if self.mesh is not None else None))

        self.queue: deque[Request] = deque()
        self.slot_req: List[Optional[Request]] = [None] * b
        self.slot_t0 = np.zeros((b,), np.float64)
        self.slot_base_len = np.zeros((b,), np.int64)
        # host-side lower bound on tokens each slot still owes (refreshed
        # from budgets at admission, from polled lengths at sync) — this is
        # what lets the scheduler size fused tick groups with no waste
        self.slot_remaining = np.zeros((b,), np.int64)
        self._responses: List[Response] = []
        # host view of the finished flags, refreshed only at sync points
        # (init_state starts all-idle, i.e. every slot is refillable)
        self._finished_host = np.ones((b,), bool)
        self.host_syncs = 0        # device→host transfers performed
        self.step_calls = 0        # fused tick groups dispatched
        # observed tokens committed per cycle (EMA over the device-side
        # cycles/commits counters, which only advance while a slot is
        # active — so mid-group finishes don't bias the estimate) — drives
        # group sizing
        self._tau_est = float(self.session.topology.commit_width)
        self._last_cycles = np.zeros((b,), np.int64)
        self._last_commits = np.zeros((b,), np.int64)
        # host mirror of the carry's per-slot theta row (written at
        # admission and by controller retunes; the device copy is the
        # truth the verify reads)
        self.slot_theta = np.full((b,), engine_cfg.theta, np.float64)
        self.theta_retunes = 0     # controller dispatches (host→device)
        # adaptive-K bucket state: the controller flips the *next* group's
        # draft length between the full-K and half-K pre-jitted programs
        self._k_full = engine_cfg.k
        self._k_short = max(1, engine_cfg.k // 2)
        self._k_bucket = self._k_full
        self.session_short = None
        if cfg.adaptive_k and self._k_short < self._k_full:
            import copy
            short_drafter = copy.copy(drafter)
            short_drafter.k = self._k_short
            self.session_short = DecodeSession(
                target, short_drafter,
                dataclasses.replace(engine_cfg, k=self._k_short))

        def _rules_ctx():
            # trace-time: activates `constrain` annotations throughout the
            # session/model/verify stack when a mesh is set, else a no-op
            if self.mesh is None:
                return contextlib.nullcontext()
            return axis_rules(self.rules, mesh=self.mesh)

        # which entries need the cached-prefix machinery (start_pos / COW /
        # seeded positions): prefix hits, and worker-filled prompts (their
        # KV arrives exactly like a cached prefix)
        use_start = self.prefix is not None or self.worker is not None
        self._use_start = use_start
        ring_use_start = use_start
        trash_row = np.asarray(self.trash_ids, np.int32)
        ring_sps = (self._slots_per_shard if self.data_shards > 1 else None)

        def _make_fused(session):
            def _fused_cycles(tp, dp, state, steps):
                # dynamic trip count: group size varies tick to tick
                # without recompilation, and the loop exits early
                # on-device once every slot is finished (a mis-sized
                # group never burns dead cycles)
                with _rules_ctx():
                    return session.run_group(tp, dp, state, steps)
            return _fused_cycles

        def _make_fused_ring(session):
            def _fused_ring(tp, dp, state, ring, refillable, steps):
                # ring-aware group: same fused cycles, plus at most one
                # device-side slot refill per loop iteration (see
                # repro.serving.admission_ring for the two-guard contract)
                with _rules_ctx():
                    return fused_cycles_with_refill(
                        session, tp, dp, state, ring, refillable, steps,
                        trash_ids=jnp.asarray(trash_row),
                        slots_per_shard=ring_sps,
                        use_start=ring_use_start)
            return _fused_ring

        make_cycle = (_make_fused_ring if self._ring is not None
                      else _make_fused)
        _fused_cycles = make_cycle(self.session)

        def _set_theta_row(state, theta):
            # controller retune: ONE host→device write into the carry's
            # theta row; every other field passes through untouched
            return DecodeState(*state)._replace(theta=theta)

        def _admit_all(tp, dp, state, prompts, plens, smask, budgets, temps,
                       thetas, block_rows, starts, cow_src, cow_dst,
                       win_tokens, win_off):
            kw = {}
            if use_start:
                # cached-prefix (or worker-filled) admission: map shared
                # blocks read-only, COW-clone the partially matching tail,
                # decode only the un-cached window
                kw = dict(start_pos=starts, cow_src=cow_src,
                          cow_dst=cow_dst, decode_tokens=win_tokens,
                          decode_off=win_off)
            with _rules_ctx():
                return self.session.prefill(tp, dp, state, prompts, plens,
                                            slot_mask=smask, budget=budgets,
                                            temperature=temps, theta=thetas,
                                            block_rows=block_rows, **kw)

        def _gather_rows(state):
            # full slot-indexed rows; the host slices the finished slots.
            # (The old padded-index gather shipped the same bytes — a pad
            # to ``slots`` rows of buf width — with an extra dispatch axis.)
            return {"buf": state.buf, "lengths": state.lengths,
                    "stats": dict(state.stats)}

        # overlap snapshots: a NON-donated program whose outputs must be
        # fresh buffers — jnp.copy on every leaf, because returning the
        # carry's own arrays would alias buffers the NEXT donated dispatch
        # deletes, and the host reads snapshots one group late.  The field
        # sets come from _poll_stat_fields/_ring_harvest_fields — the SAME
        # helpers the serial sync path reads — so the snapshot and serial
        # polls can never drift on which stats rows ride the transfer.
        def _snap_state(state):
            return jax.tree_util.tree_map(jnp.copy, {
                "poll": self._poll_stat_fields(state),
                "rows": _gather_rows(state)})

        def _snap_ring(state, ring):
            return jax.tree_util.tree_map(jnp.copy, {
                "poll": self._poll_stat_fields(state, ring),
                "rows": _gather_rows(state),
                "ring": self._ring_harvest_fields(ring)})

        _snap = _snap_state if self._ring is None else _snap_ring

        # the carry is donated: the jitted program reuses its buffers
        # in place of allocating a fresh carry every dispatch.  On a mesh
        # the entry points carry explicit NamedShardings: the donated carry
        # keeps one stable sharding tree across dispatches, host-built
        # admission arrays (prompts, masks, budgets) land pre-split on
        # "data", and harvest gathers to a replicated (host-readable) tree.
        # The ring (and every snapshot leaf) is replicated: staged entries
        # are consumed by whichever shard owns the freed slot.
        if self.mesh is None:
            donate = (2,) if self._ring is None else (2, 3)
            self._cycle = jax.jit(_fused_cycles, donate_argnums=donate)
            self._cycle_short = (
                jax.jit(make_cycle(self.session_short),
                        donate_argnums=donate)
                if self.session_short is not None else None)
            self._prefill = jax.jit(_admit_all, donate_argnums=(2,))
            self._set_theta = jax.jit(_set_theta_row, donate_argnums=(0,))
            self._gather = jax.jit(_gather_rows)
            self._push = jax.jit(ring_push, donate_argnums=(0,))
            self._snapshot = jax.jit(_snap)
        else:
            repl = NamedSharding(self.mesh, P())
            row = NamedSharding(self.mesh, P("data"))
            mat = NamedSharding(self.mesh, P("data", None))
            if self._ring is None:
                cycle_shardings = dict(
                    in_shardings=(self._t_shardings, self._d_shardings,
                                  self._state_shardings, repl),
                    out_shardings=self._state_shardings)
                donate = (2,)
                snap_in = (self._state_shardings,)
            else:
                cycle_shardings = dict(
                    in_shardings=(self._t_shardings, self._d_shardings,
                                  self._state_shardings,
                                  self._ring_shardings, row, repl),
                    out_shardings=(self._state_shardings,
                                   self._ring_shardings))
                donate = (2, 3)
                snap_in = (self._state_shardings, self._ring_shardings)
            self._cycle = jax.jit(_fused_cycles, donate_argnums=donate,
                                  **cycle_shardings)
            self._cycle_short = (
                jax.jit(make_cycle(self.session_short),
                        donate_argnums=donate, **cycle_shardings)
                if self.session_short is not None else None)
            self._prefill = jax.jit(
                _admit_all, donate_argnums=(2,),
                in_shardings=(self._t_shardings, self._d_shardings,
                              self._state_shardings, mat, row, row, row,
                              row, row, mat, row, row, row, mat, repl),
                out_shardings=self._state_shardings)
            self._set_theta = jax.jit(
                _set_theta_row, donate_argnums=(0,),
                in_shardings=(self._state_shardings, row),
                out_shardings=self._state_shardings)
            self._gather = jax.jit(
                _gather_rows,
                in_shardings=(self._state_shardings,),
                out_shardings=repl)
            self._push = jax.jit(
                ring_push, donate_argnums=(0,),
                in_shardings=(self._ring_shardings,) + (repl,) * 10,
                out_shardings=self._ring_shardings)
            self._snapshot = jax.jit(_snap, in_shardings=snap_in,
                                     out_shardings=repl)

    # -- host snapshots of the carry (debug/inspection views).  The carry
    # is donated on every dispatch, so these return fresh host copies — a
    # device array view held across step() would be a deleted buffer — and
    # they go through the counted transfer funnel like every other read.
    @property
    def buf(self):
        return self._device_get(self.state.buf)

    @property
    def lengths(self):
        return self._device_get(self.state.lengths)

    @property
    def finished(self):
        return self._device_get(self.state.finished)

    @property
    def stats(self):
        if self._overlap:
            # Newest already-harvested snapshot, NOT a fresh device poll:
            # a mid-pipeline device_get would block on the in-flight group
            # (stalling the double buffer) and inflate ``host_syncs`` for
            # what is a debug peek.  The cache is refreshed from every
            # poll/gather the sync already pays for, so this is exactly as
            # current as the host's own view of the carry.
            d = {k: v.copy() for k, v in self._stats_host.items()}
        else:
            d = dict(self._device_get(self.state.stats))
        # host-side pipeline counters ride along for reporting: idle
        # slot-ticks while work waited (the ring's zero-idle claim),
        # finished-row gathers (the sync-gate regression), and device-side
        # refills consumed
        d["slot_idle_ticks"] = self.slot_idle_ticks
        d["gather_calls"] = self.gather_calls
        d["ring_refills"] = self.ring_refills
        return d

    def _poll_stat_fields(self, state, ring=None):
        """Single source of truth for which stat rows ride the sync poll.

        Shared by the overlap snapshot program (traced under jit) and the
        serial ``sync`` path, so the two can never drift: finished flags +
        lengths + cycle/commit counters always; the controller's inputs
        (accepts/relaxed/margin EMA) ride the SAME transfer when adaptive
        theta is on; the ring head when device-side admission is on."""
        f = {"finished": state.finished, "lengths": state.lengths,
             "cycles": state.stats["cycles"],
             "commits": state.stats["commits"]}
        if self.controller is not None:
            f.update(accepts=state.stats["accepts"],
                     relaxed=state.stats["relaxed"],
                     margin=state.stats["margin_ema"])
        if ring is not None:
            f["ring_head"] = ring.head
        return f

    @staticmethod
    def _ring_harvest_fields(ring):
        """The ring's harvest-record leaves (evicted occupants' rows) —
        shared by the overlap snapshot and the serial lazy fetch."""
        return {"h_buf": ring.h_buf, "h_len": ring.h_len,
                "h_stats": ring.h_stats, "h_slot": ring.h_slot}

    def _obs_span(self, name, **args):
        """Tick-phase span (no-op without telemetry)."""
        if self.obs is None:
            return contextlib.nullcontext()
        return self.obs.span(name, **args)

    def _device_get(self, tree):
        """Single funnel for device→host transfers (counted)."""
        self.host_syncs += 1
        return jax.device_get(tree)

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        if self.pool is not None:
            # reject can-never-fit requests HERE, before they enter the
            # queue: raising mid-admission would strand the requests
            # admitted earlier in the same batched prefill
            self._blocks_needed(min(len(req.prompt),
                                    self.cfg.max_prompt_len),
                                req.params.max_tokens)
        self.queue.append(req)
        if self.obs is not None:
            self.obs.on_submit(req.uid, prompt_len=len(req.prompt),
                               max_tokens=req.params.max_tokens)

    def cancel(self, uid: int) -> bool:
        """Cancel a request still waiting in the host queue.  Returns True
        if it was removed.  A request already staged or seated keeps
        running — its blocks and slot are device-owned mid-group, so
        in-flight cancellation belongs to the serving front door (it
        would ride the existing poll, like everything else here)."""
        for i, r in enumerate(self.queue):
            if r.uid == uid:
                del self.queue[i]
                if self.obs is not None:
                    self.obs.on_cancel(uid)
                return True
        return False

    def _usable_prefix(self, plen: int) -> int:
        """Prompt tokens whose KV may ride in from the prefix cache: the
        final prompt token always stays pending (never cached), and
        feature-carrying drafters additionally need the second-to-last
        token decoded live to ground their feature."""
        keep = 2 if self.session.drafter.wants_features else 1
        return max(plen - keep, 0)

    def _defer_for_sibling(self, ptoks, usable: int, matched: int,
                           pending) -> bool:
        """Cached-prefix admission, same-pass case: a cold sibling admitted
        earlier in THIS pass publishes its prompt blocks right after the
        dispatch, so a request sharing that prefix is worth holding ONE
        tick — it then rides the published blocks instead of paying a
        duplicate cold prefill.  Only a common prefix that beats both the
        ``min_match_blocks`` floor and what the index already offers
        defers."""
        bs = self.cfg.block_size
        thresh = self.cfg.min_match_blocks * bs
        for sib_toks, sib_plen in pending:
            # the sibling publishes its prompt's full blocks only
            lim = min(usable, ((sib_plen - 1) // bs) * bs, len(sib_toks))
            if lim <= 0:
                continue
            eq = np.equal(ptoks[:lim], sib_toks[:lim])
            common = lim if eq.all() else int(eq.argmin())
            common = (common // bs) * bs
            if common >= thresh and common > matched:
                return True
        return False

    def _admit(self):
        """Admission pass: fill refillable slots host-side (one batched
        prefill), then — with the device-side ring on — stage head-of-queue
        requests on device so mid-group finishers refill without waiting
        for the next sync."""
        with self._obs_span("admit"):
            self._admit_free_slots()
            if self._ring is not None:
                self._stage_ring()

    def _free_slot_order(self, free: List[int]) -> List[int]:
        """Cross-shard work stealing (``shard_steal``): visit free slots in
        order of their shard's live-request load (fewest first), breaking
        ties toward more pool headroom, then slot id.  A data shard whose
        requests all drained early therefore takes the head of the queue
        even when the request would "belong" to a loaded shard — FIFO over
        requests is untouched, only the admitting slot changes.  Off (or
        single-shard), admission scans slots in id order exactly as
        before."""
        if not self.cfg.shard_steal or self.data_shards <= 1:
            return free
        live = [0] * self.data_shards
        for s in range(self.cfg.slots):
            if self.slot_req[s] is not None and not self._finished_host[s]:
                live[s // self._slots_per_shard] += 1
        avail = [self.pool.available(sh) if self.pool is not None else 0
                 for sh in range(self.data_shards)]
        return sorted(free, key=lambda s: (
            live[s // self._slots_per_shard],
            -avail[s // self._slots_per_shard], s))

    def _admit_free_slots(self):
        """Admit queued requests into refillable slots with ONE slot-masked
        prefill call (no per-request dispatch, no host reads: refillable
        slots are known from the last sync's ``finished`` poll).

        Admission hysteresis: a prefill pass costs the same whether it
        admits one request or all of them, so a freed slot is held back
        while more finishers are expected within ONE more fused group —
        clustered finishes then share a single prefill pass — but never
        longer: when the remaining slots still owe more than a group's
        worth of tokens, the free slots admit immediately rather than idle
        behind a long-running neighbour.

        With the prefix cache on, each candidate prompt is first matched
        against the published-block index: fully matching blocks map
        read-only (``acquire``), a partially matching tail block is COW
        cloned into the first private block, and the slot's prefill starts
        at the divergence point.  The prompt's own full blocks are
        published immediately after the dispatch."""
        b = self.cfg.slots
        free = [s for s in range(b)
                if self._finished_host[s] and self.slot_req[s] is None
                and s not in self._refill_inflight]
        if not free or not self.queue:
            return
        free = self._free_slot_order(free)
        if len(free) < min(len(self.queue), b):
            active = [int(self.slot_remaining[s]) for s in range(b)
                      if self.slot_req[s] is not None
                      and not self._finished_host[s]]
            tau = min(max(self._tau_est, 1.0),
                      float(self.session.topology.commit_width))
            if active and np.ceil(min(active) / tau) <= 1:
                return      # next finisher ~1 group away: wait and batch
        s_len = self.cfg.max_prompt_len
        prompts = np.zeros((b, s_len), np.int32)
        plens = np.zeros((b,), np.int32)
        smask = np.zeros((b,), bool)
        budgets = np.zeros((b,), np.int32)
        temps = np.zeros((b,), np.float32)
        # non-admitted rows keep their carried theta: splat the host
        # mirror, overwrite admitted rows below (prefill's slot mask only
        # writes masked rows, but the full array must still be well-formed)
        thetas = self.slot_theta.astype(np.float32).copy()
        # unmapped table rows point at the slot's (shard-local) trash block
        rows = np.tile(self.trash_ids.astype(np.int32)[:, None],
                       (1, self.max_blocks))
        starts = np.zeros((b,), np.int32)
        # device starts vs ledger starts diverge under the prefill worker:
        # the device seeds everything the worker wrote (narrow window), the
        # ledgers keep counting only the SHARED tokens as skipped work
        match_starts = np.zeros((b,), np.int32)
        cow_src = self.trash_ids.astype(np.int32).copy()
        cow_dst = self.trash_ids.astype(np.int32).copy()
        pending: dict = {}             # shard -> [(ptoks, plen)] cold this pass
        admitted = []                  # (slot, ptoks, plen, shard)
        now = time.time()
        for slot in free:
            if not self.queue:
                break
            req = self.queue[0]
            plen = min(len(req.prompt), s_len)
            shard = slot // self._slots_per_shard
            if self.pool is not None:
                # paged admission is gated by POOL headroom, not slot count:
                # a free slot with an empty pool stays idle until a harvest
                # returns blocks (FIFO — later, smaller requests don't jump
                # a starved head-of-queue request).  On a mesh the headroom
                # is per data shard: blocks come from the partition of the
                # shard owning THIS slot, and when that shard is short the
                # same head request retries on free slots of other shards.
                need = self._blocks_needed(plen, req.params.max_tokens)
                shared: List[int] = []
                match = None
                if self.prefix is not None:
                    ptoks = np.asarray(req.prompt[:plen], np.int32)
                    usable = self._usable_prefix(plen)
                    match = self.prefix.match(ptoks, usable, shard)
                    if self._defer_for_sibling(
                            ptoks, usable, match.tokens,
                            pending.get(shard, [])):
                        break          # FIFO: hold the queue one tick
                    shared = list(match.blocks)
                    if shared:
                        # shared blocks are counted ONCE in pool headroom:
                        # they are referenced, not allocated
                        self.pool.acquire(shared)
                blocks = self._pool_alloc(need - len(shared), shard)
                if blocks is None:
                    if shared:
                        self.pool.free(shared)
                    if self.data_shards > 1:
                        continue
                    break
                table = shared + blocks
                self.slot_blocks[slot] = table
                rows[slot, :len(table)] = table
                if match is not None and match.hit:
                    starts[slot] = match.tokens
                    match_starts[slot] = match.tokens
                    if match.cow is not None:
                        # first write into the shared tail block must not
                        # land: clone it into the slot's first private
                        # block before the prefill writes (COW)
                        assert blocks, "COW needs a private block"
                        cow_src[slot] = match.cow[0]
                        cow_dst[slot] = blocks[0]
                if self.prefix is not None:
                    self.prefix.record_admission(match, usable)
                    pending.setdefault(shard, []).append((ptoks, plen))
                    admitted.append((slot, ptoks, plen, shard))
                if self.worker is not None:
                    # disaggregated prefill: fill [starts, usable) into the
                    # slot's blocks with the worker program BEFORE the
                    # admission pass, then hand the warm table over exactly
                    # like a cached prefix — the batched decode window no
                    # longer widens for this cold prompt.  The worker owns
                    # the COW clone, so admission must NOT re-clone (it
                    # would overwrite the worker's rows in that block).
                    w_usable = self._usable_prefix(plen)
                    w_start = int(starts[slot])
                    if w_usable > w_start:
                        tok_row = np.zeros((s_len,), np.int32)
                        tok_row[:plen] = req.prompt[:plen]
                        with self._obs_span("worker_fill"):
                            self.state = self.worker.fill(
                                self.t_params, self.state, tok_row,
                                rows[slot], w_start, w_usable,
                                int(cow_src[slot]), int(cow_dst[slot]),
                                int(self.trash_ids[slot]))
                        starts[slot] = w_usable
                        cow_src[slot] = self.trash_ids[slot]
                        cow_dst[slot] = self.trash_ids[slot]
                        if self.obs is not None:
                            self.obs.on_prefill_handoff(
                                req.uid, w_usable - w_start)
            self.queue.popleft()
            prompts[slot, :plen] = req.prompt[:plen]
            plens[slot] = plen
            smask[slot] = True
            budgets[slot] = req.params.max_tokens
            temps[slot] = req.params.temperature
            th = (req.params.theta if req.params.theta is not None
                  else self.ecfg.theta)
            if self.controller is not None:
                th = self.controller.clamp(th)
            thetas[slot] = th
            self.slot_theta[slot] = th
            self.slot_req[slot] = req
            self.slot_t0[slot] = now
            self.slot_base_len[slot] = plen
            self.slot_remaining[slot] = min(
                req.params.max_tokens,
                self.cfg.max_len - plen)       # buffer-room bound
            self._finished_host[slot] = False
            # active from the NEXT dispatch: snapshots of earlier groups
            # predate this occupant
            self._slot_active_from[slot] = self._step_idx
            self.slot_start[slot] = int(match_starts[slot])
            # useful positions decoded for this request (worker-filled
            # positions count: they are decoded, just off the batched pass)
            self.prefill_tokens += max(plen - 1 - int(match_starts[slot]), 0)
            # prefill resets the admitted rows' device stats to zero
            self._last_cycles[slot] = 0
            self._last_commits[slot] = 0
            if self.obs is not None:
                self.obs.on_admitted(
                    req.uid, slot, theta=float(th),
                    prefix_hit_tokens=int(match_starts[slot]),
                    blocks_held=len(self.slot_blocks[slot]),
                    via_ring=False)
        if not smask.any():
            return                       # pool exhausted before any admit
        # decode window: the un-cached tail across all admitted rows,
        # width-bucketed (multiples of 32) to bound jit specialisations
        if self._use_start:
            min_start = min(int(starts[s]) for s in range(b) if smask[s])
            w = min(s_len, max(-(-(s_len - min_start) // 32) * 32, 1))
            off = s_len - w
            win = np.ascontiguousarray(prompts[:, off:])
        else:
            # the traced program ignores the window when the prefix cache
            # is off — ship a (B, 1) dummy instead of a prompt duplicate
            off, w = 0, s_len
            win = np.zeros((b, 1), np.int32)
        self.prefill_window_tokens += b * w
        self.state = self._prefill(
            self.t_params, self.d_params, self.state, prompts, plens,
            smask, budgets, temps, thetas, rows, starts, cow_src, cow_dst,
            win, np.int32(off))
        # publish the admitted prompts' full blocks NOW: a prompt is
        # committed content by definition, and device dispatches execute in
        # submission order — the next pass's partial prefills may read them
        for slot, ptoks, plen, shard in admitted:
            self.prefix.publish(ptoks[:plen - 1], self.slot_blocks[slot],
                                shard)

    def _stage_shard(self) -> int:
        """Data shard the next staged entry binds to: fewest outstanding
        staged entries first (the ring drains round-robin under balanced
        load), then most pool headroom — the stealing policy again, applied
        to staging."""
        if self.data_shards == 1:
            return 0
        counts = [0] * self.data_shards
        for ent in self._ring_staged:
            counts[ent.shard] += 1
        avail = [self.pool.available(sh) if self.pool is not None else 0
                 for sh in range(self.data_shards)]
        return min(range(self.data_shards),
                   key=lambda sh: (counts[sh], -avail[sh], sh))

    def _stage_ring(self):
        """Stage head-of-queue requests into the device-side admission ring
        (host half): allocate their blocks NOW (worst-case reservation, so
        a mid-group refill never allocates), prefix-match against the
        published index, optionally worker-fill the prompt body, and push
        the entry on-device.  The fused group consumes entries into freed
        slots mid-group; the host learns about each consumption from the
        polled ring head and finishes the bookkeeping in ``sync``.

        Staged entries match only ALREADY-published prefixes — two staged
        siblings cannot share blocks with each other (publication happens
        at consumption), so a shared-prefix burst deeper than the free
        slots pays a duplicate cold prefill instead of deferring.  FIFO
        over requests is preserved: the queue head is staged first."""
        depth = self.cfg.ring_depth
        s_len = self.cfg.max_prompt_len
        while self.queue and len(self._ring_staged) < depth:
            req = self.queue[0]
            plen = min(len(req.prompt), s_len)
            shard = self._stage_shard()
            start = 0              # device start (seeded positions)
            match_start = 0        # shared tokens (ledger)
            cow_src = cow_dst = NO_COW
            table: List[int] = []
            if self.pool is not None:
                need = self._blocks_needed(plen, req.params.max_tokens)
                shared: List[int] = []
                match = None
                ptoks = np.asarray(req.prompt[:plen], np.int32)
                usable = self._usable_prefix(plen)
                if self.prefix is not None:
                    match = self.prefix.match(ptoks, usable, shard)
                    shared = list(match.blocks)
                    if shared:
                        self.pool.acquire(shared)
                blocks = self._pool_alloc(need - len(shared), shard)
                if blocks is None and self.data_shards > 1:
                    # stealing, staging flavour: the preferred shard is
                    # short — retry the others (most headroom first)
                    for alt in sorted(
                            range(self.data_shards),
                            key=lambda sh: -self.pool.available(sh)):
                        if alt == shard:
                            continue
                        blocks = self._pool_alloc(need - len(shared), alt)
                        if blocks is not None and self.prefix is not None:
                            # shared blocks are shard-local: re-match on
                            # the shard that actually has room
                            if shared:
                                self.pool.free(shared)
                            match = self.prefix.match(ptoks, usable, alt)
                            shared = list(match.blocks)
                            if shared:
                                self.pool.acquire(shared)
                        if blocks is not None:
                            shard = alt
                            break
                if blocks is None:
                    if shared:
                        self.pool.free(shared)
                    break          # pool-starved: keep FIFO, stop staging
                table = shared + blocks
                if match is not None and match.hit:
                    start = match_start = match.tokens
                    if match.cow is not None:
                        assert blocks, "COW needs a private block"
                        cow_src = int(match.cow[0])
                        cow_dst = int(blocks[0])
                if self.prefix is not None:
                    self.prefix.record_admission(match, usable)
            tok_row = np.zeros((s_len,), np.int32)
            tok_row[:plen] = req.prompt[:plen]
            if self.worker is not None:
                usable = self._usable_prefix(plen)
                if usable > start:
                    trash = int(self.trash_ids[shard
                                               * self._slots_per_shard])
                    row_np = np.full((self.max_blocks,), trash, np.int32)
                    row_np[:len(table)] = table
                    with self._obs_span("worker_fill"):
                        self.state = self.worker.fill(
                            self.t_params, self.state, tok_row, row_np,
                            start, usable,
                            cow_src if cow_src != NO_COW else trash,
                            cow_dst if cow_dst != NO_COW else trash, trash)
                    if self.obs is not None:
                        self.obs.on_prefill_handoff(req.uid, usable - start)
                    start = usable
                    cow_src = cow_dst = NO_COW
            th = (req.params.theta if req.params.theta is not None
                  else self.ecfg.theta)
            if self.controller is not None:
                th = self.controller.clamp(th)
            trash = int(self.trash_ids[shard * self._slots_per_shard])
            row_np = np.full((self.max_blocks,), trash, np.int32)
            row_np[:len(table)] = table
            self._ring = self._push(
                self._ring, tok_row, np.int32(plen),
                np.int32(req.params.max_tokens),
                np.float32(req.params.temperature), np.float32(th),
                np.int32(start), row_np, np.int32(cow_src),
                np.int32(cow_dst), np.int32(shard))
            self._ring_staged.append(_StagedEntry(
                req=req, ptoks=tok_row, plen=plen, blocks=table,
                shard=shard, match_start=match_start, theta=float(th),
                t0=time.time()))
            self.prefill_tokens += max(plen - 1 - match_start, 0)
            self.queue.popleft()
            if self.obs is not None:
                self.obs.on_staged(req.uid, shard=shard)

    def _pool_alloc(self, n: int, shard: int):
        """Allocate ``n`` blocks from ``shard``'s pool partition (the data
        shard owning the admitting slot: ``slot // slots_per_shard``), so a
        slot only ever references shard-local blocks."""
        if self.data_shards > 1:
            return self.pool.alloc(n, shard)
        return self.pool.alloc(n)

    def _blocks_needed(self, plen: int, max_tokens: int) -> int:
        """Worst-case physical blocks for a request (see
        :meth:`~repro.models.paging.PagedCacheConfig.request_blocks`): the
        reservation covers prompt + budget + speculative overhang, so
        mid-flight rollback never needs new blocks — the index rewind stays
        within what admission reserved."""
        need = self.paged.request_blocks(
            plen, max_tokens, self.session.topology.buffer_margin,
            self.cfg.max_len, self.target.cfg.sliding_window or 0)
        cap = (self.pool.shard_capacity
               if isinstance(self.pool, ShardedBlockPool)
               else self.pool.n_blocks - 1)
        if need > cap:
            where = (f"each data shard's pool partition only has {cap}"
                     if self.data_shards > 1
                     else f"the pool only has {cap}")
            raise ValueError(
                f"request needs {need} blocks but {where}; raise "
                f"ServerConfig.pool_blocks or block_size")
        return need

    def _group_size(self) -> int:
        """Fused cycles until the next moment a slot is *expected* to
        finish: a cycle commits at most ``commit_width`` tokens but on
        average ``tau`` of them, so a slot owing ``r`` tokens runs for
        about ``ceil(r / tau)`` more cycles (never fewer than
        ``ceil(r / commit_width)``).  Computed entirely from host-cached
        budgets/lengths and the observed tau — no transfer.  An EOS token
        can preempt a slot much earlier, so then ``steps_per_sync`` caps
        the group."""
        w = self._active_session().topology.commit_width
        active = [int(self.slot_remaining[s])
                  for s in range(self.cfg.slots)
                  if self.slot_req[s] is not None and not self._finished_host[s]]
        staged_n = len(self._ring_staged) if self._ring is not None else 0
        if staged_n:
            # staged entries join the group mid-flight: size for them too
            # (each refill consumes one loop iteration before its cycles)
            active += [min(ent.req.params.max_tokens,
                           self.cfg.max_len - ent.plen)
                       for ent in self._ring_staged]
        if not active:
            return 1
        tau = min(max(self._tau_est, 1.0), float(w))
        steps = max(1, int(np.ceil(min(active) / tau))) + staged_n
        if self.ecfg.eos_token is not None and staged_n == 0:
            # the on-device "earliest possible EOS" logic inverts the old
            # cap: with entries staged, an early EOS frees a slot the ring
            # refills immediately, so the group may fuse PAST
            # steps_per_sync — the host has nothing to do at the boundary
            steps = min(steps, max(1, self.cfg.steps_per_sync))
        return steps

    def _active_session(self):
        """The DecodeSession whose pre-jitted tick program the next group
        dispatches (adaptive-K picks the half-K bucket when acceptance is
        low; everyone else always runs the full-K session)."""
        if (self.session_short is not None
                and self._k_bucket == self._k_short):
            return self.session_short
        return self.session

    def step(self):
        """One scheduler tick: dispatch one fused group of verify cycles
        (adaptively sized, see :meth:`_group_size`).  Budget exhaustion,
        EOS, and buffer limits all flip ``finished`` inside the jitted
        program — no device→host transfer happens here (with ``overlap``
        on, not even implicitly: the harvest snapshot is dispatched, held
        as device handles, and read one group later in ``sync``)."""
        staged_n = len(self._ring_staged) if self._ring is not None else 0
        if all(r is None for r in self.slot_req) and staged_n == 0:
            return                      # nothing in flight: no dispatch
        # idle accounting: slots that enter this group empty while
        # admissible work is waiting.  With the ring on, up to ``staged_n``
        # of them are refilled by the device at the group's first
        # iteration, so only the excess idles.
        if self.queue or staged_n:
            empty = sum(1 for r in self.slot_req if r is None)
            self.slot_idle_ticks += max(0, empty - staged_n)
        self.step_calls += 1
        idx = self._step_idx
        self._step_idx += 1
        cycle = (self._cycle if self._active_session() is self.session
                 else self._cycle_short)
        steps = np.int32(self._group_size())
        # the dispatch span measures host ENQUEUE wall time (the dispatch
        # is async — device compute shows up in the profiler trace, and
        # the benchmark's fenced --profile-phases mode remains the ground
        # truth for the device-side phase split)
        with self._obs_span("dispatch", steps=int(steps), group=idx):
            if self._ring is None:
                self.state = cycle(self.t_params, self.d_params, self.state,
                                   steps)
            else:
                # harvested (host-processed) slots are safe for the device
                # to refill from iteration 0; unharvested finished slots
                # stay frozen until the lagged snapshot holding them is read
                refillable = np.array([r is None for r in self.slot_req],
                                      bool)
                # under overlap this dispatch outlives the next _admit: the
                # device owns every refillable slot until its snapshot is
                # processed, so host admission must skip them (no
                # double-claim)
                self._refill_inflight = (
                    set(np.flatnonzero(refillable).tolist())
                    if self._overlap and staged_n else set())
                self.state, self._ring = cycle(self.t_params, self.d_params,
                                               self.state, self._ring,
                                               refillable, steps)
            if self._overlap:
                snap = dict(self._snapshot(self.state) if self._ring is None
                            else self._snapshot(self.state, self._ring))
                snap["idx"] = idx
                self._pending.append(snap)
                self._stepped = True
        if self.obs is not None and self._overlap:
            self.obs.on_inflight(len(self._pending))

    def sync(self, *, flush: bool = False):
        """The only point where the host observes the carry.

        Serial mode: one poll of the finished flags + lengths (refreshing
        the group-sizing bounds), then — only when something finished — a
        single gathered ``device_get`` of the full slot rows.

        Overlap mode: ``step()`` left one snapshot per dispatched group in
        ``_pending``; this drains every snapshot EXCEPT the newest one
        when a group was just dispatched (``flush=True`` drains that too).
        Reading a snapshot's poll blocks only until ITS group completed —
        the newer in-flight group keeps the drafter busy while the older
        harvest crosses to the host.  Finished rows frozen by the cycle
        stay bit-stable, so a one-group-late harvest reads the same
        tokens the serial tick would have."""
        with self._obs_span("harvest", flush=flush):
            if self._overlap:
                keep = 1 if (self._stepped and not flush) else 0
                self._stepped = False
                while len(self._pending) > keep:
                    snap = self._pending.popleft()
                    poll = self._device_get(snap["poll"])
                    self._apply_poll(
                        poll, lambda: self._device_get(snap["rows"]),
                        (lambda: self._device_get(snap["ring"]))
                        if "ring" in snap else None,
                        idx=snap["idx"])
                return
            # same field set as the overlap snapshot program — both come
            # from _poll_stat_fields, so the two paths cannot drift
            poll = self._device_get(
                self._poll_stat_fields(self.state, self._ring))
            self._apply_poll(
                poll, lambda: self._device_get(self._gather(self.state)),
                (lambda: self._device_get(
                    self._ring_harvest_fields(self._ring)))
                if self._ring is not None else None,
                idx=self._step_idx - 1)

    def _apply_poll(self, poll, fetch_rows, fetch_ring, *, idx):
        """Process the completed poll of the group dispatched at ``idx``:
        ring consumptions first (they re-seat slots, so the per-slot
        refresh below sees the NEW occupants), then the tau/remaining
        refresh, then harvest of finished rows via ``fetch_rows`` (one
        lazy transfer, dispatched only when >= 1 slot finished), then the
        controller retune.  Slots whose occupant activated AFTER ``idx``
        are skipped everywhere: the snapshot's rows and stats belong to a
        harvested predecessor, not to them."""
        self._finished_host = np.array(poll["finished"])  # writable copy
        if fetch_ring is not None:
            self._consume_ring(poll, fetch_ring, idx)
        fresh = [self._slot_active_from[s] <= idx
                 for s in range(self.cfg.slots)]
        d_cycles = d_commits = 0
        for s in range(self.cfg.slots):
            if not fresh[s]:
                # the occupant postdates this snapshot: it is still
                # running whatever the stale finished flag says
                if self.slot_req[s] is not None:
                    self._finished_host[s] = False
                continue
            if self.slot_req[s] is not None:
                req = self.slot_req[s]
                produced = int(poll["lengths"][s]) - int(self.slot_base_len[s])
                if self.obs is not None and produced > 0:
                    # first poll whose lengths exceed the slot's base is
                    # the host's first (and honest) observation of a
                    # commit — TTFT quantizes to sync granularity because
                    # that is when a streaming API could first emit it
                    self.obs.on_first_commit(req.uid, produced)
                self.slot_remaining[s] = min(
                    req.params.max_tokens - produced,
                    self.cfg.max_len - int(poll["lengths"][s]))
                d_cycles += int(poll["cycles"][s]) - int(self._last_cycles[s])
                d_commits += (int(poll["commits"][s])
                              - int(self._last_commits[s]))
                self._last_cycles[s] = int(poll["cycles"][s])
                self._last_commits[s] = int(poll["commits"][s])
        if d_cycles > 0:
            obs = d_commits / d_cycles
            self._tau_est = 0.5 * self._tau_est + 0.5 * max(obs, 0.1)
        # refresh the host stats cache (the overlap ``stats`` view) from
        # rows this poll already carried — fresh slots only, a lagged
        # snapshot's stale rows belong to a harvested predecessor
        fmask = np.asarray(fresh, bool)
        for pk, sk in (("cycles", "cycles"), ("commits", "commits"),
                       ("accepts", "accepts"), ("relaxed", "relaxed"),
                       ("margin", "margin_ema")):
            if pk in poll:
                self._stats_host[sk][fmask] = np.asarray(poll[pk])[fmask]
        done = [s for s in range(self.cfg.slots)
                if fresh[s] and self._finished_host[s]
                and self.slot_req[s] is not None]
        if done:
            with self._obs_span("gather", slots=len(done)):
                rows = fetch_rows()
            self.gather_calls += 1
            # the gather ships every stat row (controller or not): fold
            # them all into the host cache
            for sk, vals in rows["stats"].items():
                self._stats_host[sk][fmask] = np.asarray(vals)[fmask]
            now = time.time()
            for slot in done:
                req = self.slot_req[slot]
                base = int(self.slot_base_len[slot])
                length = int(rows["lengths"][slot])
                toks = rows["buf"][slot, base:length]
                self._responses.append(Response(
                    uid=req.uid, tokens=np.asarray(toks),
                    n_cycles=int(rows["stats"]["cycles"][slot]),
                    n_committed=int(rows["stats"]["commits"][slot]),
                    latency_s=now - self.slot_t0[slot],
                    n_accepted=int(rows["stats"]["accepts"][slot])))
                if self.obs is not None:
                    # device stats + block/theta context captured BEFORE
                    # the slot is freed below
                    self.obs.on_finish(
                        req.uid, n_tokens=int(length - base),
                        n_cycles=int(rows["stats"]["cycles"][slot]),
                        n_accepted=int(rows["stats"]["accepts"][slot]),
                        n_relaxed=int(rows["stats"]["relaxed"][slot]),
                        margin_ema=float(rows["stats"]["margin_ema"][slot]),
                        theta=float(self.slot_theta[slot]),
                        blocks_held=len(self.slot_blocks[slot]))
                self.slot_req[slot] = None
                if self.pool is not None and self.slot_blocks[slot]:
                    if self.prefix is not None:
                        # publish the generated history's full blocks
                        # before releasing: positions < length-1 hold
                        # exactly the committed chain's KV (the pending
                        # token and any rejected-draft stale rows lie
                        # beyond), so only those full blocks are
                        # content-addressable
                        committed = np.asarray(
                            rows["buf"][slot, :max(length - 1, 0)],
                            np.int32)
                        self.prefix.publish(committed,
                                            self.slot_blocks[slot],
                                            slot // self._slots_per_shard)
                    # block-list truncate at its terminal point: the
                    # finished slot drops its references — unpublished
                    # blocks return to the pool, published ones park in
                    # the reclaimable LRU (the table rows are unmapped by
                    # reset_slots at the next admission)
                    self.pool.free(self.slot_blocks[slot])
                    self.slot_blocks[slot] = []
        self._retune(poll, fresh)
        if self.obs is not None:
            live = [s for s in range(self.cfg.slots)
                    if self.slot_req[s] is not None
                    and not self._finished_host[s]]
            margin_mean = (float(np.mean([poll["margin"][s] for s in live]))
                           if "margin" in poll and live else None)
            self.obs.on_sync(queue_depth=len(self.queue),
                             slots_active=len(live),
                             inflight=len(self._pending),
                             margin_mean=margin_mean)

    def _consume_ring(self, poll, fetch_ring, idx):
        """Finish the host half of every ring consumption this poll
        reveals: emit the evicted occupant's response from the harvest
        record the device wrote at refill time, release its blocks, then
        install the staged request in the slot's host ledgers and publish
        its prompt blocks (the poll proves the refill prefill completed,
        so the blocks hold committed content)."""
        consumed = int(poll["ring_head"]) - self._ring_head_host
        if consumed <= 0:
            return
        ring = fetch_ring()
        now = time.time()
        depth = self.cfg.ring_depth
        b = self.cfg.slots
        for _ in range(consumed):
            e = self._ring_head_host % depth
            ent = self._ring_staged.popleft()
            slot = int(ring["h_slot"][e])
            old = self.slot_req[slot]
            if old is not None:
                # evicted occupant: response + publish + free, all from
                # the device-written harvest record (the slot's live row
                # now belongs to the staged request)
                h_len = int(ring["h_len"][e])
                base = int(self.slot_base_len[slot])
                self._responses.append(Response(
                    uid=old.uid,
                    tokens=np.asarray(ring["h_buf"][e, base:h_len]),
                    n_cycles=int(ring["h_stats"]["cycles"][e]),
                    n_committed=int(ring["h_stats"]["commits"][e]),
                    latency_s=now - self.slot_t0[slot],
                    n_accepted=int(ring["h_stats"]["accepts"][e])))
                if self.obs is not None:
                    # the harvest record the device wrote at refill time
                    # carries the full stat row — same zero-extra-transfer
                    # story as the gathered finish path
                    self.obs.on_finish(
                        old.uid, n_tokens=int(max(h_len - base, 0)),
                        n_cycles=int(ring["h_stats"]["cycles"][e]),
                        n_accepted=int(ring["h_stats"]["accepts"][e]),
                        n_relaxed=int(ring["h_stats"]["relaxed"][e]),
                        margin_ema=float(ring["h_stats"]["margin_ema"][e]),
                        theta=float(self.slot_theta[slot]),
                        blocks_held=len(self.slot_blocks[slot]))
                if self.pool is not None and self.slot_blocks[slot]:
                    if self.prefix is not None:
                        committed = np.asarray(
                            ring["h_buf"][e, :max(h_len - 1, 0)], np.int32)
                        self.prefix.publish(committed,
                                            self.slot_blocks[slot],
                                            slot // self._slots_per_shard)
                    self.pool.free(self.slot_blocks[slot])
                    self.slot_blocks[slot] = []
            # seat the staged request (device side already prefilled it);
            # the refill happened inside THIS snapshot's group, so the
            # occupant is fresh for this very poll (harvestable now if it
            # also finished in-group)
            self._slot_active_from[slot] = idx
            self.slot_req[slot] = ent.req
            self.slot_blocks[slot] = ent.blocks
            self.slot_t0[slot] = ent.t0
            self.slot_base_len[slot] = ent.plen
            self.slot_remaining[slot] = min(ent.req.params.max_tokens,
                                            self.cfg.max_len - ent.plen)
            self.slot_start[slot] = ent.match_start
            self.slot_theta[slot] = ent.theta
            self._last_cycles[slot] = 0
            self._last_commits[slot] = 0
            # the in-loop refill decodes the full (slots, max_prompt_len)
            # masked window — count the batched compute honestly
            self.prefill_window_tokens += b * self.cfg.max_prompt_len
            if self.prefix is not None:
                self.prefix.publish(ent.ptoks[:ent.plen - 1], ent.blocks,
                                    ent.shard)
            self._ring_head_host += 1
            self.ring_refills += 1
            if self.obs is not None:
                self.obs.on_admitted(
                    ent.req.uid, slot, theta=float(ent.theta),
                    prefix_hit_tokens=int(ent.match_start),
                    blocks_held=len(ent.blocks), via_ring=True)

    def _retune(self, poll, fresh=None):
        """Controller pass at the sync boundary: retune every live slot's
        theta from stats the poll already transferred, then (only when
        something actually moved) dispatch ONE host→device write into the
        carry's theta row.  Runs strictly between fused groups, so the
        sync-free tick contract is untouched — ``step()`` still performs
        zero device→host transfers, and ``host_syncs`` does not grow here
        (the retune is a host→device scatter, the cheap direction).
        ``fresh`` masks out slots whose occupant postdates the poll (their
        stats rows belong to a predecessor)."""
        if self.controller is None:
            return
        with self._obs_span("retune"):
            live = [s for s in range(self.cfg.slots)
                    if self.slot_req[s] is not None
                    and not self._finished_host[s]
                    and (fresh is None or fresh[s])]
            if self.session_short is not None:
                # width bucket for the NEXT group: commits/cycle ~
                # accepts/cycle + 1 correction token, so tau-1 estimates
                # draft acceptance
                self._k_bucket = self.controller.choose_k(
                    max(self._tau_est - 1.0, 0.0), self._k_full,
                    self._k_short)
            if not live:
                return
            idx = np.asarray(live, np.int64)
            # stats rows were reset at each slot's admission, so the raw
            # counters ARE per-request totals
            accepts = np.asarray(poll["accepts"], np.float64)[idx]
            relaxed = np.asarray(poll["relaxed"], np.float64)[idx]
            relax_share = relaxed / np.maximum(accepts, 1.0)
            margin = np.asarray(poll["margin"], np.float64)[idx]
            pressure = len(self.queue) / max(self.cfg.slots, 1)
            new = self.controller.update(self.slot_theta[idx], relax_share,
                                         margin, pressure)
            if float(np.max(np.abs(new - self.slot_theta[idx]))) <= 1e-6:
                return                  # converged: skip the dispatch
            self.slot_theta[idx] = new
            self.theta_retunes += 1
            self.state = self._set_theta(
                self.state, self.slot_theta.astype(np.float32))
            if self.obs is not None:
                self.obs.on_retune(
                    [(self.slot_req[s].uid, float(self.slot_theta[s]))
                     for s in live])

    def run(self, *, max_ticks: int = 10_000) -> List[Response]:
        for _ in range(max_ticks):
            if (not self.queue and all(r is None for r in self.slot_req)
                    and not self._pending
                    and not (self._ring is not None and self._ring_staged)):
                break
            self._admit()
            self.step()
            self.sync()
        if self._overlap and self._pending:
            self.sync(flush=True)       # drain the final in-flight group
        out, self._responses = self._responses, []
        return out
