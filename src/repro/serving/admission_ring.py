"""Device-side admission ring: staged prompts the fused tick refills from.

The sync-free tick admits only at host syncs, so a slot that finishes in
the middle of a fused group idles until the group ends even when the
queue is full.  The admission ring closes that bubble: the host stages
queued prompts (tokens, length, budget, temperature, theta, block-table
row, cached-prefix start, COW pair) into a pre-allocated on-device ring,
and the fused group body consumes one entry per loop iteration via a
masked in-loop :meth:`DecodeSession.prefill` whenever a slot is free —
no host round-trip, no idle ticks.

Contract
--------
* The ring is a plain ``NamedTuple`` carry next to :class:`DecodeState`;
  the fused program takes and returns both with donation, so staging and
  refilling never copy the ring.
* ``head`` is device-incremented (consumptions), ``tail`` is
  host-incremented (:func:`ring_push` between groups).  Entries live at
  ``index % depth``; the host never stages more than ``depth``
  outstanding entries, so a push can never overwrite an unconsumed or
  unharvested entry.
* A refill *evicts* a finished occupant: the occupant's token buffer,
  length, and stats are copied into the ring's harvest fields
  (``h_buf``/``h_len``/``h_stats``/``h_slot``) *before* the masked
  prefill resets the slot, so the host emits the response from the ring
  when it processes the group's poll.
* Which finished slots may be taken is the conjunction of two guards:
  slots that *finish inside this group* (``~entry_finished``) are always
  consumable — the device is first to know they freed — while slots
  already finished at dispatch are consumable only if the host marked
  them ``refillable`` (harvested; an unharvested row must stay frozen
  for the host's lagged gather under double-buffering).
* On a ``(data, model)`` mesh the ring is replicated; an entry is bound
  to one data shard at staging (its blocks are shard-local) and the
  candidate mask keeps the refill on that shard's slots.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.session import STAT_KEYS, DecodeState

NO_COW = -1          # cow_src/cow_dst sentinel: nothing to clone


class AdmissionRing(NamedTuple):
    """On-device staging ring (all leaves pre-allocated, depth R)."""
    tokens: jnp.ndarray         # (R, S)  staged prompt, right-padded
    plen: jnp.ndarray           # (R,)    valid prompt length
    budget: jnp.ndarray         # (R,)    max_tokens budget
    temp: jnp.ndarray           # (R,)    verification temperature
    theta: jnp.ndarray          # (R,)    MARS relaxation threshold
    start: jnp.ndarray          # (R,)    cached-prefix divergence point
    rows: jnp.ndarray           # (R, MB) block-table row (trash when dense)
    cow_src: jnp.ndarray        # (R,)    COW clone source (NO_COW = none)
    cow_dst: jnp.ndarray        # (R,)    COW clone destination
    shard: jnp.ndarray          # (R,)    owning data shard
    head: jnp.ndarray           # ()      consumed entries (device-side)
    tail: jnp.ndarray           # ()      staged entries (host-side)
    # harvest side: the evicted occupant of a consumed entry's slot
    h_buf: jnp.ndarray          # (R, L+1) occupant token buffer
    h_len: jnp.ndarray          # (R,)     occupant length
    h_stats: Dict[str, jnp.ndarray]  # (R,) per stat key (+ margin_ema)
    h_slot: jnp.ndarray         # (R,)     slot the consumption refilled


def make_ring(depth: int, prompt_width: int, max_blocks: int,
              buf_width: int) -> AdmissionRing:
    """Allocate an empty ring: ``depth`` entries of ``prompt_width`` prompt
    tokens, ``max_blocks``-wide table rows, and ``buf_width`` harvest
    buffers (the slot buffer width, ``max_len + 1``)."""
    stats = {k: jnp.zeros((depth,), jnp.int32) for k in STAT_KEYS}
    stats["margin_ema"] = jnp.zeros((depth,), jnp.float32)
    return AdmissionRing(
        tokens=jnp.zeros((depth, prompt_width), jnp.int32),
        plen=jnp.zeros((depth,), jnp.int32),
        budget=jnp.zeros((depth,), jnp.int32),
        temp=jnp.ones((depth,), jnp.float32),
        theta=jnp.zeros((depth,), jnp.float32),
        start=jnp.zeros((depth,), jnp.int32),
        rows=jnp.zeros((depth, max_blocks), jnp.int32),
        cow_src=jnp.full((depth,), NO_COW, jnp.int32),
        cow_dst=jnp.full((depth,), NO_COW, jnp.int32),
        shard=jnp.zeros((depth,), jnp.int32),
        head=jnp.zeros((), jnp.int32),
        tail=jnp.zeros((), jnp.int32),
        h_buf=jnp.zeros((depth, buf_width), jnp.int32),
        h_len=jnp.zeros((depth,), jnp.int32),
        h_stats=stats,
        h_slot=jnp.full((depth,), -1, jnp.int32),
    )


def ring_push(ring: AdmissionRing, tokens, plen, budget, temp, theta,
              start, row, cow_src, cow_dst, shard) -> AdmissionRing:
    """Stage one request at ``tail % depth`` — the host half of staging.

    The operands ride the cheap host→device direction; jitted with the
    ring donated, a push between groups mutates the ring in place and
    (device execution being in submission order) lands after any
    in-flight group that might still consume older entries.
    """
    e = ring.tail % ring.plen.shape[0]
    return ring._replace(
        tokens=ring.tokens.at[e].set(tokens),
        plen=ring.plen.at[e].set(plen),
        budget=ring.budget.at[e].set(budget),
        temp=ring.temp.at[e].set(temp),
        theta=ring.theta.at[e].set(theta),
        start=ring.start.at[e].set(start),
        rows=ring.rows.at[e].set(row),
        cow_src=ring.cow_src.at[e].set(cow_src),
        cow_dst=ring.cow_dst.at[e].set(cow_dst),
        shard=ring.shard.at[e].set(shard),
        tail=ring.tail + 1,
    )


def refill_candidates(state: DecodeState, ring: AdmissionRing,
                      entry_finished: jnp.ndarray,
                      refillable: jnp.ndarray,
                      slots_per_shard: Optional[int]) -> jnp.ndarray:
    """(B,) bool: slots the next staged entry may take *right now*.

    ``entry_finished`` is the finished mask at group entry and
    ``refillable`` the host's harvested-slot mask at dispatch — see the
    module docstring for why both guards exist.  When staged entries
    carry a shard binding, only that shard's slots qualify.
    """
    b = state.finished.shape[0]
    cand = state.finished & (~entry_finished | refillable)
    if slots_per_shard is not None:
        e = ring.head % ring.plen.shape[0]
        slot_shard = jnp.arange(b, dtype=jnp.int32) // slots_per_shard
        cand = cand & (slot_shard == ring.shard[e])
    return cand & (ring.tail > ring.head)


def maybe_refill(session, t_params, d_params, state: DecodeState,
                 ring: AdmissionRing, entry_finished, refillable,
                 trash_ids: Optional[jnp.ndarray], *,
                 slots_per_shard: Optional[int] = None,
                 use_blocks: bool = True,
                 use_start: bool = False):
    """Consume at most one ring entry into a free slot (lax.cond-gated).

    The do-branch (1) copies the evicted occupant's buffer/length/stats
    into the harvest fields at ``head % depth``, (2) runs a slot-masked
    ``session.prefill`` of the staged prompt into the chosen slot —
    blocks via ``rows``, cached-prefix seeding via ``start``, COW via
    the entry's pair (``NO_COW`` resolves to the slot's trash id) — and
    (3) advances ``head``.  The no-branch is the identity, so groups
    with nothing to refill pay one predicate only.
    """
    cand = refill_candidates(state, ring, entry_finished, refillable,
                             slots_per_shard)

    def consume(args):
        st, rg = args
        depth = rg.plen.shape[0]
        b = st.finished.shape[0]
        e = rg.head % depth
        slot = jnp.argmax(cand).astype(jnp.int32)
        smask = jnp.arange(b, dtype=jnp.int32) == slot
        # harvest record FIRST: the prefill below resets the slot's row
        rg = rg._replace(
            h_buf=rg.h_buf.at[e].set(st.buf[slot]),
            h_len=rg.h_len.at[e].set(st.lengths[slot]),
            h_stats={k: v.at[e].set(st.stats[k][slot])
                     for k, v in rg.h_stats.items()},
            h_slot=rg.h_slot.at[e].set(slot),
            head=rg.head + 1,
        )
        prompt = jnp.broadcast_to(rg.tokens[e][None],
                                  (b, rg.tokens.shape[1]))
        plen = jnp.broadcast_to(rg.plen[e], (b,))
        kw = {}
        if use_blocks:
            kw["block_rows"] = jnp.broadcast_to(
                rg.rows[e][None], (b, rg.rows.shape[1]))
        if use_start:
            kw["start_pos"] = jnp.where(smask, rg.start[e], 0)
            kw["cow_src"] = jnp.where(smask & (rg.cow_src[e] != NO_COW),
                                      rg.cow_src[e], trash_ids)
            kw["cow_dst"] = jnp.where(smask & (rg.cow_dst[e] != NO_COW),
                                      rg.cow_dst[e], trash_ids)
        st = session.prefill(t_params, d_params, st, prompt, plen,
                             slot_mask=smask, budget=rg.budget[e],
                             temperature=rg.temp[e], theta=rg.theta[e],
                             **kw)
        return st, rg

    return jax.lax.cond(cand.any(), consume, lambda args: args,
                        (state, ring))


def fused_cycles_with_refill(session, t_params, d_params,
                             state: DecodeState, ring: AdmissionRing,
                             refillable, steps, *,
                             trash_ids: Optional[jnp.ndarray] = None,
                             slots_per_shard: Optional[int] = None,
                             use_blocks: bool = True,
                             use_start: bool = False):
    """Ring-aware fused group: ``steps`` cycles with one possible ring
    consumption per iteration, refill-before-cycle so a slot freed at
    group entry (or by the previous iteration) decodes immediately.

    The loop keeps running — past every live slot finishing — while
    staged entries remain consumable, so a group sized for the staged
    backlog drains the ring without host involvement.  Returns the new
    ``(state, ring)`` pair; jit wrappers donate both.
    """
    entry_finished = state.finished

    def cond(carry):
        i, st, rg = carry
        st = DecodeState(*st)
        more = (~st.finished).any()
        can = refill_candidates(st, rg, entry_finished, refillable,
                                slots_per_shard).any()
        return (i < steps) & (more | can)

    def body(carry):
        i, st, rg = carry
        st, rg = maybe_refill(session, t_params, d_params,
                              DecodeState(*st), rg, entry_finished,
                              refillable, trash_ids,
                              slots_per_shard=slots_per_shard,
                              use_blocks=use_blocks, use_start=use_start)
        st = session.cycle(t_params, d_params, st)
        return i + 1, tuple(st), rg

    _, out, ring = jax.lax.while_loop(
        cond, body, (jnp.int32(0), tuple(state), ring))
    return DecodeState(*out), ring
