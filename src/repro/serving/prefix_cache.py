"""Prefix cache over the paged block pool: refcounted KV block sharing.

Heavy-traffic serving workloads repeat KV work constantly — shared system
prompts, multi-turn chats that resend the whole conversation, best-of-N
sampling over one prompt.  Under the paged layout (``repro.models.paging``)
that work lives in *content-addressable* units: a full KV block holds the
keys/values of exactly ``block_size`` consecutive tokens, and two requests
whose token prefixes agree block-for-block can share the physical blocks.
This module is the host-side index that makes the sharing safe:

* **Hash-chained keys** — block ``j`` of a sequence is keyed by
  ``H(key(j-1), tokens[j*bs:(j+1)*bs])``, so a key identifies the *entire
  prefix* up to and including the block, not just its own tokens.  The
  index maps keys to physical block ids; matching a prompt is a walk down
  the chain (a radix-tree descent with hashed edges).
* **Per-block token store** — published blocks remember their tokens, which
  buys *partial tail matches*: when a prompt diverges mid-block, the best
  partially matching child block is mapped anyway and **copy-on-write**
  cloned (``paging.cow_clone_blocks``) before the divergent suffix is
  written, so even the matched head of a divergent block is reused.
* **Refcounts live in the pool** (``BlockPool``/``ShardedBlockPool``): one
  reference per table mapping.  ``match`` hands back blocks the scheduler
  ``acquire``s; harvest ``free``s them; a published block whose count hits
  zero parks in the pool's reclaimable LRU — ``available`` still counts it,
  and allocation pressure evicts it oldest-first through the pool's
  ``evict_cb``, which drops the index entry here.

Write-safety invariant (checked by ``tests/test_prefix_cache.py``): a
published or shared block is **never written through a slot's table** — the
scheduler maps shared blocks strictly below each admitted slot's
``start_pos`` (everything the slot writes, speculative drafts and rollbacks
included, lands at positions ≥ ``start_pos``, i.e. in private blocks), and
a partially-shared tail block is cloned before the first write.  Rollback
therefore remains an index rewind that only ever touches private blocks.

Sharding: on a serving mesh the pool's block dim partitions over ``data``
and a slot may only reference blocks of its own shard, so the index is
per-shard — each data shard grows its own copy of hot prefixes (cold
prefills per shard, not per request).

Everything here is host-side bookkeeping at admission/harvest sync points;
nothing in this file touches device memory.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Tuple

import numpy as np

_ROOT = b"prefix-root"


def _chain_key(parent: bytes, tokens: np.ndarray) -> bytes:
    """Key of the block holding ``tokens`` whose prefix chain is
    ``parent``: sha1 over the parent digest + the token bytes (stable,
    collision-negligible, O(block_size) per block)."""
    h = hashlib.sha1(parent)
    h.update(np.ascontiguousarray(tokens, dtype=np.int32).tobytes())
    return h.digest()


@dataclasses.dataclass
class PrefixMatch:
    """Longest cached prefix of one prompt (see :meth:`PrefixCache.match`).

    ``blocks``: physical ids of fully matched blocks, chain order.
    ``cow``: ``(src_block, n_rows)`` when a partially matching tail block
    is worth cloning — the first ``n_rows`` rows of ``src_block`` match the
    prompt — else None.  ``tokens``: total matched tokens
    (``len(blocks) * block_size + n_rows``)."""
    blocks: List[int]
    cow: Optional[Tuple[int, int]]
    tokens: int

    @property
    def hit(self) -> bool:
        return self.tokens > 0


@dataclasses.dataclass
class PrefixStats:
    lookups: int = 0
    hits: int = 0                  # lookups that matched >= 1 block
    tokens_total: int = 0          # prompt tokens across lookups
    tokens_reused: int = 0         # matched tokens (KV work skipped)
    blocks_shared: int = 0         # full-block mappings handed out
    cow_clones: int = 0            # partial tail blocks cloned
    published_blocks: int = 0      # blocks entered into the index
    evictions: int = 0             # index entries reclaimed by the pool

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.lookups, 1)

    @property
    def reuse_rate(self) -> float:
        return self.tokens_reused / max(self.tokens_total, 1)


class _Entry:
    __slots__ = ("key", "parent", "shard", "tokens")

    def __init__(self, key, parent, shard, tokens):
        self.key = key
        self.parent = parent
        self.shard = shard
        self.tokens = tokens


class PrefixCache:
    """Host-side radix/hash index over published full KV blocks.

    Registers itself as the pool's ``retain_cb``/``evict_cb``: published
    blocks survive their last reference in the pool's reclaimable LRU and
    leave the index only when allocation pressure evicts them.
    """

    def __init__(self, pool, block_size: int, *, n_shards: int = 1,
                 min_match_blocks: int = 1, kv_dtype: str = "bf16"):
        if min_match_blocks < 1:
            raise ValueError("min_match_blocks must be >= 1")
        self.pool = pool
        self.block_size = block_size
        self.n_shards = n_shards
        self.min_match_blocks = min_match_blocks
        # the chain root folds the pool's storage dtype in, so replicas
        # serving the same prompts at different kv_dtypes can never alias
        # index entries: an int8 block's bytes are NOT a bf16 block's bytes,
        # and a key must name the content it maps to
        self.kv_dtype = kv_dtype
        self._root = hashlib.sha1(_ROOT + kv_dtype.encode()).digest()
        # per-shard chain-key -> physical block
        self._index: List[Dict[bytes, int]] = [{} for _ in range(n_shards)]
        # per-shard parent-key -> child blocks (partial tail candidates)
        self._children: List[Dict[bytes, List[int]]] = [
            {} for _ in range(n_shards)]
        self._entries: Dict[int, _Entry] = {}      # physical block -> entry
        self.stats = PrefixStats()
        pool.retain_cb = self._retain
        pool.evict_cb = self._evicted

    # -- pool callbacks -----------------------------------------------------
    def _retain(self, block: int) -> bool:
        return block in self._entries

    def _evicted(self, block: int) -> None:
        e = self._entries.pop(block, None)
        if e is None:
            return
        self._index[e.shard].pop(e.key, None)
        kids = self._children[e.shard].get(e.parent)
        if kids is not None:
            try:
                kids.remove(block)
            except ValueError:
                pass
            if not kids:
                del self._children[e.shard][e.parent]
        # descendants become unreachable (their parent key is gone); they
        # stay parked in the pool's LRU and age out under pressure
        self.stats.evictions += 1

    # -- admission ----------------------------------------------------------
    def match(self, tokens: np.ndarray, usable: int,
              shard: int = 0) -> PrefixMatch:
        """Longest cached prefix of ``tokens[:usable]`` on ``shard``.

        Walks fully matching blocks down the hash chain, then tries one
        partial tail match among the last node's children (most matching
        rows wins).  A match shorter than ``min_match_blocks`` blocks is
        reported as a miss — mapping one nearly-empty shared block is not
        worth the table bookkeeping.  Matched full blocks have their LRU
        recency refreshed only when the scheduler ``acquire``s them.

        Pure lookup: no statistics are recorded here.  The scheduler may
        match the same request several times before it actually admits
        (sibling deferral, pool-short retries), so the stats commit via
        :meth:`record_admission` exactly once, when the mapping is real.
        """
        bs = self.block_size
        tokens = np.asarray(tokens)
        usable = min(usable, len(tokens))

        blocks: List[int] = []
        parent = self._root
        j = 0
        while (j + 1) * bs <= usable:
            key = _chain_key(parent, tokens[j * bs:(j + 1) * bs])
            blk = self._index[shard].get(key)
            if blk is None:
                break
            blocks.append(blk)
            parent = key
            j += 1

        cow = None
        rem = min(usable - j * bs, bs)     # one tail block at most
        if rem > 0:
            seg = np.asarray(tokens[j * bs:j * bs + rem], np.int32)
            best, best_rows = None, 0
            for child in self._children[shard].get(parent, ()):
                eq = np.equal(self._entries[child].tokens[:rem], seg)
                n = rem if eq.all() else int(eq.argmin())
                if n > best_rows:
                    best, best_rows = child, n
            if best is not None:
                cow = (best, best_rows)

        matched = len(blocks) * bs + (cow[1] if cow else 0)
        n_match_blocks = len(blocks) + (1 if cow else 0)
        if matched == 0 or n_match_blocks < self.min_match_blocks:
            return PrefixMatch([], None, 0)
        return PrefixMatch(blocks, cow, matched)

    def record_admission(self, match: PrefixMatch, usable: int) -> None:
        """Commit one admission's worth of statistics — called by the
        scheduler exactly once per request actually admitted, so deferred
        and pool-short attempts never inflate hit/reuse metrics."""
        self.stats.lookups += 1
        self.stats.tokens_total += int(usable)
        if not match.hit:
            return
        self.stats.hits += 1
        self.stats.tokens_reused += match.tokens
        self.stats.blocks_shared += len(match.blocks)
        if match.cow is not None:
            self.stats.cow_clones += 1

    # -- publication --------------------------------------------------------
    def publish(self, tokens: np.ndarray, table_blocks: List[int],
                shard: int = 0) -> int:
        """Enter the full blocks of ``tokens`` (the *cached-correct* token
        prefix: committed history minus the pending token) into the index,
        mapped to the publishing slot's physical blocks ``table_blocks``
        (logical order).  Chain nodes already indexed — the shared blocks
        this very slot rode in on, or a concurrent duplicate — are skipped:
        the slot's physical block for that node simply returns to the free
        list when released.  Returns the number of newly published blocks.

        Called twice per request: at admission for the prompt's full blocks
        (they are committed by definition the moment the admission prefill
        is dispatched — which is what lets same-prefix followers one tick
        later share them), and at harvest for the generated history.
        """
        bs = self.block_size
        tokens = np.asarray(tokens)
        n_full = min(len(tokens) // bs, len(table_blocks))
        parent = self._root
        published = 0
        for j in range(n_full):
            btoks = np.asarray(tokens[j * bs:(j + 1) * bs], np.int32)
            key = _chain_key(parent, btoks)
            if key not in self._index[shard]:
                phys = int(table_blocks[j])
                if phys in self._entries:
                    # already published under another chain (can't happen
                    # for distinct keys of the same physical block)
                    parent = key
                    continue
                self._index[shard][key] = phys
                self._children[shard].setdefault(parent, []).append(phys)
                self._entries[phys] = _Entry(key, parent, shard, btoks)
                published += 1
            parent = key
        self.stats.published_blocks += published
        return published

    # -- introspection ------------------------------------------------------
    @property
    def n_indexed(self) -> int:
        return len(self._entries)

    def summary(self) -> Dict[str, float]:
        s = self.stats
        return {
            "lookups": s.lookups, "hits": s.hits,
            "hit_rate": round(s.hit_rate, 3),
            "tokens_total": s.tokens_total,
            "tokens_reused": s.tokens_reused,
            "reuse_rate": round(s.reuse_rate, 3),
            "blocks_shared": s.blocks_shared,
            "cow_clones": s.cow_clones,
            "published_blocks": s.published_blocks,
            "evictions": s.evictions,
            "indexed_blocks": self.n_indexed,
        }
