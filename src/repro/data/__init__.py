from repro.data.pipeline import (
    ByteTokenizer,
    MarkovCorpus,
    batch_iterator,
    make_lm_batches,
)

__all__ = ["ByteTokenizer", "MarkovCorpus", "batch_iterator",
           "make_lm_batches"]
