"""Data pipeline: synthetic corpora with controllable entropy + byte
tokenizer + LM batching.

The Markov corpus is central to the paper-validation experiments: its
``temperature`` knob directly controls how often the *trained target model*
lands in low-margin regimes (near-ties between top candidates) — the regime
MARS exploits.  Low corpus temperature → decisive continuations → high
margins; high temperature → frequent near-ties → many relaxation
opportunities.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


class ByteTokenizer:
    """Byte-level tokenizer with BOS/EOS/PAD specials."""
    PAD, BOS, EOS = 0, 1, 2
    OFFSET = 3

    @property
    def vocab_size(self) -> int:
        return 256 + self.OFFSET

    def encode(self, text: str, *, bos: bool = True, eos: bool = False):
        ids = [b + self.OFFSET for b in text.encode("utf-8")]
        if bos:
            ids = [self.BOS] + ids
        if eos:
            ids = ids + [self.EOS]
        return np.asarray(ids, np.int32)

    def decode(self, ids) -> str:
        bs = bytes(int(i) - self.OFFSET for i in ids
                   if int(i) >= self.OFFSET)
        return bs.decode("utf-8", errors="replace")


@dataclasses.dataclass
class MarkovCorpus:
    """Order-2 Markov chain over a small alphabet with a Zipf-ish transition
    table; ``temperature`` reshapes transition entropy."""
    vocab_size: int = 64
    order: int = 2
    temperature: float = 1.0
    branching: int = 8
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        n_ctx = self.vocab_size ** self.order
        # each context transitions to `branching` candidates with Zipf weights
        self._succ = rng.integers(0, self.vocab_size,
                                  size=(n_ctx, self.branching))
        ranks = np.arange(1, self.branching + 1, dtype=np.float64)
        base = 1.0 / ranks
        logits = np.log(base)[None, :] + 0.3 * rng.standard_normal(
            (n_ctx, self.branching))
        w = np.exp(logits / max(self.temperature, 1e-3))
        self._probs = w / w.sum(axis=1, keepdims=True)

    def _ctx_id(self, ctx) -> int:
        cid = 0
        for c in ctx:
            cid = cid * self.vocab_size + int(c)
        return cid

    def sample(self, length: int, rng: np.random.Generator) -> np.ndarray:
        out = list(rng.integers(0, self.vocab_size, size=self.order))
        for _ in range(length - self.order):
            cid = self._ctx_id(out[-self.order:])
            j = rng.choice(self.branching, p=self._probs[cid])
            out.append(int(self._succ[cid, j]))
        return np.asarray(out, np.int32)

    def sample_batch(self, batch: int, length: int, seed: int = 0) -> np.ndarray:
        rng = np.random.default_rng(seed)
        return np.stack([self.sample(length, rng) for _ in range(batch)])


def make_lm_batches(corpus: MarkovCorpus, *, batch: int, seq_len: int,
                    n_batches: int, seed: int = 0) -> Iterator[dict]:
    """Yields {"tokens": (B, S+1)} — inputs tokens[:, :-1], labels [:, 1:]."""
    rng = np.random.default_rng(seed)
    for i in range(n_batches):
        toks = corpus.sample_batch(batch, seq_len + 1,
                                   seed=int(rng.integers(1 << 31)))
        yield {"tokens": toks}


def batch_iterator(tokens: np.ndarray, *, batch: int, seq_len: int,
                   seed: int = 0, drop_last: bool = True) -> Iterator[dict]:
    """Chunk a flat token stream into LM batches (file-backed corpora)."""
    n = (len(tokens) - 1) // seq_len
    idx = np.arange(n)
    np.random.default_rng(seed).shuffle(idx)
    buf = []
    for i in idx:
        chunk = tokens[i * seq_len:(i + 1) * seq_len + 1]
        if len(chunk) < seq_len + 1:
            continue
        buf.append(chunk)
        if len(buf) == batch:
            yield {"tokens": np.stack(buf).astype(np.int32)}
            buf = []
    if buf and not drop_last:
        yield {"tokens": np.stack(buf).astype(np.int32)}
