"""Checkpointing: flattened-pytree .npz store (orbax is not in this env).

Path-keyed so checkpoints survive refactors that keep param names; works for
params, optimizer state and engine stats alike.  On multi-host deployments
each host saves its addressable shards (`process_index` suffix) — on this
single-process environment that degenerates to one file, which is fine for
the dry-run scale.
"""
from __future__ import annotations

import os
import re
from typing import Any, Optional

import jax
import numpy as np

_SEP = "::"


def _flatten(tree) -> dict:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(directory: str, step: int, tree: Any, *,
                    name: str = "ckpt") -> str:
    os.makedirs(directory, exist_ok=True)
    proc = jax.process_index()
    path = os.path.join(directory, f"{name}_{step:08d}_p{proc}.npz")
    tmp = path + ".tmp.npz"
    np.savez(tmp, **_flatten(tree))
    os.replace(tmp, path)
    return path


def latest_step(directory: str, *, name: str = "ckpt") -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    pat = re.compile(rf"{re.escape(name)}_(\d+)_p0\.npz")
    for f in os.listdir(directory):
        m = pat.match(f)
        if m:
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def load_checkpoint(directory: str, step: int, template: Any, *,
                    name: str = "ckpt") -> Any:
    """Restore into the structure of ``template`` (shapes validated)."""
    proc = jax.process_index()
    path = os.path.join(directory, f"{name}_{step:08d}_p{proc}.npz")
    data = np.load(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat:
        key = _SEP.join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in p)
        arr = data[key]
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)
