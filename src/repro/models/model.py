"""Unified model API across the six assigned architecture families.

``build_model(cfg)`` returns a :class:`Model` whose methods are pure
functions of (params, inputs) and therefore jit/pjit-friendly:

  init(rng)                                 -> params pytree
  forward(params, batch, remat=..)          -> (logits, aux_loss)   # train/eval
  init_cache(params, batch, max_len, ...)   -> cache pytree
  decode(params, tokens, positions, cache)  -> (logits, new_cache)  # T >= 1

Layer stacks carry a leading layer dim and run under ``jax.lax.scan`` so the
compiled HLO is depth-independent (critical for the 95-layer deepseek-67b
dry-run).

The KV / SSM caches are pytrees governed by one invariant —
``cache["index"]`` counts committed tokens whose kv/state is stored — but
the speculative engine's *rollback* differs by family and layout (see
docs/ARCHITECTURE.md):

* attention families with the dense ring cache rewind the write index;
  stale entries past it are masked by stored position and overwritten later;
* attention families with the **paged** block-table cache
  (``init_cache(..., paged=...)``) do the same index rewind on device —
  the slot keeps its admission-reserved blocks mid-flight — and the
  block-list *truncate* is host-side: the scheduler drops the finished
  slot's block references at harvest (under the serving prefix cache the
  leading blocks may be shared/refcounted: the rewind range always lies in
  the slot's private blocks, so sharing never constrains rollback);
* recurrent families (ssm / hybrid) cannot rewind: the engine re-applies
  the committed tokens from the pre-cycle state under a token mask, so
  their state only ever reflects committed tokens.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.sharding import constrain
from repro.utils.lowering import maybe_scan

Params = Dict[str, Any]


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _sinusoidal(positions: jnp.ndarray, d: int) -> jnp.ndarray:
    half = d // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Transformer block (dense / moe / vlm / whisper-decoder)
# ---------------------------------------------------------------------------

def _init_block(cfg: ModelConfig, key, *, moe: bool, cross: bool) -> Params:
    keys = jax.random.split(key, 6)
    p = {
        "norm1": L.init_norm(cfg, keys[0]),
        "attn": L.init_attention(cfg, keys[1]),
        "norm2": L.init_norm(cfg, keys[2]),
    }
    if moe:
        p["moe"] = L.init_moe(cfg, keys[3])
    else:
        p["mlp"] = L.init_mlp(cfg, keys[3])
    if cross:
        p["norm_cross"] = L.init_norm(cfg, keys[4])
        p["cross_attn"] = L.init_attention(cfg, keys[5], cross=True)
    return p


def _apply_block(cfg: ModelConfig, p: Params, x, positions, *,
                 cache=None, cross_kv=None, causal=True,
                 unrolled=False, tree_mask=None,
                 ) -> Tuple[jnp.ndarray, Any, jnp.ndarray]:
    aux = jnp.zeros((), jnp.float32)
    if cfg.parallel_residual and cross_kv is None:
        # PaLM-style parallel block: x + attn(n1(x)) + mlp(n2(x)) — the two
        # partial-sum outputs share ONE TP all-reduce (§Perf variant)
        h_attn, new_cache = L.attention_forward(
            cfg, p["attn"], L.apply_norm(cfg, p["norm1"], x), positions,
            cache=cache, causal=causal, use_unrolled=unrolled,
            tree_mask=tree_mask)
        inner = L.apply_norm(cfg, p["norm2"], x)
        if "moe" in p:
            h_mlp, aux = L.apply_moe(cfg, p["moe"], inner)
        else:
            h_mlp = L.apply_mlp(cfg, p["mlp"], inner)
        return x + h_attn + h_mlp, new_cache, aux

    h, new_cache = L.attention_forward(
        cfg, p["attn"], L.apply_norm(cfg, p["norm1"], x), positions,
        cache=cache, causal=causal, use_unrolled=unrolled,
        tree_mask=tree_mask)
    x = x + h
    if cross_kv is not None:
        q_in = L.apply_norm(cfg, p["norm_cross"], x)
        h = _cross_attention(cfg, p["cross_attn"], q_in, cross_kv)
        x = x + h
    inner = L.apply_norm(cfg, p["norm2"], x)
    if "moe" in p:
        h, aux = L.apply_moe(cfg, p["moe"], inner)
    else:
        h = L.apply_mlp(cfg, p["mlp"], inner)
    x = x + h
    return x, new_cache, aux


def _cross_kv(cfg: ModelConfig, p_attn: Params, enc: jnp.ndarray):
    b, s, _ = enc.shape
    k = (enc @ p_attn["wk"].astype(enc.dtype))
    v = (enc @ p_attn["wv"].astype(enc.dtype))
    if cfg.use_bias:
        k = k + p_attn["bk"].astype(enc.dtype)
        v = v + p_attn["bv"].astype(enc.dtype)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    return k, v


def _cross_attention(cfg: ModelConfig, p: Params, x, cross_kv):
    """Cross attention against precomputed encoder K/V."""
    b, t, _ = x.shape
    k, v = cross_kv
    q = x @ p["wq"].astype(x.dtype)
    if cfg.use_bias:
        q = q + p["bq"].astype(x.dtype)
    q = q.reshape(b, t, cfg.n_heads, cfg.head_dim)
    s = k.shape[1]
    k_pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    q_pos = jnp.zeros((b, t), jnp.int32)
    out = L.blockwise_attention(q, k, v, q_pos, k_pos, window=0, causal=False)
    out = out.reshape(b, t, cfg.n_heads * cfg.head_dim) @ p["wo"].astype(x.dtype)
    if cfg.use_bias:
        out = out + p["bo"].astype(x.dtype)
    return out



def _remat_policy(name):
    """None -> full remat; "dots" -> save matmul/collective outputs so the
    backward pass does not recompute (and re-all-reduce) them (§Perf)."""
    if name is None:
        return None
    if name == "dots":
        return jax.checkpoint_policies.checkpoint_dots
    if name == "dots_no_batch":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    raise ValueError(name)


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- init ---------------------------------------------------------------
    def init(self, rng) -> Params:
        cfg = self.cfg
        n_extra = 8
        keys = jax.random.split(rng, cfg.n_layers + cfg.n_encoder_layers + n_extra)
        k_emb, k_head, k_final, k_shared, k_enc_norm = keys[:5]
        layer_keys = keys[n_extra:n_extra + cfg.n_layers]
        enc_keys = keys[n_extra + cfg.n_layers:]

        p: Params = {
            "embedding": L._dense_init(k_emb, (cfg.vocab_size, cfg.d_model),
                                       scale=0.02),
            "final_norm": L.init_norm(cfg, k_final),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = L._dense_init(k_head, (cfg.d_model, cfg.vocab_size))

        fam = cfg.family
        if fam in ("dense", "vlm"):
            p["blocks"] = _stack([
                _init_block(cfg, k, moe=False, cross=False) for k in layer_keys])
        elif fam == "moe":
            p["blocks"] = _stack([
                _init_block(cfg, k, moe=True, cross=False) for k in layer_keys])
        elif fam == "hybrid":
            every = cfg.hybrid_attn_every
            n_groups = cfg.n_layers // every
            p["mamba"] = _stack([
                _stack([S.init_mamba2(cfg, layer_keys[g * every + i])
                        for i in range(every)])
                for g in range(n_groups)])
            p["shared_block"] = _init_block(cfg, k_shared, moe=False, cross=False)
        elif fam == "ssm":
            every = cfg.slstm_every
            n_groups = cfg.n_layers // every
            n_m = every - 1
            p["mlstm"] = _stack([
                _stack([S.init_mlstm(cfg, layer_keys[g * every + i])
                        for i in range(n_m)])
                for g in range(n_groups)])
            p["slstm"] = _stack([
                S.init_slstm(cfg, layer_keys[g * every + n_m])
                for g in range(n_groups)])
        elif fam == "audio":
            p["enc_blocks"] = _stack([
                _init_block(cfg, k, moe=False, cross=False) for k in enc_keys])
            p["enc_final_norm"] = L.init_norm(cfg, k_enc_norm)
            p["blocks"] = _stack([
                _init_block(cfg, k, moe=False, cross=True) for k in layer_keys])
        else:
            raise ValueError(f"unknown family {fam}")
        return p

    # -- embedding / head -----------------------------------------------------
    def _embed(self, params, tokens):
        cfg = self.cfg
        x = params["embedding"][tokens].astype(L.dtype_of(cfg))
        return constrain(x, "batch", None, "embed")

    def _head(self, params, x):
        cfg = self.cfg
        x = L.apply_norm(cfg, params["final_norm"], x)
        w = (params["embedding"].T if cfg.tie_embeddings
             else params["lm_head"]).astype(x.dtype)
        logits = x @ w
        return constrain(logits, "batch", None, "vocab")

    # -- encoder (whisper) ------------------------------------------------------
    def encode(self, params, frames: jnp.ndarray) -> jnp.ndarray:
        """frames: (B, S_enc, d_model) stub frontend embeddings."""
        cfg = self.cfg
        b, s, _ = frames.shape
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        x = frames.astype(L.dtype_of(cfg))
        x = x + _sinusoidal(pos, cfg.d_model).astype(x.dtype)

        def body(x, p_layer):
            y, _, _ = _apply_block(cfg, p_layer, x, pos, causal=False)
            return y, None

        x, _ = maybe_scan(body, x, params["enc_blocks"])
        return L.apply_norm(cfg, params["enc_final_norm"], x)

    # -- full-sequence forward (train / eval) ------------------------------------
    def forward(self, params, batch: Dict[str, jnp.ndarray], *,
                remat: bool = False, unrolled_attn: bool = False,
                remat_policy: Optional[str] = None,
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        x = self._embed(params, tokens)
        if cfg.family == "audio":
            x = x + _sinusoidal(positions, cfg.d_model).astype(x.dtype)
        aux_total = jnp.zeros((), jnp.float32)
        fam = cfg.family

        if fam in ("dense", "moe", "vlm"):
            def body(x, p_layer):
                y, _, aux = _apply_block(cfg, p_layer, x, positions,
                                         unrolled=unrolled_attn)
                return y, aux
            if remat:
                body = jax.checkpoint(body, policy=_remat_policy(remat_policy))
            x, auxs = maybe_scan(body, x, params["blocks"])
            aux_total = jnp.sum(auxs)

        elif fam == "hybrid":
            shared = params["shared_block"]

            def group(x, p_group):
                def inner(x, p_m):
                    y, _ = S.mamba2_forward(cfg, p_m, x)
                    return x + y, None
                x, _ = maybe_scan(inner, x, p_group)
                x, _, _ = _apply_block(cfg, shared, x, positions,
                                       unrolled=unrolled_attn)
                return x, None
            if remat:
                group = jax.checkpoint(group, policy=_remat_policy(remat_policy))
            x, _ = maybe_scan(group, x, params["mamba"])

        elif fam == "ssm":
            def group(x, xs):
                p_ms, p_s = xs

                def inner(x, p_m):
                    y, _ = S.mlstm_forward(cfg, p_m, x)
                    return x + y, None
                x, _ = maybe_scan(inner, x, p_ms)
                y, _ = S.slstm_forward(cfg, p_s, x)
                return x + y, None
            if remat:
                group = jax.checkpoint(group, policy=_remat_policy(remat_policy))
            x, _ = maybe_scan(group, x, (params["mlstm"], params["slstm"]))

        elif fam == "audio":
            enc = self.encode(params, batch["encoder_frames"])

            def body(x, p_layer):
                ckv = _cross_kv(cfg, p_layer["cross_attn"], enc)
                y, _, _ = _apply_block(cfg, p_layer, x, positions,
                                       cross_kv=ckv, unrolled=unrolled_attn)
                return y, None
            if remat:
                body = jax.checkpoint(body, policy=_remat_policy(remat_policy))
            x, _ = maybe_scan(body, x, params["blocks"])

        return self._head(params, x), aux_total

    # -- caches -------------------------------------------------------------------
    def init_cache(self, params, batch: int, max_len: int, *,
                   encoder_frames: Optional[jnp.ndarray] = None,
                   paged=None, paged_shards: int = 1) -> Params:
        """``paged`` (a :class:`repro.models.paging.PagedCacheConfig`) swaps
        the dense per-slot KV ring for the shared block pool + per-slot
        block tables.  Only attention KV pages: recurrent state (mamba /
        xlstm) is O(1) per slot, and the whisper cross-KV is a fixed,
        always-full encoder block — both stay dense.  Pure-ssm targets
        accept ``paged`` as a no-op (they have no attention KV, so the
        cache carries no pool/table leaves at all — the zero-block
        layout); sliding-window targets get a window-bounded ring of
        blocks.  ``paged_shards`` (the serving mesh's data-axis size)
        gives each slot a shard-local trash block so masked paged writes
        never cross shards."""
        cfg = self.cfg
        fam = cfg.family

        def attn_cache(n_layers):
            if paged is not None:
                from repro.models.paging import make_paged_attention_cache
                return make_paged_attention_cache(cfg, batch, max_len, paged,
                                                  n_layers=n_layers,
                                                  data_shards=paged_shards)
            return L.make_attention_cache(cfg, batch, max_len,
                                          n_layers=n_layers)

        cache: Params = {"index": jnp.zeros((batch,), jnp.int32)}
        if fam in ("dense", "moe", "vlm"):
            cache["layers"] = attn_cache(cfg.n_layers)
        elif fam == "hybrid":
            every = cfg.hybrid_attn_every
            n_groups = cfg.n_layers // every
            mamba = [S.make_mamba2_cache(cfg, batch, n_layers=every)
                     for _ in range(n_groups)]
            cache["mamba"] = _stack(mamba)
            cache["attn"] = attn_cache(n_groups)
        elif fam == "ssm":
            every = cfg.slstm_every
            n_groups = cfg.n_layers // every
            cache["mlstm"] = _stack([
                S.make_mlstm_cache(cfg, batch, n_layers=every - 1)
                for _ in range(n_groups)])
            cache["slstm"] = _stack([
                S.make_slstm_cache(cfg, batch) for _ in range(n_groups)])
        elif fam == "audio":
            cache["layers"] = attn_cache(cfg.n_layers)
            if encoder_frames is not None:
                enc = self.encode(params, encoder_frames)

                def kv_body(_, p_layer):
                    return None, _cross_kv(cfg, p_layer["cross_attn"], enc)
                _, (ck, cv) = maybe_scan(kv_body, None, params["blocks"])
                cache["cross_k"], cache["cross_v"] = ck, cv
            else:
                s_enc = cfg.encoder_seq_len
                shape = (cfg.n_layers, batch, s_enc, cfg.n_kv_heads, cfg.head_dim)
                cache["cross_k"] = jnp.zeros(shape, L.dtype_of(cfg))
                cache["cross_v"] = jnp.zeros(shape, L.dtype_of(cfg))
        return cache

    # -- incremental decode (T >= 1 new tokens) -------------------------------------
    def decode(self, params, tokens: jnp.ndarray, positions: jnp.ndarray,
               cache: Params,
               token_mask: Optional[jnp.ndarray] = None,
               with_features: bool = False):
        """Process T new tokens against the cache.

        ``token_mask`` (B, T) marks valid tokens; masked tokens are state
        no-ops (attention kv goes to trash slots, recurrent states freeze).
        Used for post-verify state recompute on recurrent families and for
        ragged continuous-batching steps.
        """
        cfg = self.cfg
        fam = cfg.family
        x = self._embed(params, tokens)
        attn_positions = positions
        if token_mask is not None:
            attn_positions = jnp.where(token_mask, positions, -1)
        if fam == "audio":
            x = x + _sinusoidal(positions, cfg.d_model).astype(x.dtype)
        new_cache = dict(cache)

        if fam in ("dense", "moe", "vlm"):
            def body(x, xs):
                p_layer, c_layer = xs
                y, nc, _ = _apply_block(cfg, p_layer, x, attn_positions,
                                        cache=c_layer)
                return y, nc
            x, ncl = maybe_scan(body, x, (params["blocks"], cache["layers"]))
            new_cache["layers"] = ncl

        elif fam == "hybrid":
            shared = params["shared_block"]

            def group(x, xs):
                p_group, c_mamba, c_attn = xs

                def inner(x, xs_i):
                    p_m, c_m = xs_i
                    y, nc = S.mamba2_forward(cfg, p_m, x, cache=c_m,
                                             token_mask=token_mask)
                    return x + y, nc
                x, nc_m = maybe_scan(inner, x, (p_group, c_mamba))
                x, nc_a, _ = _apply_block(cfg, shared, x, attn_positions,
                                          cache=c_attn)
                return x, (nc_m, nc_a)
            x, (nm, na) = maybe_scan(group, x, (params["mamba"], cache["mamba"], cache["attn"]))
            new_cache["mamba"], new_cache["attn"] = nm, na

        elif fam == "ssm":
            def group(x, xs):
                p_ms, p_s, c_ms, c_s = xs

                def inner(x, xs_i):
                    p_m, c_m = xs_i
                    y, nc = S.mlstm_forward(cfg, p_m, x, cache=c_m,
                                            token_mask=token_mask)
                    return x + y, nc
                x, nc_m = maybe_scan(inner, x, (p_ms, c_ms))
                y, nc_s = S.slstm_forward(cfg, p_s, x, cache=c_s,
                                          token_mask=token_mask)
                return x + y, (nc_m, nc_s)
            x, (nm, ns) = maybe_scan(group, x,
                (params["mlstm"], params["slstm"],
                 cache["mlstm"], cache["slstm"]))
            new_cache["mlstm"], new_cache["slstm"] = nm, ns

        elif fam == "audio":
            def body(x, xs):
                p_layer, c_layer, ck, cv = xs
                y, nc, _ = _apply_block(cfg, p_layer, x, attn_positions,
                                        cache=c_layer, cross_kv=(ck, cv))
                return y, nc
            x, ncl = maybe_scan(body, x,
                (params["blocks"], cache["layers"],
                 cache["cross_k"], cache["cross_v"]))
            new_cache["layers"] = ncl

        feats = x
        logits = self._head(params, x)
        n_new = (tokens.shape[1] if token_mask is None
                 else jnp.sum(token_mask.astype(jnp.int32), axis=1))
        new_cache["index"] = cache["index"] + n_new
        if with_features:
            return logits, new_cache, feats
        return logits, new_cache

    def decode_virtual(self, params, tokens: jnp.ndarray,
                       positions: jnp.ndarray, cache: Params,
                       tree_mask: jnp.ndarray) -> jnp.ndarray:
        """Tree-verification forward: score T tree nodes against the cache
        WITHOUT writing them.  Node 0 must be the tree root (the pending
        token); ``tree_mask[i, j]`` marks node j as an ancestor-or-self of
        node i.  Attention families only — recurrent targets verify trees by
        per-path recompute in the engine instead."""
        cfg = self.cfg
        fam = cfg.family
        if fam not in ("dense", "moe", "vlm", "audio"):
            raise NotImplementedError(
                "virtual tree decode requires attention-family targets")
        x = self._embed(params, tokens)
        if fam == "audio":
            x = x + _sinusoidal(positions, cfg.d_model).astype(x.dtype)

        if fam == "audio":
            def body(x, xs):
                p_layer, c_layer, ck, cv = xs
                y, _, _ = _apply_block(cfg, p_layer, x, positions,
                                       cache=c_layer, cross_kv=(ck, cv),
                                       tree_mask=tree_mask)
                return y, None
            x, _ = maybe_scan(body, x,
                              (params["blocks"], cache["layers"],
                               cache["cross_k"], cache["cross_v"]))
        else:
            def body(x, xs):
                p_layer, c_layer = xs
                y, _, _ = _apply_block(cfg, p_layer, x, positions,
                                       cache=c_layer, tree_mask=tree_mask)
                return y, None
            x, _ = maybe_scan(body, x, (params["blocks"], cache["layers"]))
        return self._head(params, x)

    @property
    def is_recurrent(self) -> bool:
        """Families whose decode state cannot be rolled back by index —
        the engine re-applies committed tokens from the pre-cycle state."""
        return self.cfg.family in ("ssm", "hybrid")

    # -- continuous batching support --------------------------------------------
    def reset_slots(self, cache: Params, slot_mask: jnp.ndarray) -> Params:
        """Clear the cache rows of slots in ``slot_mask`` (B,) so a new
        request can be admitted there (continuous batching)."""
        from repro.models.layers import _INVALID_POS

        def wipe(x, batch_axis: int, value=0):
            shape = [1] * x.ndim
            shape[batch_axis] = slot_mask.shape[0]
            m = slot_mask.reshape(shape)
            return jnp.where(m, jnp.asarray(value, x.dtype), x)

        fam = self.cfg.family
        new = dict(cache)
        new["index"] = wipe(cache["index"], 0)

        def wipe_attn(lay):
            # invalidating stored positions is a full wipe for both layouts;
            # a paged slot additionally unmaps its table rows (the slot's
            # trash block — shard-local on a mesh) so writes before the
            # host re-maps the slot are dropped.  Pool CONTENT is never
            # wiped: published prefix blocks outlive the slots that wrote
            # them.
            lay = dict(lay)
            lay["pos"] = wipe(lay["pos"], 1, _INVALID_POS)
            if "table" in lay:
                trash = lay.get("trash")
                if trash is None:      # hand-built caches (pre-trash schema)
                    trash = jnp.zeros(lay["table"].shape[:-1], jnp.int32)
                m = slot_mask.reshape((1,) * (lay["table"].ndim - 2)
                                      + (-1, 1))
                lay["table"] = jnp.where(m, trash[..., :, None],
                                         lay["table"])
            return lay

        if fam in ("dense", "moe", "vlm", "audio"):
            new["layers"] = wipe_attn(cache["layers"])
        if fam == "hybrid":
            new["mamba"] = {k: wipe(v, 2) for k, v in cache["mamba"].items()}
            new["attn"] = wipe_attn(cache["attn"])
        if fam == "ssm":
            new["mlstm"] = {
                "state": wipe(cache["mlstm"]["state"], 2),
                "m": wipe(cache["mlstm"]["m"], 2),
            }
            sl = {k: wipe(v, 1) for k, v in cache["slstm"].items()}
            sl["m"] = wipe(cache["slstm"]["m"], 1, -10.0)
            new["slstm"] = sl
        return new

    def assign_blocks(self, cache: Params, slot_mask: jnp.ndarray,
                      rows: jnp.ndarray) -> Params:
        """Map the paged-cache table rows of slots in ``slot_mask`` (B,) to
        the physical blocks in ``rows`` (B, max_blocks) — the device half of
        admission (the host half is ``paging.BlockPool``).  No-op on dense
        caches."""
        from repro.models.paging import assign_block_rows, is_paged
        key = "attn" if self.cfg.family == "hybrid" else "layers"
        if key not in cache or not is_paged(cache[key]):
            return cache
        new = dict(cache)
        new[key] = assign_block_rows(cache[key], slot_mask, rows)
        return new

    def clone_blocks(self, cache: Params, src: jnp.ndarray,
                     dst: jnp.ndarray) -> Params:
        """Copy pool rows of physical blocks ``src`` (B,) into ``dst`` (B,)
        across every paged attention layer — the device half of
        copy-on-write (see :func:`repro.models.paging.cow_clone_blocks`).
        No-op on dense caches."""
        from repro.models.paging import cow_clone_blocks, is_paged
        key = "attn" if self.cfg.family == "hybrid" else "layers"
        if key not in cache or not is_paged(cache[key]):
            return cache
        new = dict(cache)
        new[key] = cow_clone_blocks(cache[key], src, dst)
        return new

    def seed_prefix(self, cache: Params, slot_mask: jnp.ndarray,
                    start: jnp.ndarray) -> Params:
        """Mark positions ``[0, start[b])`` of the admitted slots as cached
        (stored pos valid, ``index = start``) — the device half of mapping
        an already-written shared KV prefix into a fresh slot so the
        admission prefill can start from the divergence point.  No-op on
        dense caches (``start`` must then be all zero)."""
        from repro.models.paging import is_paged, seed_prefix_positions
        key = "attn" if self.cfg.family == "hybrid" else "layers"
        if key not in cache or not is_paged(cache[key]):
            return cache
        new = dict(cache)
        new[key] = seed_prefix_positions(cache[key], slot_mask, start)
        new["index"] = jnp.where(slot_mask, start.astype(jnp.int32),
                                 cache["index"])
        return new

    # convenience -------------------------------------------------------------
    def prefill(self, params, tokens: jnp.ndarray, cache: Params,
                ) -> Tuple[jnp.ndarray, Params]:
        b, s = tokens.shape
        positions = (cache["index"][:, None]
                     + jnp.arange(s, dtype=jnp.int32)[None])
        return self.decode(params, tokens, positions, cache)


def build_model(cfg: ModelConfig, *, sliding_window: Optional[int] = None) -> Model:
    if sliding_window is not None:
        cfg = dataclasses.replace(cfg, sliding_window=sliding_window)
    return Model(cfg)
