"""Paged KV cache: block tables over a shared device block pool.

The dense attention cache (``layers.make_attention_cache``) reserves a full
``max_len`` ring per batch slot, so admission capacity is bounded by the
*worst-case* request length.  This module replaces that per-slot ring with a
vLLM-style paged layout:

* a **block pool** — one shared device array of fixed-size KV blocks,
  ``(n_layers, n_blocks, block_size, Hkv, D)``; physical block 0 is the
  *trash block* (masked-out tokens land there, nothing ever reads it);
* a **block table** — per slot, the list of physical blocks backing its
  logical KV ring, ``(B, max_blocks)`` int32 (0 = unmapped → trash);
* per-slot **logical positions** stay dense int32 exactly as in the ring
  cache (``pos`` is ~0.1% of the K/V bytes — the capacity win is in K/V),
  so every masking rule (causal, window, invalid) is unchanged.

Logical address of token position ``p`` in slot ``b``::

    logical_slot = p %  L          (L = max_blocks * block_size)
    block        = logical_slot // block_size
    offset       = logical_slot %  block_size
    physical     = table[b, block]

Device/host split
-----------------
The device side only ever *indexes through* the table: writes scatter into
``pool[physical, offset]`` and attention gathers one block per online-softmax
chunk.  Allocation and freeing are **host-side** (:class:`BlockPool`), done
at the scheduler's sync points: admission reserves a request's *worst-case*
block count (prompt + budget + speculative overhang,
:meth:`PagedCacheConfig.request_blocks`) and maps the slot's table rows —
refusing admission when the pool lacks headroom — and harvest returns the
finished slot's whole list.  Mid-cycle rollback therefore stays an index
rewind: the slot still owns its reserved blocks, stale entries are masked
by stored position, and no allocation can ever be needed mid-flight.
:func:`used_blocks` computes a slot's live block prefix for finer-grained
truncation (e.g. reclaiming the unused tail of an EOS-terminated slot
before harvest).

Block sharing (prefix cache)
----------------------------
Blocks are **refcounted**: every live mapping of a physical block into some
slot's table holds one reference (``alloc`` creates the first, ``acquire``
adds more when the serving prefix cache maps an already-written block
read-only into a new slot).  ``free`` drops a reference; a block whose count
hits zero either returns to the free list or — when a registered
``retain_cb`` says its content is published in the prefix index — parks in
a **reclaimable LRU** from which future allocations evict
(``evict_cb`` notifies the index).  ``available`` counts free + reclaimable,
so cached content never blocks admission.  The write-side invariant the
serving layer maintains on top: *a block with refcount > 1 — or refcount 1
held by another slot — is never written*; a slot that must write into a
shared tail block copies it first (:func:`cow_clone_blocks`, the device
half of copy-on-write) and swaps its table entry before the write lands.

Per-shard trash blocks
----------------------
On a serving mesh the pool's block dim shards over ``data``; a masked or
unmapped write routed to the *global* block 0 would scatter cross-shard.
The cache therefore carries a per-slot ``trash`` block id
(:func:`slot_trash_blocks`: the reserved first block of the slot's own pool
partition — block 0 on one device), and ``paged_cache_write`` routes masked
writes there.  A table entry equal to the slot's trash id means *unmapped*.

Per-family layouts
------------------
Every architecture family routes through the paged server; what differs is
which leaves page:

* **attention families** (dense / moe / vlm / audio) page their KV exactly
  as above; audio's cross-attention K/V stays dense (encoder-length, written
  once at admission — nothing grows).
* **``cfg.sliding_window``** layers get a *ring of blocks*: the table is
  sized to the window, not the context (``PagedCacheConfig.table_blocks``),
  and the write path's ``p % L`` wraps it — the same modulo that implements
  the dense ring.  Because stored positions stay absolute and attention
  masks by position, rollback remains an index rewind even across the wrap
  (a *wrapped rewind*): rewound entries are invisible to queries either by
  position or by having been overwritten, exactly the dense ring's rules.
* **hybrid** models page only their attention sub-cache (``cache["attn"]``);
  the recurrent leaves (conv/ssm state, O(1) per slot) stay dense in the
  carry.
* **pure-ssm** models have no attention KV at all: they route through the
  paged server with a zero-block table — no pool, no table leaves, and
  admission gated on slots only.

Quantized pool (``PagedCacheConfig.kv_dtype``)
----------------------------------------------
``kv_dtype="int8"`` / ``"fp8"`` stores ``k_pool``/``v_pool`` in the low-bit
dtype with per-token per-head amax scales riding in a small parallel **scale
pool** — ``k_scale``/``v_scale`` of shape ``(n_layers, n_blocks, block_size,
Hkv)`` — indexed by the same physical block ids as the payload pools, so a
block's scale row travels with it through every table operation.  Writes
quantize (:func:`quantize_kv` inside :func:`paged_cache_write` — the prefill
seeding path and decode writes share it); reads dequantize at the gather
(:func:`paged_blockwise_attention`, :func:`gather_dense_view`, and the
Pallas ``kernels.decode_attn.paged_decode_attention_kernel``) without ever
materialising a dense dequantized view.  Because each token's scale is
finalized at its own write — never accumulated per block history — rollback
stays a pure index rewind, and :func:`cow_clone_blocks` / prefix
publish/acquire move a block's bytes and its scale row as one unit, so the
refcount>1 never-mutated invariant is untouched.  ``kv_dtype="bf16"`` (the
default) means *unquantized*: the pool keeps the model's activation dtype
and no scale leaves exist, exactly the historical layout.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = Dict[str, jnp.ndarray]

# Reserved physical block: masked-out tokens write here, reads never see it
# (their stored logical position stays invalid).
TRASH_BLOCK = 0

# Quantized-pool storage modes.  "bf16" = unquantized (the pool keeps the
# model's activation dtype — bf16 in production, float32 in the CPU
# harness); "int8"/"fp8" store low-bit payloads with per-token per-head
# amax scales in the parallel scale pool.
KV_DTYPES = ("bf16", "int8", "fp8")

# Scale-row element type: float16 keeps the scale overhead at 2 bytes per
# token-head (an int8 block + scales stays under half a bf16 block, which
# is what the equal-HBM admission win rides on), with ample range — scales
# are amax/qmax of O(1) activations — and 10 bits of mantissa, well below
# the int8 rounding error it multiplies.
SCALE_DTYPE = jnp.float16

_QMAX = {"int8": 127.0, "fp8": 448.0}     # fp8 = float8_e4m3fn max normal


def kv_dtype_unsupported_reason(kv_dtype: str) -> Optional[str]:
    """Why ``kv_dtype`` cannot back the pool here, or None when it can.

    Mirrors :func:`paged_unsupported_reason`: the serving layer and the
    launchers call this before any cache is built so an unsupported dtype
    fails with one actionable error naming the backend, instead of a raise
    from deep inside a jitted cache write."""
    if kv_dtype not in KV_DTYPES:
        return (f"unknown kv_dtype {kv_dtype!r} "
                f"(choose from {', '.join(KV_DTYPES)})")
    if kv_dtype == "fp8" and not hasattr(jnp, "float8_e4m3fn"):
        return (f"fp8 KV storage needs jnp.float8_e4m3fn, which this jax "
                f"build ({jax.__version__}, backend "
                f"{jax.default_backend()!r}) does not provide; use "
                f"kv_dtype='int8'")
    return None


def quantize_kv(x: jnp.ndarray, dtype) -> tuple:
    """Quantize ``x`` (..., D) to storage ``dtype`` with per-(...)-row amax
    scales: returns ``(q, scale)`` where ``q`` has ``x``'s shape in
    ``dtype`` and ``scale`` (...,) is :data:`SCALE_DTYPE`.  Quantization
    divides by the *stored* (float16-rounded) scale, so
    :func:`dequantize_kv` round-trips within the storage dtype's own
    rounding error.  All-zero rows store scale 1 (dequant stays zero)."""
    dtype = jnp.dtype(dtype)
    qmax = _QMAX["int8" if dtype == jnp.dtype(jnp.int8) else "fp8"]
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.where(amax > 0, amax / qmax, 1.0).astype(SCALE_DTYPE)
    y = xf / scale.astype(jnp.float32)[..., None]
    if dtype == jnp.dtype(jnp.int8):
        q = jnp.clip(jnp.round(y), -qmax, qmax).astype(dtype)
    else:
        q = y.astype(dtype)
    return q, scale


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`quantize_kv`: ``q`` (..., D) low-bit payload,
    ``scale`` (...,) per-row scales → float32."""
    return q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    """Shape of the shared block pool.

    ``n_blocks`` counts *physical* blocks including the reserved trash block,
    so ``n_blocks - 1`` are allocatable.  ``kv_dtype`` picks the pool's
    storage mode (see :data:`KV_DTYPES`): quantized modes add the parallel
    scale pool and shrink the per-block HBM cost
    (:func:`pool_block_bytes`).  Sizing guide: docs/SERVING.md.
    """
    block_size: int = 16
    n_blocks: int = 64
    kv_dtype: str = "bf16"

    def __post_init__(self):
        if self.kv_dtype not in KV_DTYPES:
            raise ValueError(f"unknown kv_dtype {self.kv_dtype!r} "
                             f"(choose from {', '.join(KV_DTYPES)})")

    @property
    def quantized(self) -> bool:
        return self.kv_dtype != "bf16"

    def storage_dtype(self, cfg: ModelConfig):
        """Pool element dtype: the model's activation dtype when
        unquantized, the low-bit storage type otherwise."""
        from repro.models.layers import dtype_of
        if self.kv_dtype == "bf16":
            return dtype_of(cfg)
        return jnp.int8 if self.kv_dtype == "int8" else jnp.float8_e4m3fn

    def max_blocks(self, max_len: int) -> int:
        """Table width: logical blocks needed for a ``max_len`` slot."""
        return -(-max_len // self.block_size)

    def ring_len(self, max_len: int, window: int = 0) -> int:
        """Logical ring length of one slot: ``max_len``, bounded by the
        sliding window when one is set — a windowed layer never needs to
        keep more than ``window`` live entries, so its table wraps."""
        return min(max_len, window) if window > 0 else max_len

    def table_blocks(self, max_len: int, window: int = 0) -> int:
        """Window-aware table width: logical blocks backing one slot's
        ring.  Equals :meth:`max_blocks` when ``window`` is 0; a windowed
        config's table (and so its per-slot pool footprint) is bounded by
        the window, not the context length."""
        return -(-self.ring_len(max_len, window) // self.block_size)

    def blocks_for(self, n_tokens: int) -> int:
        """Physical blocks a request writing ``n_tokens`` KV entries needs."""
        return -(-max(n_tokens, 1) // self.block_size)

    def request_blocks(self, prompt_len: int, max_tokens: int,
                       margin: int, max_len: int, window: int = 0) -> int:
        """Worst-case physical blocks one request reserves at admission:
        prompt + its (buffer-clamped) budget + the topology's speculative
        overhang ``margin`` (``buffer_margin``), capped at the slot's ring
        size (a windowed ring wraps, so a request can never hold more than
        its table width).  Reserving the worst case up front is what lets
        mid-flight rollback stay allocation-free."""
        mb = self.table_blocks(max_len, window)
        tokens = min(
            prompt_len + min(max_tokens, max_len - prompt_len) + margin,
            mb * self.block_size)
        return min(self.blocks_for(tokens), mb)


class BlockPool:
    """Host-side refcounting free-list allocator over the physical blocks
    of a pool.

    Lives in the scheduler; the device never sees it.  Block 0 (trash) is
    never handed out.  ``alloc`` is all-or-nothing so a partially admitted
    request can never strand blocks.

    Every live table mapping of a block holds one reference: ``alloc``
    creates the first, ``acquire`` adds one per extra slot sharing the
    block (prefix cache), ``free`` drops one.  A block reaching refcount 0
    consults ``retain_cb`` (set by the prefix cache): published blocks park
    in a reclaimable **LRU** — still counted by ``available`` — and are
    evicted (oldest first, ``evict_cb`` notified) when the free list runs
    short; unpublished blocks return to the free list immediately, exactly
    the pre-prefix-cache behaviour.

    The LRU itself is boundable: ``max_cached`` caps how many refcount-0
    blocks may park at once (insertion past the cap evicts oldest-first to
    the free list), and ``ttl_s`` expires parked blocks untouched for that
    long (swept at every ``alloc``; ``sweep_expired`` forces a sweep).
    Both default off (0), preserving the park-until-pressure behaviour.
    Eviction only ever touches refcount-0 blocks, so neither cap can stall
    an in-flight slot.  ``time_fn`` is injectable for deterministic tests.
    """

    def __init__(self, n_blocks: int, *, max_cached: int = 0,
                 ttl_s: float = 0.0,
                 time_fn: Callable[[], float] = time.monotonic):
        if n_blocks < 2:
            raise ValueError("pool needs >= 2 blocks (trash + 1 usable)")
        if max_cached < 0 or ttl_s < 0:
            raise ValueError("max_cached/ttl_s must be >= 0 (0 = off)")
        self.n_blocks = n_blocks
        self.max_cached = max_cached
        self.ttl_s = ttl_s
        self._time = time_fn
        self._free: List[int] = list(range(1, n_blocks))
        self._free_set = set(self._free)      # O(1) double-free detection
        self._ref: Dict[int, int] = {}        # block -> live references
        self._cached: "OrderedDict[int, float]" = OrderedDict()  # b -> t_in
        self.retain_cb: Optional[Callable[[int], bool]] = None
        self.evict_cb: Optional[Callable[[int], None]] = None

    @property
    def available(self) -> int:
        """Allocation headroom: free blocks plus reclaimable cached ones."""
        return len(self._free) + len(self._cached)

    @property
    def n_cached(self) -> int:
        return len(self._cached)

    def refcount(self, block: int) -> int:
        return self._ref.get(int(block), 0)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Take ``n`` blocks, or None (and take nothing) if short.  Free
        blocks are preferred; the shortfall evicts reclaimable cached
        blocks LRU-first (their index entries are dropped via
        ``evict_cb``)."""
        self.sweep_expired()
        if n > self.available:
            return None
        taken, self._free = self._free[:n], self._free[n:]
        self._free_set.difference_update(taken)
        while len(taken) < n:
            taken.append(self._evict_lru())
        for b in taken:
            self._ref[b] = 1
        return taken

    def _evict_lru(self) -> int:
        b, _ = self._cached.popitem(last=False)
        if self.evict_cb is not None:
            self.evict_cb(b)
        return b

    def sweep_expired(self) -> int:
        """Reclaim parked blocks older than ``ttl_s`` (oldest first — the
        LRU order is also insertion order, so expiry scans stop at the
        first survivor).  No-op when the TTL is off."""
        if not self.ttl_s or not self._cached:
            return 0
        cutoff = self._time() - self.ttl_s
        n = 0
        while self._cached:
            t_in = next(iter(self._cached.values()))
            if t_in > cutoff:
                break
            b = self._evict_lru()
            self._free.append(b)
            self._free_set.add(b)
            n += 1
        return n

    def evict_all_cached(self) -> int:
        """Reclaim every refcount-0 cached block (tests / pressure relief).
        Returns the number evicted; the blocks land on the free list."""
        n = 0
        while self._cached:
            self._free.append(self._evict_lru())
            n += 1
        self._free_set.update(self._free)
        return n

    def acquire(self, blocks: Sequence[int]) -> None:
        """Add one reference per block — the prefix cache maps cached
        blocks read-only into a new slot's table.  A refcount-0 cached
        block leaves the reclaimable LRU (it can no longer be evicted)."""
        for b in blocks:
            b = int(b)
            if b in self._free_set:
                raise ValueError(f"acquiring free (unwritten) block {b}")
            self._cached.pop(b, None)
            self._ref[b] = self._ref.get(b, 0) + 1

    def free(self, blocks: Sequence[int]) -> None:
        """Drop one reference per block (a slot's table unmapped it)."""
        seen: Dict[int, int] = {}
        for b in blocks:
            b = int(b)
            if not (0 < b < self.n_blocks):
                raise ValueError(f"freeing invalid block {b}")
            if b in self._free_set or b in self._cached:
                raise ValueError(f"double free of block {b}")
            if self._ref.get(b, 0) - seen.get(b, 0) < 1:
                raise ValueError(f"double free of block {b}")
            seen[b] = seen.get(b, 0) + 1
        for b in blocks:
            b = int(b)
            self._ref[b] -= 1
            if self._ref[b] == 0:
                del self._ref[b]
                if self.retain_cb is not None and self.retain_cb(b):
                    self._cached[b] = self._time()  # most-recently used
                    while (self.max_cached
                           and len(self._cached) > self.max_cached):
                        old = self._evict_lru()     # size cap: oldest out
                        self._free.append(old)
                        self._free_set.add(old)
                else:
                    self._free.append(b)
                    self._free_set.add(b)


class ShardedBlockPool:
    """Per-data-shard free lists over one global physical-block id space —
    the host half of the *partitioned* pool on a serving mesh.

    The device pool array shards its block dim over ``data`` in contiguous
    ranges (shard ``s`` owns physical ids ``[s*per, (s+1)*per)``), so a slot
    served by data shard ``s`` must only ever reference blocks from that
    range or its gathers would cross shards.  This allocator enforces that
    *by construction*: ``alloc(n, shard)`` only hands out ids from the
    shard's own range.  The first block of every range is reserved (block 0
    is the global trash block; the other shards' first blocks are held back
    for symmetry, so every shard allocates from exactly ``per - 1`` blocks
    and capacity reasoning is shard-independent).
    """

    def __init__(self, n_blocks: int, n_shards: int, *, max_cached: int = 0,
                 ttl_s: float = 0.0,
                 time_fn: Callable[[], float] = time.monotonic):
        if n_shards < 1:
            raise ValueError("need >= 1 shard")
        if n_blocks % n_shards:
            raise ValueError(
                f"pool of {n_blocks} blocks does not divide over "
                f"{n_shards} data shards")
        self.n_blocks = n_blocks
        self.n_shards = n_shards
        self.per_shard = n_blocks // n_shards
        if self.per_shard < 2:
            raise ValueError("each shard needs >= 2 blocks "
                             "(reserved + 1 usable)")
        # one BlockPool per shard over LOCAL ids [0, per_shard): its
        # never-handed-out block 0 IS the shard's reserved first block, so
        # the whole refcount / retain-LRU / eviction lifecycle lives in
        # BlockPool once.  Global id = shard * per_shard + local id.
        # A global cached-LRU cap splits evenly (rounded up so a small cap
        # never silently disables caching on every shard).
        per_cap = -(-max_cached // n_shards) if max_cached else 0
        self._pools = [BlockPool(self.per_shard, max_cached=per_cap,
                                 ttl_s=ttl_s, time_fn=time_fn)
                       for _ in range(n_shards)]
        for s, p in enumerate(self._pools):
            base = s * self.per_shard
            p.retain_cb = (lambda base: lambda b:
                           self.retain_cb is not None
                           and self.retain_cb(base + b))(base)
            p.evict_cb = (lambda base: lambda b:
                          self.evict_cb(base + b)
                          if self.evict_cb is not None else None)(base)
        self.retain_cb: Optional[Callable[[int], bool]] = None
        self.evict_cb: Optional[Callable[[int], None]] = None

    @property
    def shard_capacity(self) -> int:
        """Allocatable blocks per shard (uniform across shards)."""
        return self.per_shard - 1

    def shard_of(self, block: int) -> int:
        return int(block) // self.per_shard

    def available(self, shard: int) -> int:
        """Shard headroom: free blocks plus reclaimable cached ones."""
        return self._pools[shard].available

    def n_cached(self, shard: int) -> int:
        return self._pools[shard].n_cached

    def refcount(self, block: int) -> int:
        s, off = divmod(int(block), self.per_shard)
        return self._pools[s].refcount(off)

    def alloc(self, n: int, shard: int) -> Optional[List[int]]:
        """Take ``n`` blocks from ``shard``'s range, or None (and take
        nothing) if that shard is short — other shards' headroom cannot
        help, their blocks live on other devices.  Shortfalls evict the
        shard's own reclaimable cached blocks LRU-first."""
        taken = self._pools[shard].alloc(n)
        if taken is None:
            return None
        base = shard * self.per_shard
        return [base + b for b in taken]

    def evict_all_cached(self) -> int:
        return sum(p.evict_all_cached() for p in self._pools)

    def sweep_expired(self) -> int:
        return sum(p.sweep_expired() for p in self._pools)

    def _by_shard(self, blocks: Sequence[int],
                  what: str) -> Dict[int, List[int]]:
        """Group global ids into per-shard local ids, validating ranges
        (a reserved first block — local id 0 — is never a valid operand)."""
        out: Dict[int, List[int]] = {}
        for b in blocks:
            s, off = divmod(int(b), self.per_shard)
            if not (0 <= s < self.n_shards) or off == 0:
                raise ValueError(f"{what} invalid/reserved block {b}")
            out.setdefault(s, []).append(off)
        return out

    def acquire(self, blocks: Sequence[int]) -> None:
        """Add one reference per block (see :meth:`BlockPool.acquire`)."""
        for s, local in self._by_shard(blocks, "acquiring").items():
            self._pools[s].acquire(local)

    def free(self, blocks: Sequence[int]) -> None:
        """Drop one reference per block; blocks return to their owning
        shard (inferred from the id) at refcount 0 — to the shard's free
        list, or to its reclaimable LRU when ``retain_cb`` keeps them.
        Validation is per shard: an invalid mix fails before any shard is
        touched, a double free within one shard fails with that shard's
        blocks untouched."""
        grouped = self._by_shard(blocks, "freeing")
        for s, local in grouped.items():
            self._pools[s].free(local)


def paged_unsupported_reason(cfg: ModelConfig) -> Optional[str]:
    """Why ``cfg`` cannot take a paged KV cache, or None when it can.

    The serving layer (``ServerConfig(cache="paged")`` validation) and the
    launchers call this *before* any cache is built so an unsupported
    config would fail with one actionable error instead of a raise from
    deep inside ``Model.init_cache``.  Every family currently supports the
    paged server: attention families page their KV, sliding-window layers
    get a window-bounded ring of blocks, hybrids page only their attention
    sub-cache, and pure-ssm configs route through with a zero-block table
    (see the per-family layouts in the module docstring)."""
    del cfg
    return None


def pool_block_bytes(cfg: ModelConfig, block_size: int,
                     kv_dtype: str = "bf16") -> int:
    """HBM bytes ONE physical block costs per layer: K + V payload plus,
    when quantized, the parallel scale rows.  The unit for honest equal-HBM
    pool sizing: a quantized ``ServerConfig(pool_blocks=0)`` fits as many
    blocks as the dense-equivalent *byte* budget allows, and the admission
    benchmark compares pools of equal bytes, not equal block counts."""
    from repro.models.layers import dtype_of

    reason = kv_dtype_unsupported_reason(kv_dtype)
    if reason is not None:
        raise ValueError(f"cannot size a kv_dtype={kv_dtype!r} pool: "
                         f"{reason}")
    if kv_dtype == "bf16":
        per_th = cfg.head_dim * jnp.dtype(dtype_of(cfg)).itemsize
    else:
        store = jnp.int8 if kv_dtype == "int8" else jnp.float8_e4m3fn
        per_th = (cfg.head_dim * jnp.dtype(store).itemsize
                  + jnp.dtype(SCALE_DTYPE).itemsize)
    return 2 * block_size * cfg.n_kv_heads * per_th


def used_blocks(n_tokens: int, block_size: int) -> int:
    """Blocks a slot actually used for ``n_tokens`` cached entries.  The
    serving scheduler frees finished slots' lists whole at harvest; this
    helper supports finer-grained truncation (trailing table entries past
    this count can be zeroed and their blocks returned early)."""
    return -(-int(n_tokens) // block_size)


# ---------------------------------------------------------------------------
# Device-side cache construction / table maintenance
# ---------------------------------------------------------------------------

def slot_trash_blocks(batch: int, n_blocks: int,
                      data_shards: int = 1) -> jnp.ndarray:
    """(B,) physical trash block per slot: the reserved first block of the
    pool partition owned by the slot's data shard, so masked/unmapped paged
    writes scatter shard-locally (block 0 — the global trash — on one
    device).  Slots map to shards contiguously, mirroring the carry's
    ``data``-axis partitioning."""
    if batch % data_shards:
        raise ValueError(f"batch {batch} must divide over {data_shards} "
                         "data shards")
    if n_blocks % data_shards:
        raise ValueError(f"pool of {n_blocks} blocks must divide over "
                         f"{data_shards} data shards")
    per_slot = batch // data_shards
    per_shard = n_blocks // data_shards
    shard = jnp.arange(batch, dtype=jnp.int32) // per_slot
    return shard * per_shard


def make_paged_attention_cache(cfg: ModelConfig, batch: int, max_len: int,
                               paged: PagedCacheConfig, *,
                               n_layers: Optional[int] = None,
                               data_shards: int = 1,
                               kv_dtype: Optional[str] = None) -> Params:
    """Paged counterpart of ``layers.make_attention_cache``.

    Layout (leading ``n_layers`` dim on every leaf when given, so the layer
    scan slices the pool, positions, and table uniformly)::

        k_pool / v_pool   : (n_layers, n_blocks, block_size, Hkv, D)
        k_scale / v_scale : (n_layers, n_blocks, block_size, Hkv)  quantized
        pos               : (n_layers, B, ring + TRASH_SLOTS) logical/slot
        table             : (n_layers, B, max_blocks)      physical block ids
        trash             : (n_layers, B)                  per-slot trash id

    ``ring`` (= ``PagedCacheConfig.ring_len``) is ``max_len``, bounded by
    ``cfg.sliding_window`` when one is set; the ``pos`` width encodes it so
    the write path wraps at EXACTLY the dense ring's length (bit-identical
    masking even when the window does not divide the block size).

    ``table`` and ``trash`` are logically layer-independent (the host writes
    the same rows to every layer); they carry the layer dim only so the
    cache pytree scans.  Tables start at the slot's trash id == unmapped: a
    slot must be mapped via :func:`assign_block_rows` before its writes
    persist.  ``data_shards`` > 1 gives every slot the reserved first block
    of its own pool partition as trash (shard-local masked writes).

    ``max_blocks`` is window-aware: a ``cfg.sliding_window`` config's table
    covers ``min(max_len, window)`` tokens and wraps (a ring of blocks),
    so its pool footprint is bounded by the window, not the context.

    ``kv_dtype`` overrides ``paged.kv_dtype``; quantized modes store the
    pools in the low-bit dtype and add the parallel scale pool (same
    physical block indexing, :data:`SCALE_DTYPE` elements).
    """
    from repro.models.layers import TRASH_SLOTS, _INVALID_POS

    if kv_dtype is not None:
        paged = dataclasses.replace(paged, kv_dtype=kv_dtype)
    reason = kv_dtype_unsupported_reason(paged.kv_dtype)
    if reason is not None:
        raise ValueError(f"paged KV cache for {cfg.name!r} cannot use "
                         f"kv_dtype={paged.kv_dtype!r}: {reason}")
    bs = paged.block_size
    # A sliding-window config's table is a ring of blocks bounded by the
    # window: paged_cache_write's `p % ring` wraps it, and positional
    # masking keeps overwritten out-of-window entries invisible — the same
    # rules the dense ring lives by, so rollback stays a (wrapped) index
    # rewind.  The ring length rides in the pos width (ring + TRASH_SLOTS)
    # so the wrap point matches the dense ring exactly, block-aligned or
    # not.
    window = cfg.sliding_window or 0
    ring = paged.ring_len(max_len, window)
    mb = paged.table_blocks(max_len, window)
    trash = slot_trash_blocks(batch, paged.n_blocks, data_shards)
    shape_pool = (paged.n_blocks, bs, cfg.n_kv_heads, cfg.head_dim)
    shape_scale = (paged.n_blocks, bs, cfg.n_kv_heads)
    shape_pos = (batch, ring + TRASH_SLOTS)
    table = jnp.broadcast_to(trash[:, None], (batch, mb))
    if n_layers is not None:
        shape_pool = (n_layers,) + shape_pool
        shape_scale = (n_layers,) + shape_scale
        shape_pos = (n_layers,) + shape_pos
        table = jnp.broadcast_to(table[None], (n_layers, batch, mb))
        trash = jnp.broadcast_to(trash[None], (n_layers, batch))
    dt = paged.storage_dtype(cfg)
    out = {
        "k_pool": jnp.zeros(shape_pool, dt),
        "v_pool": jnp.zeros(shape_pool, dt),
        "pos": jnp.full(shape_pos, _INVALID_POS, jnp.int32),
        "table": jnp.array(table, jnp.int32),
        "trash": jnp.array(trash, jnp.int32),
    }
    if paged.quantized:
        out["k_scale"] = jnp.zeros(shape_scale, SCALE_DTYPE)
        out["v_scale"] = jnp.zeros(shape_scale, SCALE_DTYPE)
    return out


def is_paged(cache: Optional[Params]) -> bool:
    return cache is not None and "table" in cache


def assign_block_rows(cache: Params, slot_mask: jnp.ndarray,
                      rows: jnp.ndarray) -> Params:
    """Point the table rows of slots in ``slot_mask`` (B,) at ``rows``
    (B, max_blocks) — the device half of admission.  Rows of unmasked slots
    are untouched; the layer dim (if any) receives the same rows."""
    tbl = cache["table"]
    rows = rows.astype(jnp.int32)
    if tbl.ndim == 3:                      # (n_layers, B, max_blocks)
        new = jnp.where(slot_mask[None, :, None], rows[None], tbl)
    else:
        new = jnp.where(slot_mask[:, None], rows, tbl)
    return {**cache, "table": new}


def cow_clone_blocks(cache: Params, src: jnp.ndarray,
                     dst: jnp.ndarray) -> Params:
    """Copy-on-write block clone: for every slot ``b``, copy the pool rows
    of physical block ``src[b]`` into ``dst[b]`` (all layers, K and V) —
    the jitted device half of COW.  The host points a slot that must write
    into a *shared* tail block at a freshly allocated private ``dst``,
    clones the shared rows here, and the admission prefill's writes then
    land in the private copy; the shared ``src`` (refcount > 1) is never
    mutated.  Slots with nothing to clone pass ``src == dst == trash``:
    the copy degenerates to trash → trash.  On a serving mesh both ids come
    from the slot's own pool partition, so the clone stays shard-local.
    On a quantized pool the scale rows are cloned with their payload — a
    block's bytes plus its scale row move as one unit, so the copy is
    bit-exact and no requantization happens."""
    src = src.astype(jnp.int32)
    dst = dst.astype(jnp.int32)

    def clone(pool, layered):
        if layered:                        # leading n_layers dim
            return pool.at[:, dst].set(pool[:, src])
        return pool.at[dst].set(pool[src])

    layered = cache["k_pool"].ndim == 5    # (n_layers, N, bs, Hkv, D)
    new = {**cache,
           "k_pool": clone(cache["k_pool"], layered),
           "v_pool": clone(cache["v_pool"], layered)}
    for leaf in ("k_scale", "v_scale"):
        if leaf in cache:
            new[leaf] = clone(cache[leaf], layered)
    return new


def seed_prefix_positions(cache: Params, slot_mask: jnp.ndarray,
                          start: jnp.ndarray) -> Params:
    """Mark logical positions ``[0, start[b])`` of the admitted slots'
    ``pos`` rows valid (stored pos == logical pos) — the device half of
    mapping an already-written cached prefix into a fresh slot.  A shared
    prefix runs contiguously from position 0, so its stored positions are
    reconstructed locally instead of being copied from the publishing slot.
    Positions past ``start`` stay as reset left them (invalid)."""
    pos = cache["pos"]
    width = pos.shape[-1]
    ar = jnp.arange(width, dtype=jnp.int32)
    mask = slot_mask[:, None] & (ar[None, :] < start[:, None])    # (B, W)
    if pos.ndim == 3:                      # (n_layers, B, W)
        new = jnp.where(mask[None], ar[None, None], pos)
    else:
        new = jnp.where(mask, ar[None], pos)
    return {**cache, "pos": new}


def full_tables(batch: int, max_blocks: int) -> jnp.ndarray:
    """Dense-equivalent static assignment: slot ``b`` owns the contiguous
    physical blocks ``[1 + b*max_blocks, 1 + (b+1)*max_blocks)``.  Needs a
    pool of ``1 + batch * max_blocks`` blocks; used by offline sessions and
    parity tests where dynamic allocation is beside the point."""
    base = 1 + max_blocks * jnp.arange(batch, dtype=jnp.int32)[:, None]
    return base + jnp.arange(max_blocks, dtype=jnp.int32)[None]


def worker_cache_view(cache: Params, table_row: jnp.ndarray,
                      trash_id: jnp.ndarray) -> Params:
    """Batch-1 synthetic paged cache over the serving pool — the prefill
    worker's half of the prefill/decode handoff.

    The pool (and scale) leaves are shared *by reference* with the live
    serving cache, so the worker's writes land in the same physical
    blocks a decode slot will later map; the per-slot leaves (``pos``,
    ``table``, ``trash``) are freshly built batch-1 arrays pointing at
    ``table_row`` (max_blocks,), so the worker program never touches any
    live slot's rows.  Merge the written pools back into the serving
    carry with :func:`merge_worker_pool` — the per-slot view leaves are
    discarded; the decode slot reconstructs positions itself via
    :func:`seed_prefix_positions` at admission.
    """
    from repro.models.layers import _INVALID_POS
    n_layers, _, width = cache["pos"].shape
    mb = cache["table"].shape[-1]
    trash = jnp.asarray(trash_id, jnp.int32)
    view = {
        "k_pool": cache["k_pool"],
        "v_pool": cache["v_pool"],
        "pos": jnp.full((n_layers, 1, width), _INVALID_POS, jnp.int32),
        "table": jnp.broadcast_to(
            table_row.astype(jnp.int32)[None, None], (n_layers, 1, mb)),
        "trash": jnp.broadcast_to(trash.reshape(1, 1), (n_layers, 1)),
    }
    for leaf in ("k_scale", "v_scale"):
        if leaf in cache:
            view[leaf] = cache[leaf]
    return view


def merge_worker_pool(cache: Params, view: Params) -> Params:
    """Fold a :func:`worker_cache_view`'s written pool leaves back into the
    serving cache.  Only the shared pool/scale leaves move; every per-slot
    leaf of ``cache`` (pos rows, block tables, trash ids, and on the
    serving carry the whole drafter/recurrent side) is untouched, so a
    worker fill can never perturb a live slot."""
    new = dict(cache)
    for leaf in ("k_pool", "v_pool", "k_scale", "v_scale"):
        if leaf in cache:
            new[leaf] = view[leaf]
    return new


# ---------------------------------------------------------------------------
# Device-side write / attention paths (mirrors of layers._cache_write and
# layers.blockwise_attention, indexing K/V through the block table)
# ---------------------------------------------------------------------------

def paged_cache_write(cache: Params, new_k, new_v, positions) -> Params:
    """Write T new KV entries at per-batch logical ``positions`` (B, T).

    Valid entries scatter into ``pool[table[b, p%L // bs], p%L % bs]``;
    entries with position < 0 (masked tokens) go to the slot's trash block
    (``cache["trash"]`` — shard-local on a serving mesh, block 0 otherwise)
    and a trash pos slot, exactly mirroring the dense ring's trash-slot
    contract.  Writes to slots whose table row is unmapped (== the slot's
    trash id) are *dropped whole* (K/V to trash, pos stays invalid) — an
    unmapped slot can neither be corrupted nor fabricate readable entries.

    On a quantized pool (scale leaves present) the write is
    quantize-on-write: each (token, head) row quantizes against its own
    amax (:func:`quantize_kv`) and scatters payload + scale with the same
    ``[phys, off]`` indices.  A write granule finalizes its own scales, so
    a later index rewind (rollback) simply leaves stale rows to be
    overwritten — committed blocks' scales are never revisited.
    """
    from repro.models.layers import TRASH_SLOTS, _INVALID_POS

    k_pool, v_pool, pos_arr, table = (cache["k_pool"], cache["v_pool"],
                                      cache["pos"], cache["table"])
    b, t = positions.shape
    bs = k_pool.shape[-3]
    mb = table.shape[-1]

    trash = cache.get("trash")
    if trash is None:                       # hand-built test caches
        trash = jnp.full((b,), TRASH_BLOCK, jnp.int32)
        l = mb * bs                         # pre-trash schema: block-aligned
    else:
        # the pos width encodes the slot's exact logical ring length
        # (ring + TRASH_SLOTS): max_len, or the sliding window when the
        # config has one — wrapping here is what makes the windowed table
        # a ring of blocks, and matching the dense ring's wrap point
        # exactly is what keeps the two layouts token-identical
        l = pos_arr.shape[-1] - TRASH_SLOTS
    logical = jnp.where(positions >= 0, positions % l, 0)
    blk = logical // bs
    b_idx = jnp.arange(b)[:, None]
    valid = (positions >= 0) & (table[b_idx, blk] != trash[:, None])
    phys = jnp.where(valid, table[b_idx, blk], trash[:, None])    # (B, T)
    off = jnp.where(valid, logical % bs,
                    jnp.arange(t, dtype=jnp.int32)[None] % bs)

    # pos bookkeeping is identical to the dense ring (trash pos slots past L)
    pslot = jnp.where(valid, logical,
                      l + (jnp.arange(t, dtype=positions.dtype)
                           % TRASH_SLOTS)[None])
    stored = jnp.where(valid, positions, _INVALID_POS)
    out = {**cache,
           "pos": pos_arr.at[b_idx, pslot].set(stored.astype(jnp.int32)),
           "table": table}
    if "k_scale" in cache:
        qk, sk = quantize_kv(new_k, k_pool.dtype)
        qv, sv = quantize_kv(new_v, v_pool.dtype)
        out["k_pool"] = k_pool.at[phys, off].set(qk)
        out["v_pool"] = v_pool.at[phys, off].set(qv)
        out["k_scale"] = cache["k_scale"].at[phys, off].set(sk)
        out["v_scale"] = cache["v_scale"].at[phys, off].set(sv)
    else:
        out["k_pool"] = k_pool.at[phys, off].set(new_k.astype(k_pool.dtype))
        out["v_pool"] = v_pool.at[phys, off].set(new_v.astype(v_pool.dtype))
    return out


def paged_blockwise_attention(q: jnp.ndarray, cache: Params,
                              q_pos: jnp.ndarray, *, window: int = 0,
                              causal: bool = True, chunk: int = 1024,
                              return_partial: bool = False):
    """Online-softmax attention over a paged cache.

    q: (B, T, H, D); q_pos: (B, T).  Semantically identical to
    ``layers.blockwise_attention`` over the gathered dense view — both
    scans share the same ``layers.online_softmax_step`` body, so the two
    layouts cannot drift numerically — but here the gather happens inside
    the scan: each step fetches ``chunk // block_size`` table entries
    (matching the dense path's scan granularity, so small blocks don't
    multiply sequential steps), and peak memory is the pool plus one
    (B, chunk) window, never the full logical view.  On a quantized pool
    each step additionally gathers the fetched blocks' scale rows and
    dequantizes in-register — only the low-bit pool ever lives in HBM.
    """
    from repro.models.layers import (_INVALID_POS, _NEG_INF, kv_valid_mask,
                                     online_softmax_step)

    k_pool, v_pool, pos_arr, table = (cache["k_pool"], cache["v_pool"],
                                      cache["pos"], cache["table"])
    b, t, h, d = q.shape
    bs = k_pool.shape[-3]
    hkv = k_pool.shape[-2]
    mb = table.shape[-1]
    g = h // hkv
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, t, hkv, g, d)

    # group table entries so one scan step covers ~chunk KV tokens; the
    # tail pads with trash blocks (0) + invalid positions, masked like any
    # unmapped entry
    gb = max(1, min(chunk // bs, mb))
    n_steps = -(-mb // gb)
    # pool slot (blk, off) reads its position from pos[blk*bs + off].  A
    # non-block-aligned ring (windowed, window % bs != 0) leaves the last
    # block's tail slots unwritten; their pos indices land in the trash
    # region (always _INVALID_POS -> masked) or past the row (padded
    # invalid), so they can never contribute.
    need = mb * bs
    if pos_arr.shape[-1] >= need:
        pos_l = pos_arr[:, :need]
    else:
        pos_l = jnp.pad(pos_arr, ((0, 0),
                                  (0, need - pos_arr.shape[-1])),
                        constant_values=_INVALID_POS)
    if n_steps * gb != mb:
        pad = n_steps * gb - mb
        table = jnp.pad(table, ((0, 0), (0, pad)))
        pos_l = jnp.pad(pos_l, ((0, 0), (0, pad * bs)),
                        constant_values=_INVALID_POS)
    tbl_steps = jnp.moveaxis(table.reshape(b, n_steps, gb), 1, 0)
    pos_steps = jnp.moveaxis(pos_l.reshape(b, n_steps, gb * bs), 1, 0)

    m0 = jnp.full((b, t, hkv, g), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, t, hkv, g), jnp.float32)
    o0 = jnp.zeros((b, t, hkv, g, d), jnp.float32)

    quant = "k_scale" in cache
    k_scale = cache.get("k_scale")             # (N, bs, Hkv) or None
    v_scale = cache.get("v_scale")

    def step(carry, xs):
        tbl_j, pos_j = xs                       # (B, GB), (B, GB*bs)
        kci = k_pool[tbl_j].reshape(b, gb * bs, hkv, d)
        vci = v_pool[tbl_j].reshape(b, gb * bs, hkv, d)
        if quant:
            ks = k_scale[tbl_j].reshape(b, gb * bs, hkv)
            vs = v_scale[tbl_j].reshape(b, gb * bs, hkv)
            kci = dequantize_kv(kci, ks)
            vci = dequantize_kv(vci, vs)
        valid = kv_valid_mask(pos_j, q_pos, causal=causal, window=window)
        return online_softmax_step(carry, qg, kci, vci, valid, scale), None

    (m, l, o), _ = jax.lax.scan(step, (m0, l0, o0), (tbl_steps, pos_steps))
    if return_partial:
        return m, l, o
    out = o / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, t, h, d).astype(q.dtype)


def gather_dense_view(cache: Params) -> Params:
    """Materialise the dense {k, v, pos} view of one layer's paged cache —
    (B, L, Hkv, D) — for oracles and the Pallas-kernel fallback path.
    Quantized pools come back dequantized (float32).  This allocates the
    full logical view: debugging/testing only."""
    k = cache["k_pool"][cache["table"]]                # (B, MB, bs, Hkv, D)
    v = cache["v_pool"][cache["table"]]
    if "k_scale" in cache:
        k = dequantize_kv(k, cache["k_scale"][cache["table"]])
        v = dequantize_kv(v, cache["v_scale"][cache["table"]])
    b, mb, bs = k.shape[0], k.shape[1], k.shape[2]
    l = mb * bs
    pos = cache["pos"]
    if pos.shape[-1] < l:      # non-block-aligned ring: pad tail invalid
        from repro.models.layers import _INVALID_POS
        pos = jnp.pad(pos, ((0, 0), (0, l - pos.shape[-1])),
                      constant_values=_INVALID_POS)
    return {
        "k": k.reshape(b, l, *k.shape[3:]),
        "v": v.reshape(b, l, *v.shape[3:]),
        "pos": pos[:, :l],
    }
