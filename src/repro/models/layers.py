"""Shared neural-net layers: norms, RoPE, GQA attention (blockwise online
softmax, ring-buffer sliding-window KV cache), MLPs and capacity-based MoE.

``attention_forward`` serves two KV-cache layouts behind one interface:
the dense per-slot ring built here (``make_attention_cache``) and the paged
block-table cache (``repro.models.paging``); both share the position-based
masking rules, so the speculative engine's rollback contract is identical.
Under the serving prefix cache a paged slot's table may mix *shared*
(read-only, refcounted) and private blocks: reads gather through the table
either way, while writes — which only ever target positions ≥ the slot's
cached-prefix start — land in private blocks by construction, with masked
tokens routed to the slot's shard-local trash block.

Conventions
-----------
* Parameters are plain nested dicts of ``jnp.ndarray`` (no flax in env).
* Layer stacks keep a leading ``n_layers`` dim and are consumed by
  ``jax.lax.scan`` so HLO size is independent of depth.
* Activations are computed in ``cfg.dtype``; softmax statistics in float32.
* ``sharding.constrain`` annotates logical axes; it is a no-op outside a
  rules context so unit tests on one device are untouched.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding import constrain
from repro.utils.lowering import attn_chunk_override

Params = Dict[str, jnp.ndarray]

DEFAULT_ATTN_CHUNK = 1024
_NEG_INF = -1e9
_INVALID_POS = -(1 << 30)


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Initialisation helpers
# ---------------------------------------------------------------------------

def _dense_init(key, shape, scale: Optional[float] = None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 2 else 1
    if scale is None:
        scale = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def init_norm(cfg: ModelConfig, key) -> Params:
    p = {"scale": jnp.ones((cfg.d_model,), jnp.float32)}
    if cfg.norm_variant == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), jnp.float32)
    return p


def apply_norm(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm_variant == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"] + p["bias"]
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"]
    return y.astype(x.dtype)


def _rms_head_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               fraction: float = 1.0) -> jnp.ndarray:
    """x: (B, T, H, D); positions: (B, T) int32.

    ``fraction`` < 1 rotates only the first ``fraction * D`` channels
    (chatglm-style partial rotary)."""
    b, t, h, d = x.shape
    rot = int(d * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    half = rot // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, :, None] * freq[None, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)
    return jnp.concatenate([out, x_pass], axis=-1) if rot < d else out


# ---------------------------------------------------------------------------
# Attention (GQA, blockwise online softmax, optional sliding window)
# ---------------------------------------------------------------------------

def init_attention(cfg: ModelConfig, key, *, cross: bool = False) -> Params:
    kq, kk, kv, ko, kn = jax.random.split(key, 5)
    d, hd = cfg.d_model, cfg.head_dim
    p = {
        "wq": _dense_init(kq, (d, cfg.n_heads * hd)),
        "wk": _dense_init(kk, (d, cfg.n_kv_heads * hd)),
        "wv": _dense_init(kv, (d, cfg.n_kv_heads * hd)),
        "wo": _dense_init(ko, (cfg.n_heads * hd, d)),
    }
    if cfg.use_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), jnp.float32)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.float32)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.float32)
        p["bo"] = jnp.zeros((d,), jnp.float32)
    if cfg.use_qk_norm:
        p["q_norm_scale"] = jnp.ones((hd,), jnp.float32)
        p["k_norm_scale"] = jnp.ones((hd,), jnp.float32)
    return p


def _pad_to_multiple(x: jnp.ndarray, axis: int, multiple: int, value=0):
    size = x.shape[axis]
    rem = size % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, multiple - rem)
    return jnp.pad(x, pad, constant_values=value)


def kv_valid_mask(k_pos: jnp.ndarray, q_pos: jnp.ndarray, *, causal: bool,
                  window: int) -> jnp.ndarray:
    """(B, C) stored KV positions + (B, T) query positions → (B, T, C)
    attention validity.  Entries with stored position < 0 are invalid
    everywhere; ``causal``/``window`` add the usual position cuts."""
    valid = k_pos[:, None, :] >= 0
    if causal:
        valid = valid & (k_pos[:, None, :] <= q_pos[:, :, None])
    if window > 0:
        valid = valid & (k_pos[:, None, :] > (q_pos[:, :, None] - window))
    return valid


def online_softmax_step(carry, qg, kci, vci, valid, scale):
    """One online-softmax accumulation over a KV chunk — the single step
    body shared by the dense ring scan (``blockwise_attention``) and the
    paged block scan (``paging.paged_blockwise_attention``), so the two
    layouts cannot drift numerically.

    carry: (m, l, o) f32 partials (B,T,Hkv,G[,D]); qg (B,T,Hkv,G,D);
    kci/vci (B,C,Hkv,D); valid (B,T,C)."""
    m, l, o = carry
    scores = jnp.einsum("btkgd,bckd->btkgc", qg, kci,
                        preferred_element_type=jnp.float32) * scale
    scores = jnp.where(valid[:, :, None, None, :], scores, _NEG_INF)
    m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
    alpha = jnp.exp(m - m_new)
    probs = jnp.exp(scores - m_new[..., None])
    l_new = l * alpha + jnp.sum(probs, axis=-1)
    o_new = o * alpha[..., None] + jnp.einsum(
        "btkgc,bckd->btkgd", probs.astype(vci.dtype), vci,
        preferred_element_type=jnp.float32)
    return m_new, l_new, o_new


def merge_attention_partials(*partials):
    """Merge (m, l, o) online-softmax partials from disjoint KV sets and
    normalise.  Shapes: m/l (B,T,Hkv,G), o (B,T,Hkv,G,D)."""
    m = partials[0][0]
    for p in partials[1:]:
        m = jnp.maximum(m, p[0])
    l = jnp.zeros_like(partials[0][1])
    o = jnp.zeros_like(partials[0][2])
    for (mi, li, oi) in partials:
        alpha = jnp.exp(mi - m)
        l = l + li * alpha
        o = o + oi * alpha[..., None]
    return o / jnp.maximum(l[..., None], 1e-30)


def dense_masked_attention_partial(q, k, v, mask):
    """Unnormalised attention partial over a small dense KV block with an
    explicit (T, S) boolean mask (tree-ancestry attention).

    q: (B,T,H,D); k/v: (B,S,Hkv,D); mask: (T,S) or (B,T,S).
    Returns (m, l, o) in blockwise_attention's partial format."""
    b, t, h, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    qg = q.reshape(b, t, hkv, g, d)
    scores = jnp.einsum("btkgd,bskd->btkgc".replace("c", "s"), qg, k,
                        preferred_element_type=jnp.float32) / math.sqrt(d)
    if mask.ndim == 2:
        mask = mask[None]
    scores = jnp.where(mask[:, :, None, None, :], scores, _NEG_INF)
    m = jnp.max(scores, axis=-1)
    probs = jnp.exp(scores - m[..., None])
    l = jnp.sum(probs, axis=-1)
    o = jnp.einsum("btkgs,bskd->btkgd", probs.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return m, l, o


def blockwise_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                        *, window: int = 0, causal: bool = True,
                        chunk: int = DEFAULT_ATTN_CHUNK,
                        return_partial: bool = False) -> jnp.ndarray:
    """Online-softmax attention, scanning over KV chunks.

    q: (B, T, H, D); k/v: (B, S, Hkv, D); q_pos: (B, T); k_pos: (B, S).
    Entries with k_pos < 0 are treated as invalid (masked out everywhere).
    Memory is bounded by the (B, T, H, chunk) score block.
    With ``return_partial`` the unnormalised (m, l, o) triple is returned
    for merging with other KV sets (tree attention).
    """
    b, t, h, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    scale = 1.0 / math.sqrt(d)

    qg = q.reshape(b, t, hkv, g, d)

    chunk = attn_chunk_override() or chunk
    chunk = min(chunk, s)
    k = _pad_to_multiple(k, 1, chunk)
    v = _pad_to_multiple(v, 1, chunk)
    k_pos = _pad_to_multiple(k_pos, 1, chunk, value=_INVALID_POS)
    n_chunks = k.shape[1] // chunk

    kc = jnp.moveaxis(k.reshape(b, n_chunks, chunk, hkv, d), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, n_chunks, chunk, hkv, d), 1, 0)
    pc = jnp.moveaxis(k_pos.reshape(b, n_chunks, chunk), 1, 0)

    m0 = jnp.full((b, t, hkv, g), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, t, hkv, g), jnp.float32)
    o0 = jnp.zeros((b, t, hkv, g, d), jnp.float32)

    def step(carry, xs):
        kci, vci, pci = xs
        valid = kv_valid_mask(pci, q_pos, causal=causal, window=window)
        return online_softmax_step(carry, qg, kci, vci, valid, scale), None

    (m, l, o), _ = jax.lax.scan(step, (m0, l0, o0), (kc, vc, pc))
    if return_partial:
        return m, l, o
    out = o / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, t, h, d).astype(q.dtype)


def causal_attention_unrolled(q, k, v, q_pos, k_pos, *, window: int = 0,
                              chunk: int = DEFAULT_ATTN_CHUNK) -> jnp.ndarray:
    """Block-causal attention that skips fully-masked upper-triangular KV
    blocks (a §Perf optimisation over ``blockwise_attention`` for the
    self-attention train/prefill path: ~2x fewer score FLOPs at long S).

    Requires q and k to cover the same positions block-aligned (q_pos ==
    k_pos), which holds for train/prefill."""
    b, t, h, d = q.shape
    assert k.shape[1] == t, "unrolled path expects self-attention"
    chunk = min(chunk, t)
    qp = _pad_to_multiple(q, 1, chunk)
    kp = _pad_to_multiple(k, 1, chunk)
    vp = _pad_to_multiple(v, 1, chunk)
    qpos = _pad_to_multiple(q_pos, 1, chunk, value=_INVALID_POS)
    kpos = _pad_to_multiple(k_pos, 1, chunk, value=_INVALID_POS)
    n = qp.shape[1] // chunk
    outs = []
    for i in range(n):
        qi = qp[:, i * chunk:(i + 1) * chunk]
        qpi = qpos[:, i * chunk:(i + 1) * chunk]
        # only attend to kv blocks j <= i (block-causal prefix)
        lo = 0
        if window > 0:
            lo = max(0, (i * chunk - window - chunk + 1) // chunk)
        hi = (i + 1) * chunk
        outs.append(
            blockwise_attention(
                qi, kp[:, lo * chunk:hi], vp[:, lo * chunk:hi],
                qpi, kpos[:, lo * chunk:hi],
                window=window, causal=True, chunk=chunk,
            )
        )
    return jnp.concatenate(outs, axis=1)[:, :t]


def _chunked_query_attend(q, positions, attend, *, chunk: int):
    """Scan query chunks through ``attend(q_chunk, pos_chunk)`` (dense or
    paged cache attention) so long prefills keep a bounded score block."""
    b, t, h, hd = q.shape
    nq = -(-t // chunk)
    qp = _pad_to_multiple(q, 1, chunk)
    pp = _pad_to_multiple(positions, 1, chunk, value=_INVALID_POS)
    qs = jnp.moveaxis(qp.reshape(b, nq, chunk, h, hd), 1, 0)
    ps = jnp.moveaxis(pp.reshape(b, nq, chunk), 1, 0)
    out = jax.lax.map(lambda xs: attend(xs[0], xs[1]), (qs, ps))
    return jnp.moveaxis(out, 0, 1).reshape(b, nq * chunk, h, hd)[:, :t]


# Extra ring slots used as a scratch target for masked-out tokens (keeps the
# data region aligned for kv_seq sharding; 8 trash slots, queries never see
# them because their stored pos stays invalid).
TRASH_SLOTS = 16


def make_attention_cache(cfg: ModelConfig, batch: int, max_len: int,
                         *, n_layers: Optional[int] = None) -> Params:
    """Ring-buffer KV cache.  If ``cfg.sliding_window`` > 0 the buffer holds
    only ``window`` slots; absolute positions are tracked in ``pos`` so a
    single masking path serves both full and windowed attention.

    The paged layout mirrors this exactly: its windowed ring is a ring *of
    blocks* wrapping at the same ``min(max_len, window)`` length (encoded
    in its pos-row width), so speculative writes clobber the same
    in-window entries in both layouts and rollback stays an index rewind —
    see ``repro.models.paging.make_paged_attention_cache``."""
    length = max_len
    if cfg.sliding_window:
        length = min(max_len, cfg.sliding_window)
    length += TRASH_SLOTS
    hd, hkv = cfg.head_dim, cfg.n_kv_heads
    dt = dtype_of(cfg)
    shape_kv = (batch, length, hkv, hd)
    shape_pos = (batch, length)
    if n_layers is not None:
        shape_kv = (n_layers,) + shape_kv
        shape_pos = (n_layers,) + shape_pos
    return {
        "k": jnp.zeros(shape_kv, dt),
        "v": jnp.zeros(shape_kv, dt),
        "pos": jnp.full(shape_pos, _INVALID_POS, jnp.int32),
    }


def _cache_write(cache: Params, new_k, new_v, positions,
                 uniform: bool = False) -> Params:
    """Write T new kv entries at per-batch positions (ring indexed).

    Entries with position < 0 (masked-out tokens) land in the trash slots
    past the data ring and keep an invalid stored pos.

    ``uniform``: all batch rows share positions[0] (uniform serving step) —
    write with one dynamic_update_slice on the length axis, which SPMD
    routes to the owning shard instead of broadcasting the updates."""
    b, t = positions.shape
    if uniform:
        ring = cache["k"].shape[1] - TRASH_SLOTS
        start = positions[0, 0] % ring
        zero = jnp.zeros((), start.dtype)
        return {
            "k": jax.lax.dynamic_update_slice(
                cache["k"], new_k.astype(cache["k"].dtype),
                (zero, start, zero, zero)),
            "v": jax.lax.dynamic_update_slice(
                cache["v"], new_v.astype(cache["v"].dtype),
                (zero, start, zero, zero)),
            "pos": jax.lax.dynamic_update_slice(
                cache["pos"], positions.astype(jnp.int32), (zero, start)),
        }
    ring = cache["k"].shape[1] - TRASH_SLOTS
    valid = positions >= 0
    slots = jnp.where(valid, positions % ring,
                      ring + (jnp.arange(t, dtype=positions.dtype) % TRASH_SLOTS)[None])
    b_idx = jnp.arange(b)[:, None]
    stored_pos = jnp.where(valid, positions, _INVALID_POS)
    return {
        "k": cache["k"].at[b_idx, slots].set(new_k.astype(cache["k"].dtype)),
        "v": cache["v"].at[b_idx, slots].set(new_v.astype(cache["v"].dtype)),
        "pos": cache["pos"].at[b_idx, slots].set(stored_pos.astype(jnp.int32)),
    }


def attention_forward(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                      positions: jnp.ndarray, *,
                      cache: Optional[Params] = None,
                      kv_source: Optional[jnp.ndarray] = None,
                      causal: bool = True,
                      window: Optional[int] = None,
                      chunk: int = DEFAULT_ATTN_CHUNK,
                      use_unrolled: bool = False,
                      tree_mask: Optional[jnp.ndarray] = None,
                      ) -> Tuple[jnp.ndarray, Optional[Params]]:
    """GQA attention.

    * ``cache`` is None: full self-attention (train / prefill / encoder).
    * ``cache`` given: writes the new kv at ``positions`` then attends over
      the cache (decode / speculative verify with T >= 1 new tokens).
    * ``kv_source`` given: cross attention (whisper decoder); kv come from
      the source sequence and no causal mask is applied.
    * ``tree_mask`` (T, T) given with ``cache``: VIRTUAL tree attention —
      the T new tokens are NOT written to the cache; each attends the cache
      prefix (position-masked) plus the tree nodes its mask row allows
      (ancestry).  Used by tree-draft verification; the engine commits the
      accepted path afterwards with a masked regular decode.

    ``cache`` may be either layout: the dense ring
    (``make_attention_cache``) or the paged block-table cache
    (``paging.make_paged_attention_cache``).  Writes and reads dispatch on
    the layout; the masking semantics are identical.
    """
    from repro.models import paging as P
    b, t, d = x.shape
    hd = cfg.head_dim
    window = cfg.sliding_window if window is None else window

    q = x @ p["wq"].astype(x.dtype)
    if cfg.use_bias:
        q = q + p["bq"].astype(x.dtype)
    q = q.reshape(b, t, cfg.n_heads, hd)

    kv_in = x if kv_source is None else kv_source
    k = kv_in @ p["wk"].astype(x.dtype)
    v = kv_in @ p["wv"].astype(x.dtype)
    if cfg.use_bias:
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    k = k.reshape(b, kv_in.shape[1], cfg.n_kv_heads, hd)
    v = v.reshape(b, kv_in.shape[1], cfg.n_kv_heads, hd)

    if cfg.use_qk_norm:
        q = _rms_head_norm(q, p["q_norm_scale"], cfg.norm_eps)
        k = _rms_head_norm(k, p["k_norm_scale"], cfg.norm_eps)

    if kv_source is None and cfg.pos_embedding == "rope":
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    # force the activation dtype BEFORE any cache scatter: otherwise XLA can
    # hoist the cast past the resharding gather and move f32 bytes (§Perf)
    k = k.astype(x.dtype)
    v = v.astype(x.dtype)

    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "kv_heads", None)

    new_cache = None
    if kv_source is not None:
        # cross attention: attend over the full source, no causality
        s = kv_source.shape[1]
        k_pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        out = blockwise_attention(q, k, v, positions, k_pos,
                                  window=0, causal=False, chunk=chunk)
    elif cache is None:
        if use_unrolled:
            out = causal_attention_unrolled(q, k, v, positions, positions,
                                            window=window, chunk=chunk)
        else:
            out = blockwise_attention(q, k, v, positions, positions,
                                      window=window, causal=causal,
                                      chunk=chunk)
    elif tree_mask is not None:
        # virtual tree attention: cache prefix partial + dense ancestry
        # block.  The cache may hold stale entries at positions >= the root
        # position (rejected drafts from earlier cycles that were never
        # overwritten), so the prefix cutoff is root_pos - 1 for every node;
        # tree-internal attention is fully described by ``tree_mask``.
        root_pos = positions[:, :1]                     # node 0 == tree root
        cache_qpos = jnp.broadcast_to(root_pos - 1, positions.shape)
        if P.is_paged(cache):
            p1 = P.paged_blockwise_attention(q, cache, cache_qpos,
                                             window=window, causal=True,
                                             chunk=chunk,
                                             return_partial=True)
        else:
            p1 = blockwise_attention(q, cache["k"], cache["v"], cache_qpos,
                                     cache["pos"], window=window, causal=True,
                                     chunk=chunk, return_partial=True)
        p2 = dense_masked_attention_partial(q, k, v, tree_mask)
        out = merge_attention_partials(p1, p2)
        out = out.reshape(b, t, cfg.n_heads, hd).astype(q.dtype)
    else:
        # write the new kv, then attend over the whole cache; prefills
        # longer than ``chunk`` scan query blocks through the same attend
        # so the score block stays (B, chunk, H, chunk)
        if P.is_paged(cache):
            # paged block-table cache: scatter through the table, gather
            # one pool block per online-softmax step.  The uniform-slots
            # fast path does not apply — the physical write location
            # differs per slot by construction.  Tables may alias shared
            # prefix blocks across slots (prefix cache); the gather is
            # oblivious to sharing and the write path never receives a
            # position inside a shared block.
            new_cache = P.paged_cache_write(cache, k, v, positions)
            # under a serving mesh the pool is partitioned on blocks (data)
            # × kv heads (model); per-shard block allocation keeps the
            # table gathers below shard-local
            att_cache = {
                **new_cache,
                "k_pool": constrain(new_cache["k_pool"],
                                    "pool_blocks", None, "kv_heads", None),
                "v_pool": constrain(new_cache["v_pool"],
                                    "pool_blocks", None, "kv_heads", None),
            }
            # quantized pool: the scale rows shard exactly like their
            # parent pool (blocks on data, KV heads on model)
            for leaf in ("k_scale", "v_scale"):
                if leaf in new_cache:
                    att_cache[leaf] = constrain(
                        new_cache[leaf], "pool_blocks", None, "kv_heads")

            def attend(qc, pc):
                return P.paged_blockwise_attention(
                    qc, att_cache, pc, window=window, causal=causal,
                    chunk=chunk)
        else:
            new_cache = _cache_write(cache, k, v, positions,
                                     uniform=cfg.cache_uniform_slots)
            ck = constrain(new_cache["k"], "batch", "kv_seq", "kv_heads",
                           None)
            cv = constrain(new_cache["v"], "batch", "kv_seq", "kv_heads",
                           None)
            cpos = new_cache["pos"]

            def attend(qc, pc):
                return blockwise_attention(qc, ck, cv, pc, cpos,
                                           window=window, causal=causal,
                                           chunk=chunk)
        out = (attend(q, positions) if t <= chunk
               else _chunked_query_attend(q, positions, attend, chunk=chunk))

    out = constrain(out, "batch", None, "heads", None)
    out = out.reshape(b, t, cfg.n_heads * hd) @ p["wo"].astype(x.dtype)
    if cfg.use_bias:
        out = out + p["bo"].astype(x.dtype)
    return out, new_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(cfg: ModelConfig, key, d_ff: Optional[int] = None) -> Params:
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    p = {
        "w1": _dense_init(k1, (d, d_ff)),
        "w2": _dense_init(k2, (d_ff, d)),
    }
    if cfg.mlp_variant == "swiglu":
        p["w3"] = _dense_init(k3, (d, d_ff))
    if cfg.use_bias:
        p["b1"] = jnp.zeros((d_ff,), jnp.float32)
        p["b2"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_mlp(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    h = x @ p["w1"].astype(x.dtype)
    if cfg.use_bias:
        h = h + p["b1"].astype(x.dtype)
    if cfg.mlp_variant == "swiglu":
        h = jax.nn.silu(h) * (x @ p["w3"].astype(x.dtype))
    else:
        h = jax.nn.gelu(h)
    h = constrain(h, "batch", None, "ff")
    out = h @ p["w2"].astype(x.dtype)
    if cfg.use_bias:
        out = out + p["b2"].astype(x.dtype)
    return out


# ---------------------------------------------------------------------------
# Mixture of Experts (capacity-based dispatch; honest active-FLOPs)
# ---------------------------------------------------------------------------

def init_moe(cfg: ModelConfig, key) -> Params:
    kr, k1, k2, k3 = jax.random.split(key, 4)
    d, ff, e = cfg.d_model, cfg.expert_d_ff, cfg.n_experts
    return {
        "router": _dense_init(kr, (d, e), scale=0.02),
        "experts_w1": _dense_init(k1, (e, d, ff)),
        "experts_w3": _dense_init(k3, (e, d, ff)),
        "experts_w2": _dense_init(k2, (e, ff, d)),
    }


def moe_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    cap = int(math.ceil(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    return max(8, -(-cap // 8) * 8)  # round up to 8 for lane alignment


def apply_moe(cfg: ModelConfig, p: Params, x: jnp.ndarray,
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Capacity-based top-k MoE with scatter dispatch.

    Compute cost is E * C * d * ff (== active FLOPs * capacity_factor) rather
    than the dense all-experts product.  Returns (output, aux_loss) where
    aux_loss is the standard load-balancing loss.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    n_tok = b * s
    xf = x.reshape(n_tok, d)

    router_logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(router_logits, axis=-1)          # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_idx, e), axis=1), axis=0) / k
    aux_loss = e * jnp.sum(me * ce)

    flat_e = expert_idx.reshape(-1)                          # (T*k,)
    flat_gate = gate_vals.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(n_tok), k)

    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)      # (T*k, E)
    pos_in_expert = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1
    cap = moe_capacity(cfg, n_tok)
    keep = pos_in_expert < cap
    slot = jnp.where(keep, pos_in_expert, cap)               # overflow -> spill row

    # dispatch: (E, C+1, d) buffer, last row is the spill bucket
    buf = jnp.zeros((e, cap + 1, d), x.dtype)
    buf = buf.at[flat_e, slot].add(xf[flat_tok])
    buf = constrain(buf, "experts", None, None)

    # expert computation (batched over E)
    w1 = p["experts_w1"].astype(x.dtype)
    w3 = p["experts_w3"].astype(x.dtype)
    w2 = p["experts_w2"].astype(x.dtype)
    h = jnp.einsum("ecd,edf->ecf", buf, w1)
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf, w3)
    h = constrain(h, "experts", None, "ff")
    out_buf = jnp.einsum("ecf,efd->ecd", h, w2)
    out_buf = constrain(out_buf, "experts", None, None)

    # combine
    gathered = out_buf[flat_e, slot]                          # (T*k, d)
    weight = (flat_gate * keep).astype(x.dtype)[:, None]
    out = jnp.zeros((n_tok, d), x.dtype).at[flat_tok].add(gathered * weight)
    return out.reshape(b, s, d), aux_loss
