"""State-space / recurrent blocks: Mamba2 (SSD) and xLSTM (mLSTM + sLSTM).

Both Mamba2's SSD and the mLSTM's exp-gated linear attention are instances of
one chunked linear recurrence

    H_t = exp(a_t) * H_{t-1} + s_t * (B_t ⊗ V_t),     y_t = C_t · H_t

with per-step log-decay ``a_t <= 0`` and input scale ``s_t``.  We implement a
single ``chunked_linear_recurrence`` core (intra-chunk masked matmul +
inter-chunk scan — the TPU-friendly SSD form: MXU matmuls inside a chunk, a
length/chunk scan across) and express both layer types through it.  Decode
steps use the O(1) recurrent update on a carried state.

Numerics note (DESIGN.md §7): the mLSTM input gate is stabilised by a running
max carried across chunks at prefill and frozen during decode, a mild
simplification of the exact xLSTM m-state that keeps the chunked form exact
w.r.t. its own definition.

Caching note: recurrent state (conv tap, SSD/mLSTM/sLSTM state) is a
FIXED-SIZE per-slot carry — it never grows with sequence length, so the
paged KV layout has nothing to page here.  Under ``--cache paged`` these
leaves stay dense exactly as built below: a hybrid model pages only its
attention sub-cache around them, and a pure-ssm model serves on the
zero-block layout (no pool, admission gated on slots only) — see
``repro.models.paging`` and docs/ARCHITECTURE.md "Paged layouts per
attention family".
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _dense_init, apply_norm
from repro.sharding import constrain

Params = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# Shared chunked linear recurrence (SSD core)
# ---------------------------------------------------------------------------

def chunked_linear_recurrence(C_, B_, V, log_decay, in_scale, *, chunk: int,
                              init_state: Optional[jnp.ndarray] = None,
                              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """All inputs chunk over the S axis.

    C_ ("query"): (B, S, H, N);  B_ ("key"): (B, S, H, N)
    V  (values) : (B, S, H, P)
    log_decay   : (B, S, H)  per-step log decay (<= 0)
    in_scale    : (B, S, H)  per-step input scale (>= 0)
    init_state  : (B, H, N, P) or None

    Returns (Y (B,S,H,P), final_state (B,H,N,P)).
    """
    b, s, h, n = B_.shape
    p = V.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        zpad = lambda x: jnp.pad(x, [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2))
        C_, B_, V = zpad(C_), zpad(B_), zpad(V)
        log_decay = zpad(log_decay)
        in_scale = zpad(in_scale)
    nc = (s + pad) // chunk

    def to_chunks(x):
        return x.reshape((b, nc, chunk) + x.shape[2:])

    Cc, Bc, Vc = to_chunks(C_), to_chunks(B_), to_chunks(V)
    ac, sc = to_chunks(log_decay), to_chunks(in_scale)

    cum = jnp.cumsum(ac, axis=2)                       # (B, nc, Q, H)
    total = cum[:, :, -1]                              # (B, nc, H)

    # ---- intra-chunk (quadratic within chunk, MXU matmuls) ----
    li = cum[:, :, :, None, :]                         # (B,nc,Q,1,H) l index
    si = cum[:, :, None, :, :]                         # (B,nc,1,Q,H) s index
    decay = jnp.exp(jnp.minimum(li - si, 0.0))         # (B,nc,Q,Q,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(mask[None, None, :, :, None], decay, 0.0)
    scores = jnp.einsum("bclhn,bcshn->bclsh", Cc, Bc,
                        preferred_element_type=jnp.float32)
    scores = scores * decay * sc[:, :, None, :, :]
    y_intra = jnp.einsum("bclsh,bcshp->bclhp", scores.astype(Vc.dtype), Vc,
                         preferred_element_type=jnp.float32)

    # ---- chunk boundary states ----
    w = jnp.exp(total[:, :, None, :] - cum) * sc       # (B,nc,Q,H)
    state_c = jnp.einsum("bcshn,bcshp->bchnp", Bc * w[..., None], Vc,
                         preferred_element_type=jnp.float32)

    # ---- inter-chunk recurrence ----
    h0 = (jnp.zeros((b, h, n, p), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(carry, xs):
        st, tot = xs                                   # (B,H,N,P), (B,H)
        new = carry * jnp.exp(tot)[:, :, None, None] + st
        return new, carry                              # emit state BEFORE chunk

    totals = jnp.moveaxis(total, 1, 0)                 # (nc, B, H)
    states = jnp.moveaxis(state_c, 1, 0)               # (nc, B, H, N, P)
    final_state, prev_states = jax.lax.scan(step, h0, (states, totals))
    prev_states = jnp.moveaxis(prev_states, 0, 1)      # (B, nc, H, N, P)

    # ---- inter-chunk contribution ----
    cdec = jnp.exp(cum)                                # decay from chunk start
    y_inter = jnp.einsum("bclhn,bchnp->bclhp",
                         (Cc * cdec[..., None]).astype(jnp.float32),
                         prev_states, preferred_element_type=jnp.float32)

    y = (y_intra + y_inter).reshape(b, nc * chunk, h, p)[:, :s]
    return y.astype(V.dtype), final_state


def recurrent_step(C_, B_, V, log_decay, in_scale, state):
    """O(1) decode update.  Shapes: C_/B_ (B,T,H,N), V (B,T,H,P) with small T
    (speculative verify windows), state (B,H,N,P).  Sequential over T."""

    def one(carry, xs):
        c_, b_, v_, a_, s_ = xs
        new = carry * jnp.exp(a_)[..., None, None] + s_[..., None, None] * (
            b_[..., :, None] * v_[..., None, :])
        y = jnp.einsum("bhn,bhnp->bhp", c_, new,
                       preferred_element_type=jnp.float32)
        return new, y

    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (C_, B_, V, log_decay, in_scale))
    state, ys = jax.lax.scan(one, state.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1).astype(V.dtype), state


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def init_mamba2(cfg: ModelConfig, key) -> Params:
    d, din, n, hd = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim
    nh = cfg.n_ssm_heads
    conv_ch = din + 2 * n
    keys = jax.random.split(key, 6)
    return {
        "in_proj": _dense_init(keys[0], (d, 2 * din + 2 * n + nh)),
        "conv_w": jnp.zeros((cfg.ssm_conv, conv_ch), jnp.float32)
        .at[-1].set(1.0),  # identity-ish init
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "dt_bias": jnp.full((nh,), math.log(math.e - 1), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "gate_norm_scale": jnp.ones((din,), jnp.float32),
        "out_proj": _dense_init(keys[1], (din, d)),
    }


def make_mamba2_cache(cfg: ModelConfig, batch: int,
                      n_layers: Optional[int] = None) -> Params:
    din, n, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    conv_ch = din + 2 * n
    conv_shape = (batch, cfg.ssm_conv - 1, conv_ch)
    state_shape = (batch, nh, n, hd)
    if n_layers is not None:
        conv_shape = (n_layers,) + conv_shape
        state_shape = (n_layers,) + state_shape
    return {
        "conv": jnp.zeros(conv_shape, jnp.float32),
        "state": jnp.zeros(state_shape, jnp.float32),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 history: Optional[jnp.ndarray] = None,
                 token_mask: Optional[jnp.ndarray] = None,
                 conv_input: Optional[jnp.ndarray] = None,
                 ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Depthwise causal conv1d.  x: (B,S,C); w: (K,C).  ``history`` carries
    the last K-1 inputs for incremental decode.

    With ``token_mask`` (valid tokens form a prefix of the window, as in
    post-verify state recompute), the new history gathers the last K-1
    *valid* inputs so rejected/padding tokens never pollute the conv state.
    """
    k = w.shape[0]
    if history is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
        new_hist = None
    else:
        xp = jnp.concatenate([history.astype(x.dtype), x], axis=1)
        if k > 1:
            if token_mask is None:
                new_hist = xp[:, -(k - 1):].astype(jnp.float32)
            else:
                n_valid = jnp.sum(token_mask.astype(jnp.int32), axis=1)  # (B,)
                # last K-1 valid entries end at hist_len + n_valid
                idx = (history.shape[1] + n_valid)[:, None] - (k - 1) \
                    + jnp.arange(k - 1)[None]
                idx = jnp.clip(idx, 0, xp.shape[1] - 1)
                new_hist = jnp.take_along_axis(
                    xp, idx[:, :, None], axis=1).astype(jnp.float32)
        else:
            new_hist = history
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    return jax.nn.silu(out + b), new_hist


def mamba2_forward(cfg: ModelConfig, p: Params, x: jnp.ndarray, *,
                   cache: Optional[Params] = None,
                   token_mask: Optional[jnp.ndarray] = None,
                   ) -> Tuple[jnp.ndarray, Optional[Params]]:
    b, s, d = x.shape
    din, n, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim

    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    zxbcdt = constrain(zxbcdt, "batch", None, "ssm_heads")
    z, xc, Bv, Cv, dt_raw = jnp.split(
        zxbcdt, [din, 2 * din, 2 * din + n, 2 * din + 2 * n], axis=-1)

    conv_in = jnp.concatenate([xc, Bv, Cv], axis=-1)
    hist = cache["conv"] if cache is not None else None
    conv_out, new_hist = _causal_conv(
        conv_in, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype), hist,
        token_mask=token_mask, conv_input=conv_in)
    xc, Bv, Cv = jnp.split(conv_out, [din, din + n], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    a = -jnp.exp(p["A_log"])                                          # (nh,)
    log_decay = dt * a[None, None, :]
    if token_mask is not None:
        # masked tokens are state no-ops: decay 1, input scale 0
        mf = token_mask.astype(jnp.float32)[:, :, None]
        dt = dt * mf
        log_decay = log_decay * mf

    xh = xc.reshape(b, s, nh, hd)
    Bh = jnp.broadcast_to(Bv[:, :, None, :], (b, s, nh, n)).astype(jnp.float32)
    Ch = jnp.broadcast_to(Cv[:, :, None, :], (b, s, nh, n)).astype(jnp.float32)

    if cache is None:
        y, _ = chunked_linear_recurrence(
            Ch, Bh, xh, log_decay, dt, chunk=cfg.ssm_chunk)
        new_cache = None
    else:
        # works for both long prefill (chunked) and 1-token decode
        y, new_state = chunked_linear_recurrence(
            Ch, Bh, xh, log_decay, dt, chunk=cfg.ssm_chunk,
            init_state=cache["state"])
        new_cache = {"conv": new_hist, "state": new_state}

    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(b, s, din)
    # gated RMS norm (mamba2 style)
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    ms = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    y = (yf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["gate_norm_scale"]).astype(x.dtype)
    out = y @ p["out_proj"].astype(x.dtype)
    return out, new_cache


# ---------------------------------------------------------------------------
# xLSTM: mLSTM block
# ---------------------------------------------------------------------------

def init_mlstm(cfg: ModelConfig, key) -> Params:
    d = cfg.d_model
    din = 2 * d                       # xLSTM projection factor 2
    nh = cfg.n_heads
    keys = jax.random.split(key, 8)
    return {
        "up_proj": _dense_init(keys[0], (d, 2 * din)),
        "wq": _dense_init(keys[1], (din, din)),
        "wk": _dense_init(keys[2], (din, din)),
        "wv": _dense_init(keys[3], (din, din)),
        "igate_w": _dense_init(keys[4], (din, nh), scale=0.02),
        "igate_b": jnp.zeros((nh,), jnp.float32),
        "fgate_w": _dense_init(keys[5], (din, nh), scale=0.02),
        "fgate_b": jnp.full((nh,), 3.0, jnp.float32),  # open forget gates
        "mlstm_norm_scale": jnp.ones((din,), jnp.float32),
        "down_proj": _dense_init(keys[6], (din, d)),
    }


def make_mlstm_cache(cfg: ModelConfig, batch: int,
                     n_layers: Optional[int] = None) -> Params:
    din = 2 * cfg.d_model
    nh = cfg.n_heads
    dk = din // nh
    # state holds numerator (N x P) with value dim extended by 1 for the
    # normaliser column
    shape = (batch, nh, dk, dk + 1)
    mshape = (batch, nh)
    if n_layers is not None:
        shape = (n_layers,) + shape
        mshape = (n_layers,) + mshape
    return {"state": jnp.zeros(shape, jnp.float32),
            "m": jnp.zeros(mshape, jnp.float32)}


def mlstm_forward(cfg: ModelConfig, p: Params, x: jnp.ndarray, *,
                  cache: Optional[Params] = None,
                  token_mask: Optional[jnp.ndarray] = None,
                  ) -> Tuple[jnp.ndarray, Optional[Params]]:
    b, s, d = x.shape
    din = 2 * d
    nh = cfg.n_heads
    dk = din // nh

    up = x @ p["up_proj"].astype(x.dtype)
    xi, z = jnp.split(up, 2, axis=-1)
    xi = constrain(xi, "batch", None, "ssm_heads")

    q = (xi @ p["wq"].astype(x.dtype)).reshape(b, s, nh, dk)
    k = (xi @ p["wk"].astype(x.dtype)).reshape(b, s, nh, dk) / math.sqrt(dk)
    v = (xi @ p["wv"].astype(x.dtype)).reshape(b, s, nh, dk)

    i_raw = (xi.astype(jnp.float32) @ p["igate_w"]) + p["igate_b"]   # (B,S,H)
    f_raw = (xi.astype(jnp.float32) @ p["fgate_w"]) + p["fgate_b"]
    log_f = jax.nn.log_sigmoid(f_raw)

    i_eff = i_raw
    if token_mask is not None:
        mf = token_mask.astype(jnp.float32)[:, :, None]
        log_f = log_f * mf
        i_eff = jnp.where(token_mask[:, :, None], i_raw, -jnp.inf)

    if cache is None:
        m = jnp.max(i_eff, axis=1, keepdims=True)                    # (B,1,H)
        new_m = m[:, 0]
    else:
        m = jnp.maximum(cache["m"][:, None, :], jnp.max(i_eff, axis=1, keepdims=True))
        new_m = m[:, 0]
    in_scale = jnp.exp(i_eff - m)
    if token_mask is not None:
        in_scale = jnp.where(token_mask[:, :, None], in_scale, 0.0)

    v_ext = jnp.concatenate(
        [v, jnp.ones(v.shape[:-1] + (1,), v.dtype)], axis=-1)

    if cache is None:
        y_ext, final_state = chunked_linear_recurrence(
            q.astype(jnp.float32), k.astype(jnp.float32), v_ext,
            log_f, in_scale, chunk=cfg.ssm_chunk)
        new_cache = None
    else:
        y_ext, final_state = chunked_linear_recurrence(
            q.astype(jnp.float32), k.astype(jnp.float32), v_ext,
            log_f, in_scale, chunk=cfg.ssm_chunk,
            init_state=cache["state"])
        new_cache = {"state": final_state, "m": new_m}

    num, den = y_ext[..., :dk], y_ext[..., dk:]
    y = num / jnp.maximum(jnp.abs(den), 1e-6)
    y = y.reshape(b, s, din)

    yf = y.astype(jnp.float32)
    ms = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    y = (yf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["mlstm_norm_scale"]).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["down_proj"].astype(x.dtype)
    return out, new_cache


# ---------------------------------------------------------------------------
# xLSTM: sLSTM block (scalar memory, sequential scan)
# ---------------------------------------------------------------------------

def init_slstm(cfg: ModelConfig, key) -> Params:
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    keys = jax.random.split(key, 10)
    p = {}
    for i, gate in enumerate(("zgate", "igate", "fgate", "ogate")):
        p[f"{gate}_w"] = _dense_init(keys[i], (d, d))
        p[f"{gate}_r"] = _dense_init(keys[4 + i], (nh, dh, dh),
                                     scale=1.0 / math.sqrt(dh))
        p[f"{gate}_b"] = jnp.zeros((d,), jnp.float32)
    p["fgate_b"] = jnp.full((d,), 3.0, jnp.float32)
    p["slstm_norm_scale"] = jnp.ones((d,), jnp.float32)
    ff = int(8 * d / 3 / 64) * 64
    p["ffn_w1"] = _dense_init(keys[8], (d, ff))
    p["ffn_w3"] = _dense_init(keys[8], (d, ff))
    p["ffn_w2"] = _dense_init(keys[9], (ff, d))
    return p


def make_slstm_cache(cfg: ModelConfig, batch: int,
                     n_layers: Optional[int] = None) -> Params:
    d = cfg.d_model
    shape = (batch, d)
    if n_layers is not None:
        shape = (n_layers,) + shape
    z = jnp.zeros(shape, jnp.float32)
    return {"c": z, "n": z, "h": z, "m": z - 10.0}


def slstm_forward(cfg: ModelConfig, p: Params, x: jnp.ndarray, *,
                  cache: Optional[Params] = None,
                  token_mask: Optional[jnp.ndarray] = None,
                  ) -> Tuple[jnp.ndarray, Optional[Params]]:
    b, s, d = x.shape
    nh = cfg.n_heads
    dh = d // nh

    pre = {g: x.astype(jnp.float32) @ p[f"{g}_w"] + p[f"{g}_b"]
           for g in ("zgate", "igate", "fgate", "ogate")}

    if cache is None:
        c0 = jnp.zeros((b, d), jnp.float32)
        n0 = jnp.zeros((b, d), jnp.float32)
        h0 = jnp.zeros((b, d), jnp.float32)
        m0 = jnp.zeros((b, d), jnp.float32) - 10.0
    else:
        c0, n0, h0, m0 = cache["c"], cache["n"], cache["h"], cache["m"]

    def rec(hprev, gate):
        hh = hprev.reshape(b, nh, dh)
        return jnp.einsum("bhd,hde->bhe", hh, p[f"{gate}_r"]).reshape(b, d)

    def step(carry, xs):
        c, n, h, m = carry
        zp, ip, fp, op, valid = xs
        zt = jnp.tanh(zp + rec(h, "zgate"))
        it = ip + rec(h, "igate")
        ft = fp + rec(h, "fgate")
        ot = jax.nn.sigmoid(op + rec(h, "ogate"))
        log_f = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(log_f + m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(log_f + m - m_new)
        c_new = f_p * c + i_p * zt
        n_new = f_p * n + i_p
        h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
        keep = valid[:, None]
        new_carry = (jnp.where(keep, c_new, c), jnp.where(keep, n_new, n),
                     jnp.where(keep, h_new, h), jnp.where(keep, m_new, m))
        return new_carry, h_new

    valid_seq = (jnp.ones((b, s), bool) if token_mask is None else token_mask)
    xs = tuple(jnp.moveaxis(pre[g], 1, 0)
               for g in ("zgate", "igate", "fgate", "ogate"))
    xs = xs + (jnp.moveaxis(valid_seq, 1, 0),)
    (c, n, h, m), hs = jax.lax.scan(step, (c0, n0, h0, m0), xs)
    y = jnp.moveaxis(hs, 0, 1)                       # (B,S,d) float32

    ms = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(ms + cfg.norm_eps) * p["slstm_norm_scale"]).astype(x.dtype)

    # gated FFN (xLSTM post-up-projection)
    hmid = jax.nn.silu(y @ p["ffn_w1"].astype(x.dtype)) * (y @ p["ffn_w3"].astype(x.dtype))
    hmid = constrain(hmid, "batch", None, "ff")
    y = y + hmid @ p["ffn_w2"].astype(x.dtype)

    new_cache = {"c": c, "n": n, "h": h, "m": m} if cache is not None else None
    return y, new_cache
