"""granite-8b — llama-arch dense code model. [arXiv:2405.04324]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    source="arXiv:2405.04324",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=49152,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab_size=259,
        tie_embeddings=True,
    )
