"""dbrx-132b — 16-expert top-4 fine-grained MoE. [hf:databricks/dbrx-base]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    source="hf:databricks/dbrx-base",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    n_experts=16,
    top_k=4,
    expert_d_ff=10752,
    capacity_factor=1.25,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-smoke",
        family="moe",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab_size=259,
        n_experts=4,
        top_k=2,
        expert_d_ff=256,
        capacity_factor=1.25,
    )
