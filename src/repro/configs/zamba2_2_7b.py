"""zamba2-2.7b — Mamba2 backbone + shared attention blocks. [arXiv:2411.15242]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    source="arXiv:2411.15242",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_head_dim=64,
    ssm_chunk=256,
    hybrid_attn_every=6,      # shared attention block every 6 mamba layers
    sliding_window=4096,      # shared-attn block uses a window; long_500k native
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke",
        family="hybrid",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=256,
        vocab_size=259,
        ssm_state=16,
        ssm_expand=2,
        ssm_conv=4,
        ssm_head_dim=32,
        ssm_chunk=32,
        hybrid_attn_every=2,
        sliding_window=64,
    )
