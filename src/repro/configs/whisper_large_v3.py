"""whisper-large-v3 — enc-dec audio; conv/mel frontend stubbed. [arXiv:2212.04356]

The assigned entry specifies the TRANSFORMER BACKBONE; the mel-spectrogram +
conv feature extractor is a stub — ``input_specs`` feeds (B, 1500, d_model)
precomputed frame embeddings to the encoder.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    source="arXiv:2212.04356",
    n_layers=32,             # decoder layers
    n_encoder_layers=32,
    encoder_seq_len=1500,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    mlp_variant="gelu",
    norm_variant="layernorm",
    use_bias=True,
    pos_embedding="learned",
    sliding_window=4096,     # enables long_500k decode lowering (artificial for
                             # whisper — documented in DESIGN.md)
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke",
        family="audio",
        n_layers=2,
        n_encoder_layers=2,
        encoder_seq_len=32,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=256,
        vocab_size=259,
        mlp_variant="gelu",
        norm_variant="layernorm",
        use_bias=True,
        pos_embedding="learned",
        sliding_window=64,
    )
