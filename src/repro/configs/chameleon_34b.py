"""chameleon-34b — early-fusion VLM, VQ image tokens in the text vocab.
[arXiv:2405.09818]

The vision tokenizer (VQ-GAN) is stubbed: ``input_specs`` feeds mixed
text+image token ids; image tokens occupy [image_token_start,
image_token_start + n_image_tokens).  The backbone is a dense GQA decoder
with qk-norm (chameleon's logit-drift fix).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    source="arXiv:2405.09818",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    use_qk_norm=True,
    image_token_start=4,
    n_image_tokens=8192,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-smoke",
        family="vlm",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab_size=259,
        use_qk_norm=True,
        image_token_start=4,
        n_image_tokens=64,
    )
