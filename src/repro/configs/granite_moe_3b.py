"""granite-moe-3b-a800m — 40-expert top-8 fine-grained MoE.
[hf:ibm-granite/granite-3.0-1b-a400m-base family]

Note: 40 experts do not divide the 16-way model axis; experts are replicated
and tokens stay data-parallel (see DESIGN.md §5 sharding exception).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    n_experts=40,
    top_k=8,
    expert_d_ff=512,
    capacity_factor=1.25,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-smoke",
        family="moe",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=64,
        vocab_size=259,
        n_experts=4,
        top_k=2,
        expert_d_ff=64,
        capacity_factor=1.25,
        tie_embeddings=True,
    )
