"""chatglm3-6b — RoPE on half head-dim ("2d rope"), GQA kv=2. [arXiv:2406.12793]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    source="arXiv:2406.12793",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    rope_fraction=0.5,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab_size=259,
        rope_fraction=0.5,
    )
