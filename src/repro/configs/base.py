"""Base model configuration for all assigned architectures.

A single dataclass covers the 6 architecture families (dense, moe, hybrid,
ssm, audio, vlm).  Family-specific fields are ignored by families that do
not use them.  Every assigned architecture file instantiates ``ModelConfig``
with the exact published numbers and provides ``smoke_config()`` — a reduced
variant of the same family used by CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # --- identity -------------------------------------------------------
    name: str = "model"
    family: str = "dense"  # dense | moe | hybrid | ssm | audio | vlm
    source: str = ""       # citation for the config numbers

    # --- core transformer ------------------------------------------------
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0          # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 259
    max_seq_len: int = 1 << 20
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0   # chatglm applies rope to half the head dim
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    use_qk_norm: bool = False    # chameleon stabilises with qk-norm
    mlp_variant: str = "swiglu"  # swiglu | gelu (whisper)
    use_bias: bool = False
    norm_variant: str = "rmsnorm"  # rmsnorm | layernorm
    pos_embedding: str = "rope"    # rope | learned | none

    # --- attention variants ----------------------------------------------
    sliding_window: int = 0      # 0 = full attention; >0 = ring-buffer window
    attn_logit_softcap: float = 0.0
    # parallel attention+FFN residual (PaLM/GPT-J): halves the per-layer TP
    # all-reduce count; §Perf serving variant, off for the faithful configs
    parallel_residual: bool = False
    # uniform-batch cache writes via dynamic_update_slice instead of the
    # per-row scatter (valid when all rows share the same write index, e.g.
    # the dry-run serve_step); avoids broadcast-gathers of the kv updates
    cache_uniform_slots: bool = False

    # --- MoE --------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0         # 0 -> d_ff
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    moe_every: int = 1           # apply MoE every n-th layer (1 = all)

    # --- SSM / Mamba2 ------------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    ssm_chunk: int = 256

    # --- hybrid (zamba2-style shared attention block) ----------------------
    hybrid_attn_every: int = 0   # insert shared attn block every n ssm layers

    # --- xLSTM --------------------------------------------------------------
    slstm_every: int = 0         # one sLSTM per n blocks (rest mLSTM)

    # --- encoder-decoder (whisper) ------------------------------------------
    n_encoder_layers: int = 0
    encoder_seq_len: int = 0     # stub frontend emits this many frames

    # --- vlm (chameleon) ------------------------------------------------------
    image_token_start: int = 0   # first vocab id reserved for VQ image tokens
    n_image_tokens: int = 0

    # --- numerics ---------------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        if self.n_experts and not self.expert_d_ff:
            object.__setattr__(self, "expert_d_ff", self.d_ff)

    # ------------------------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Approximate parameter count (used for roofline MODEL_FLOPS)."""
        d, v = self.d_model, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb
        kvd = self.n_kv_heads * self.head_dim
        qd = self.n_heads * self.head_dim
        attn = d * qd + 2 * d * kvd + qd * d
        if self.family in ("dense", "vlm"):
            per = attn + 3 * d * self.d_ff
            total += self.n_layers * per
        elif self.family == "moe":
            per = attn + self.n_experts * 3 * d * self.expert_d_ff + d * self.n_experts
            total += self.n_layers * per
        elif self.family == "hybrid":
            din = self.d_inner
            ssm_per = d * (2 * din + 2 * self.ssm_state + self.n_ssm_heads) + din * d
            n_shared = 1
            total += self.n_layers * ssm_per + n_shared * (attn + 3 * d * self.d_ff)
        elif self.family == "ssm":
            # mLSTM block: qkv + gates + out + ffn-ish up/down (d_ff==0 means
            # the block carries its own expansion)
            dk = d
            per = 4 * d * dk + 2 * d * self.n_heads + dk * d + 4 * d * d
            total += self.n_layers * per
        elif self.family == "audio":
            per = attn + 3 * d * self.d_ff
            cross = d * qd + 2 * d * kvd + qd * d
            total += self.n_encoder_layers * per + self.n_layers * (per + cross)
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE uses top_k of n_experts)."""
        if self.family != "moe" or not self.n_experts:
            return self.param_count()
        d = self.d_model
        dense_share = self.param_count() - self.n_layers * self.n_experts * 3 * d * self.expert_d_ff
        return dense_share + self.n_layers * self.top_k * 3 * d * self.expert_d_ff


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input shapes."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)


def get_shape(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}; have {[s.name for s in SHAPES]}")
