"""deepseek-67b — llama-arch dense, 95 layers. [arXiv:2401.02954]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    source="arXiv:2401.02954",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=102400,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab_size=259,
    )
