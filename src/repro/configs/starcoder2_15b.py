"""starcoder2-15b — GQA kv=4, RoPE. [arXiv:2402.19173]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    source="arXiv:2402.19173",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    norm_variant="layernorm",
    use_bias=True,
    mlp_variant="gelu",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab_size=259,
        norm_variant="layernorm",
        use_bias=True,
        mlp_variant="gelu",
    )
