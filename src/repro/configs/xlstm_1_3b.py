"""xlstm-1.3b — sLSTM + mLSTM blocks (7:1 mLSTM:sLSTM). [arXiv:2405.04517]

d_ff=0 per the assignment: blocks carry their own expansion (mLSTM uses a
projection expansion of 2, sLSTM a gated ffn of 4/3*2).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    source="arXiv:2405.04517",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    slstm_every=8,     # one sLSTM per 8 blocks -> 7:1
    ssm_chunk=256,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-smoke",
        family="ssm",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=259,
        slstm_every=2,
        ssm_chunk=32,
    )
