"""Architecture config registry.

``get_config(arch)`` returns the full assigned config; ``get_smoke(arch)``
returns the reduced same-family variant used in CPU smoke tests.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, get_shape

_ARCH_MODULES: Dict[str, str] = {
    "zamba2-2.7b": "zamba2_2_7b",
    "dbrx-132b": "dbrx_132b",
    "chatglm3-6b": "chatglm3_6b",
    "deepseek-67b": "deepseek_67b",
    "starcoder2-15b": "starcoder2_15b",
    "granite-8b": "granite_8b",
    "whisper-large-v3": "whisper_large_v3",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "chameleon-34b": "chameleon_34b",
    "xlstm-1.3b": "xlstm_1_3b",
}


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def _module(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; have {list_archs()}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke(arch: str) -> ModelConfig:
    return _module(arch).smoke_config()


__all__ = [
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "get_shape",
    "get_config",
    "get_smoke",
    "list_archs",
]
