from repro.sharding.rules import (
    AxisRules,
    axis_rules,
    constrain,
    current_rules,
    param_specs,
    batch_axes,
)

__all__ = [
    "AxisRules",
    "axis_rules",
    "constrain",
    "current_rules",
    "param_specs",
    "batch_axes",
]
