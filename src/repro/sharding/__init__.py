from repro.sharding.rules import (
    AxisRules,
    axis_rules,
    constrain,
    current_mesh,
    current_rules,
    param_specs,
    sanitize_spec,
    serving_rules,
    batch_axes,
)

__all__ = [
    "AxisRules",
    "axis_rules",
    "constrain",
    "current_mesh",
    "current_rules",
    "param_specs",
    "sanitize_spec",
    "serving_rules",
    "batch_axes",
]
