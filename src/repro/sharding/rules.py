"""Logical-axis sharding rules.

Model code annotates tensors with *logical* axis names via ``constrain``.
The launcher activates an ``AxisRules`` mapping logical names to mesh axes
(or None).  Outside any rules context ``constrain`` is a no-op, so smoke
tests and benchmarks run on one device untouched.

Logical axes used across the codebase:

  batch      global batch                    -> ("pod", "data") / ("data",)
  seq        sequence (activations)          -> None (or "model" for long KV)
  kv_seq     KV-cache length (full attn)     -> "model" on decode shapes
  heads      attention heads / q-projection  -> "model"
  kv_heads   kv heads (replicated if few)    -> None or "model"
  ff         MLP hidden                      -> "model"
  experts    MoE expert dim                  -> "model" (when divisible)
  vocab      vocabulary                      -> "model"
  embed      d_model residual dim            -> None
  ssm_heads  mamba2/xlstm head dim           -> "model"
  pool_blocks paged-KV physical block dim    -> "data" (serving mesh)

Mesh-aware mode: ``axis_rules(rules, mesh=mesh)`` additionally records the
mesh, which lets ``constrain`` (and the spec builders) *sanitise* specs —
any mapping whose mesh-axis product does not divide the tensor dim is
dropped for that dim instead of erroring (GSPMD silently replicates uneven
``with_sharding_constraint`` specs wholesale; ``device_put`` rejects them).
That is what lets one serving rule set cover targets AND tiny drafters
whose head/vocab counts do not divide the model axis.
"""
from __future__ import annotations

import contextlib
import contextvars
import math
import re
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisRules = Dict[str, Union[str, Tuple[str, ...], None]]

_RULES: contextvars.ContextVar[Optional[AxisRules]] = contextvars.ContextVar(
    "repro_axis_rules", default=None
)
_MESH: contextvars.ContextVar[Optional[Mesh]] = contextvars.ContextVar(
    "repro_axis_mesh", default=None
)


def current_rules() -> Optional[AxisRules]:
    return _RULES.get()


def current_mesh() -> Optional[Mesh]:
    return _MESH.get()


@contextlib.contextmanager
def axis_rules(rules: AxisRules, mesh: Optional[Mesh] = None):
    token = _RULES.set(rules)
    m_token = _MESH.set(mesh)
    try:
        yield
    finally:
        _RULES.reset(token)
        _MESH.reset(m_token)


def _axis_size(mesh: Mesh, entry) -> int:
    """Mesh-device product of one spec entry (axis name or tuple)."""
    if entry is None:
        return 1
    names = entry if isinstance(entry, tuple) else (entry,)
    return int(math.prod(mesh.shape[n] for n in names))


def sanitize_spec(spec: P, shape: Sequence[int], mesh: Mesh) -> P:
    """Drop per-dim mappings that do not divide the dim (see module doc)."""
    out = []
    entries = tuple(spec) + (None,) * (len(shape) - len(spec))
    for dim, entry in zip(shape, entries):
        ok = entry is not None and dim % _axis_size(mesh, entry) == 0
        out.append(entry if ok else None)
    return P(*out)


def single_pod_rules(*, shard_kv_seq: bool = False) -> AxisRules:
    return {
        "batch": ("data",),
        "seq": None,
        "kv_seq": "model" if shard_kv_seq else None,
        "heads": "model",
        "kv_heads": None,
        "ff": "model",
        "experts": "model",
        "vocab": "model",
        "embed": None,
        "fsdp": None,
        "fsdp_head": None,
        "ssm_heads": "model",
    }


def multi_pod_rules(*, shard_kv_seq: bool = False) -> AxisRules:
    rules = single_pod_rules(shard_kv_seq=shard_kv_seq)
    rules["batch"] = ("pod", "data")
    return rules


def serving_rules() -> AxisRules:
    """Rules for the mesh-partitioned serving tick (``launch.mesh
    .make_serving_mesh``): slot-indexed carry state on ``data``, tensor
    parallelism for the target/drafter on ``model``, and the paged KV pool
    partitioned under both (physical blocks on ``data``, KV heads on
    ``model``).  ``kv_seq`` stays unsharded — a slot's KV ring lives whole
    on the data shard that owns the slot."""
    return {
        "batch": "data",
        "seq": None,
        "kv_seq": None,
        "heads": "model",
        "kv_heads": "model",
        "ff": "model",
        "experts": "model",
        "vocab": "model",
        "embed": None,
        "fsdp": None,
        "fsdp_head": None,
        "ssm_heads": "model",
        "pool_blocks": "data",
    }


def resolve(*logical: Optional[str]) -> P:
    rules = current_rules()
    if rules is None:
        return P()
    return P(*[rules.get(name) if name else None for name in logical])


def constrain(x, *logical: Optional[str]):
    """Annotate ``x`` with the mesh axes the active rules map to.

    Under a mesh-carrying rules context (``axis_rules(rules, mesh=...)``)
    the spec is sanitised per-dim against the tensor shape and applied as a
    :class:`NamedSharding` (usable inside ``jit`` without an ambient mesh);
    otherwise the bare :class:`PartitionSpec` path is kept for the ambient
    ``with Mesh:`` callers (dry-run / train)."""
    rules = current_rules()
    if rules is None:
        return x
    spec = resolve(*logical)
    mesh = current_mesh()
    if mesh is not None:
        spec = sanitize_spec(spec, x.shape, mesh)
        if all(s is None for s in spec):
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def batch_axes() -> Union[str, Tuple[str, ...], None]:
    rules = current_rules()
    if rules is None:
        return None
    return rules.get("batch")


# ---------------------------------------------------------------------------
# Parameter partition specs, resolved by parameter path name.
# ---------------------------------------------------------------------------

# Patterns are matched against "/"-joined param paths.  Each entry maps to a
# tuple of logical axis names per tensor dim.  A leading layer-stacking dim
# (from scan-stacked blocks) is detected by rank and padded with None.
_PARAM_PATTERNS: Sequence[Tuple[str, Tuple[Optional[str], ...]]] = (
    # the vocab-adjacent matrices get their own fsdp knob ("fsdp_head"):
    # sharding their d_model dim over data makes the head matmul emit
    # partial-sum logits all-reduced over data — a huge collective (§Perf)
    (r".*embedding$", ("vocab", "fsdp_head")),
    (r".*pos_embedding$", (None, "fsdp_head")),
    (r".*lm_head$", ("fsdp_head", "vocab")),
    (r".*(wq|wqkv)$", ("fsdp", "heads")),
    (r".*(wk|wv)$", ("fsdp", "kv_heads")),
    (r".*wo$", ("heads", "fsdp")),
    (r".*(w1|w3)$", ("fsdp", "ff")),
    (r".*w2$", ("ff", "fsdp")),
    (r".*router$", ("fsdp", "experts")),
    (r".*experts_w[13]$", ("experts", "fsdp", "ff")),
    (r".*experts_w2$", ("experts", "ff", "fsdp")),
    (r".*(in_proj|up_proj)$", ("fsdp", "ssm_heads")),
    (r".*(out_proj|down_proj)$", ("ssm_heads", "fsdp")),
    (r".*ffn_w[13]$", ("fsdp", "ff")),
    (r".*ffn_w2$", ("ff", "fsdp")),
    (r".*(conv_w)$", (None, "ssm_heads")),
    (r".*(A_log|dt_bias|D)$", ("ssm_heads",)),
    # mLSTM wq/wk/wv match the attention (wq|wk|wv) patterns above; their
    # flat output dim shards on "heads" -> model, which is what we want.
    (r".*(norm|scale|bias|gamma|beta|qk_norm).*", None),  # replicate norms
)


def _spec_for_path(path: str, ndim: int) -> P:
    rules = current_rules() or {}
    for pat, axes in _PARAM_PATTERNS:
        if re.match(pat, path):
            if axes is None:
                return P()
            resolved = [rules.get(a) if a else None for a in axes]
            # a 1-tuple mesh mapping (e.g. ("data",)) is the same sharding
            # as the bare axis name; normalise so specs compare cleanly
            resolved = [a[0] if isinstance(a, tuple) and len(a) == 1 else a
                        for a in resolved]
            # pad leading dims (layer stacking) with None
            pad = [None] * (ndim - len(resolved))
            if ndim < len(resolved):
                # e.g. tied weights reused at lower rank; trim from the left
                resolved = resolved[len(resolved) - ndim:]
                pad = []
            return P(*pad, *resolved)
    return P()


def param_specs(params, *, mesh: Optional[Mesh] = None,
                ) -> "jax.tree_util.PyTreeDef":
    """Build a PartitionSpec pytree mirroring ``params`` by path matching.

    With ``mesh`` the specs are additionally sanitised per-dim against the
    leaf shapes (non-dividing mappings dropped) so the result is directly
    usable for ``device_put``/``in_shardings``, which reject uneven
    shardings."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        name = "/".join(
            getattr(k, "key", getattr(k, "name", str(k))) for k in path
        )
        spec = _spec_for_path(name, leaf.ndim)
        if mesh is not None:
            spec = sanitize_spec(spec, leaf.shape, mesh)
        specs.append(spec)
    return jax.tree_util.tree_unflatten(treedef, specs)
