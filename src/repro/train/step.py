"""Training step: next-token cross entropy (+ MoE aux loss), grads, AdamW.

``make_train_step`` builds the jit/pjit-able step used both by the CPU
trainer (tiny target/draft pairs for the paper-validation benchmarks) and by
the ``train_4k`` multi-pod dry-run shape.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.optim import adamw, apply_updates
from repro.optim.adamw import Transform, global_norm


def loss_fn(model: Model, params, batch: Dict[str, jnp.ndarray], *,
            remat: bool = False, unrolled_attn: bool = False,
            remat_policy=None,
            aux_weight: float = 0.01) -> Tuple[jnp.ndarray, Dict]:
    tokens = batch["tokens"]
    inputs = {"tokens": tokens[:, :-1]}
    if "encoder_frames" in batch:
        inputs["encoder_frames"] = batch["encoder_frames"]
    labels = tokens[:, 1:]
    logits, aux = model.forward(params, inputs, remat=remat,
                                unrolled_attn=unrolled_attn,
                                remat_policy=remat_policy)
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels != 0).astype(jnp.float32)   # PAD = 0
    ntok = jnp.maximum(mask.sum(), 1.0)
    ce = jnp.sum(nll * mask) / ntok
    loss = ce + aux_weight * aux
    return loss, {"ce": ce, "aux": aux,
                  "ppl": jnp.exp(jnp.minimum(ce, 20.0))}


def make_train_step(model: Model, tx: Transform, *, remat: bool = False,
                    unrolled_attn: bool = False,
                    remat_policy=None) -> Callable:
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(model, p, batch, remat=remat,
                              unrolled_attn=unrolled_attn,
                              remat_policy=remat_policy),
            has_aux=True)(params)
        updates, opt_state = tx.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = dict(metrics, loss=loss, grad_norm=global_norm(grads))
        return params, opt_state, metrics

    return train_step
