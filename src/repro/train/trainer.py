"""Minimal trainer loop with checkpointing + logging.

Used by the examples to train the tiny target/draft pairs that power the
paper-validation benchmarks (τ, θ-sweep, quality preservation)."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.models.model import Model
from repro.optim import adamw
from repro.optim.schedule import cosine_schedule
from repro.train.step import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    lr: float = 3e-3
    warmup_steps: int = 20
    total_steps: int = 300
    weight_decay: float = 0.1
    log_every: int = 25
    ckpt_every: int = 0
    ckpt_dir: str = "/tmp/repro_ckpt"
    remat: bool = False


class Trainer:
    def __init__(self, model: Model, cfg: TrainerConfig):
        self.model = model
        self.cfg = cfg
        self.tx = adamw(
            cosine_schedule(cfg.lr, cfg.warmup_steps, cfg.total_steps),
            weight_decay=cfg.weight_decay)
        self.step_fn = jax.jit(make_train_step(model, self.tx,
                                               remat=cfg.remat))

    def fit(self, params, batches: Iterator[Dict[str, np.ndarray]],
            *, log: Callable[[str], None] = print):
        opt_state = self.tx.init(params)
        t0 = time.time()
        history = []
        for step, batch in enumerate(batches, start=1):
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt_state, m = self.step_fn(params, opt_state, batch)
            if step % self.cfg.log_every == 0 or step == 1:
                m = {k: float(v) for k, v in m.items()}
                history.append({"step": step, **m})
                log(f"step {step:5d} loss {m['loss']:.4f} "
                    f"ppl {m['ppl']:.2f} gnorm {m['grad_norm']:.2f} "
                    f"({time.time() - t0:.1f}s)")
            if self.cfg.ckpt_every and step % self.cfg.ckpt_every == 0:
                save_checkpoint(self.cfg.ckpt_dir, step, params)
            if step >= self.cfg.total_steps:
                break
        return params, history
