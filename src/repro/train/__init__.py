from repro.train.step import loss_fn, make_train_step
from repro.train.trainer import Trainer, TrainerConfig

__all__ = ["loss_fn", "make_train_step", "Trainer", "TrainerConfig"]
