"""Fused MARS verification kernel.

For each draft position (row) the kernel streams the vocab axis through VMEM
in lane-aligned blocks, keeping a running top-2 (value, index) in registers,
and on the final block emits the accept decision:

    accept_exact = draft == top1
    relax        = draft == top2  and  z2 > theta * z1  and  z1 > 0, z2 > 0

One HBM pass over the logits, no full sort / top-k materialisation — this is
the TPU-native shape of the paper's Algorithm 1 (DESIGN.md §3).

Grid: (rows / BT, V / BV), vocab axis innermost so the running top-2 output
refs are revisited ("arbitrary" dimension semantics).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params as _compiler_params

NEG = -1e30


def _block_top2(blk: jnp.ndarray, col0: jnp.ndarray):
    """Top-2 values + global indices within a (BT, BV) block."""
    bt, bv = blk.shape
    idx1 = jnp.argmax(blk, axis=1)                              # (BT,)
    v1 = jnp.max(blk, axis=1)
    cols = jax.lax.broadcasted_iota(jnp.int32, (bt, bv), 1)
    masked = jnp.where(cols == idx1[:, None], NEG, blk)
    idx2 = jnp.argmax(masked, axis=1)
    v2 = jnp.max(masked, axis=1)
    return v1, col0 + idx1.astype(jnp.int32), v2, col0 + idx2.astype(jnp.int32)


def _merge_top2(z1, i1, z2, i2, b1, j1, b2, j2):
    """Merge running top-2 (z1,i1,z2,i2) with a block's (b1,j1,b2,j2)."""
    # candidates: the four values; result top1 = max(z1, b1)
    take_b = b1 > z1
    n1 = jnp.where(take_b, b1, z1)
    ni1 = jnp.where(take_b, j1, i1)
    # runner-up = max(min(z1, b1), max(z2, b2))
    lo = jnp.where(take_b, z1, b1)
    lo_i = jnp.where(take_b, i1, j1)
    hi2 = jnp.where(z2 > b2, z2, b2)
    hi2_i = jnp.where(z2 > b2, i2, j2)
    take_lo = lo > hi2
    n2 = jnp.where(take_lo, lo, hi2)
    ni2 = jnp.where(take_lo, lo_i, hi2_i)
    return n1, ni1, n2, ni2


def _kernel(draft_ref, logits_ref, theta_ref,
            z1_ref, i1_ref, z2_ref, i2_ref, exact_ref, relax_ref,
            *, bv: int, n_vblocks: int):
    vb = pl.program_id(1)

    @pl.when(vb == 0)
    def _init():
        z1_ref[...] = jnp.full_like(z1_ref, NEG)
        z2_ref[...] = jnp.full_like(z2_ref, NEG)
        i1_ref[...] = jnp.zeros_like(i1_ref)
        i2_ref[...] = jnp.zeros_like(i2_ref)

    blk = logits_ref[...].astype(jnp.float32)                    # (BT, BV)
    col0 = vb * bv
    b1, j1, b2, j2 = _block_top2(blk, col0)
    z1, i1, z2, i2 = _merge_top2(
        z1_ref[...], i1_ref[...], z2_ref[...], i2_ref[...], b1, j1, b2, j2)
    z1_ref[...], i1_ref[...], z2_ref[...], i2_ref[...] = z1, i1, z2, i2

    @pl.when(vb == n_vblocks - 1)
    def _finish():
        draft = draft_ref[...]
        theta = theta_ref[...]                   # (BT,) per-row threshold
        exact_ref[...] = (draft == i1).astype(jnp.int32)
        pos_ok = (z1 > 0.0) & (z2 > 0.0)
        relax_ref[...] = ((draft == i2) & pos_ok
                          & (z2 > theta * z1)).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "block_vocab", "interpret"))
def mars_verify_kernel(draft_tokens: jnp.ndarray, logits: jnp.ndarray,
                       theta, *, block_rows: int = 8,
                       block_vocab: int = 2048, interpret: bool = False):
    """draft_tokens: (T,) int32; logits: (T, V); theta: scalar or (T,) —
    a per-row threshold rides the grid like the draft tokens, so mixed
    per-slot thetas verify in the same fused pass.

    Returns (exact, relax, top1, top2, z1, z2) — all (T,)."""
    t, v = logits.shape
    bt = min(block_rows, t)
    bv = min(block_vocab, v)
    # pad so grid divides evenly; padded logits are NEG so never win top-2
    tp = -(-t // bt) * bt
    vp = -(-v // bv) * bv
    theta_arr = jnp.broadcast_to(
        jnp.asarray(theta, jnp.float32), (t,))
    if (tp, vp) != (t, v):
        logits = jnp.pad(logits, ((0, tp - t), (0, vp - v)),
                         constant_values=NEG)
        draft_tokens = jnp.pad(draft_tokens, (0, tp - t))
        # padded rows have z1 = z2 = NEG, so relax is False for any theta
        theta_arr = jnp.pad(theta_arr, (0, tp - t), constant_values=1.0)
    n_vblocks = vp // bv
    grid = (tp // bt, n_vblocks)
    out_shapes = [
        jax.ShapeDtypeStruct((tp,), jnp.float32),   # z1
        jax.ShapeDtypeStruct((tp,), jnp.int32),     # i1
        jax.ShapeDtypeStruct((tp,), jnp.float32),   # z2
        jax.ShapeDtypeStruct((tp,), jnp.int32),     # i2
        jax.ShapeDtypeStruct((tp,), jnp.int32),     # exact
        jax.ShapeDtypeStruct((tp,), jnp.int32),     # relax
    ]
    row_spec = pl.BlockSpec((bt,), lambda i, j: (i,))
    outs = pl.pallas_call(
        functools.partial(_kernel, bv=bv, n_vblocks=n_vblocks),
        grid=grid,
        in_specs=[
            row_spec,
            pl.BlockSpec((bt, bv), lambda i, j: (i, j)),
            row_spec,
        ],
        out_specs=[row_spec] * 6,
        out_shape=out_shapes,
        interpret=interpret,
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
    )(draft_tokens, logits, theta_arr)
    z1, i1, z2, i2, exact, relax = outs
    sl = slice(0, t)
    return (exact[sl].astype(bool), relax[sl].astype(bool),
            i1[sl], i2[sl], z1[sl], z2[sl])
