"""Public jit'd wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode —
the kernel body runs as traced JAX ops, validating semantics; on TPU the
same code lowers through Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attn import (decode_attention_kernel,
                                       paged_decode_attention_kernel)
from repro.kernels.mars_verify import mars_verify_kernel
from repro.kernels.ssd_chunk import ssd_chunk_kernel


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _mars_verify_all(draft_tokens, logits, theta):
    b, k = draft_tokens.shape
    v = logits.shape[-1]
    flat_d = draft_tokens.reshape(b * k)
    flat_l = logits.reshape(b * k, v)
    # theta: scalar (one threshold for all rows), (B,) per batch row, or
    # (B, K) per position — always lands on the kernel as one value/row
    th = jnp.asarray(theta, jnp.float32)
    if th.ndim == 1:
        th = th[:, None]
    flat_t = jnp.broadcast_to(th, (b, k)).reshape(b * k)
    outs = mars_verify_kernel(flat_d, flat_l, flat_t,
                              interpret=_interpret())
    return tuple(x.reshape(b, k) for x in outs)


def mars_verify(draft_tokens: jnp.ndarray, logits: jnp.ndarray, theta):
    """Fused verify for (B, K) drafts against (B, K, V) logits.

    ``theta`` may be a scalar, per-batch-row ``(B,)``, or per-position
    ``(B, K)``.  Returns (exact, relax, top1, top2), each (B, K)."""
    exact, relax, t1, t2, _, _ = _mars_verify_all(draft_tokens, logits, theta)
    return exact, relax, t1, t2


def mars_verify_stats(draft_tokens: jnp.ndarray, logits: jnp.ndarray, theta):
    """Like :func:`mars_verify` but also returns the top-2 logit values the
    kernel already holds — (exact, relax, top1, top2, z1, z2) — so callers
    can derive the acceptance margin without a second vocab pass."""
    return _mars_verify_all(draft_tokens, logits, theta)


def decode_attention(q, k, v, k_pos, q_pos, *, window: int = 0,
                     block_len: int = 512):
    return decode_attention_kernel(q, k, v, k_pos, q_pos, window=window,
                                   block_len=block_len,
                                   interpret=_interpret())


def paged_decode_attention(q, k_pool, v_pool, table, k_pos, q_pos, *,
                           k_scale=None, v_scale=None, window: int = 0):
    """Flash-decode over a paged cache (``repro.models.paging`` layout):
    the block table is scalar-prefetched so the kernel reads physical pool
    blocks directly — no host- or device-side gather of a dense view.
    Quantized pools pass their scale pools as ``k_scale``/``v_scale``; the
    kernel prefetches each block's scale row with its payload and
    dequantizes inside the gather."""
    return paged_decode_attention_kernel(q, k_pool, v_pool, table, k_pos,
                                         q_pos, k_scale=k_scale,
                                         v_scale=v_scale, window=window,
                                         interpret=_interpret())


def ssd_chunk(c, b, v, cum, scale, h0):
    return ssd_chunk_kernel(c, b, v, cum, scale, h0, interpret=_interpret())
