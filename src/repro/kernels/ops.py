"""Public jit'd wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode —
the kernel body runs as traced JAX ops, validating semantics; on TPU the
same code lowers through Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attn import (decode_attention_kernel,
                                       paged_decode_attention_kernel)
from repro.kernels.mars_verify import mars_verify_kernel
from repro.kernels.ssd_chunk import ssd_chunk_kernel


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def mars_verify(draft_tokens: jnp.ndarray, logits: jnp.ndarray,
                theta: float):
    """Fused verify for (B, K) drafts against (B, K, V) logits.

    Returns (exact, relax, top1, top2), each (B, K)."""
    b, k = draft_tokens.shape
    v = logits.shape[-1]
    flat_d = draft_tokens.reshape(b * k)
    flat_l = logits.reshape(b * k, v)
    exact, relax, t1, t2 = mars_verify_kernel(
        flat_d, flat_l, theta, interpret=_interpret())
    rs = lambda x: x.reshape(b, k)
    return rs(exact), rs(relax), rs(t1), rs(t2)


def decode_attention(q, k, v, k_pos, q_pos, *, window: int = 0,
                     block_len: int = 512):
    return decode_attention_kernel(q, k, v, k_pos, q_pos, window=window,
                                   block_len=block_len,
                                   interpret=_interpret())


def paged_decode_attention(q, k_pool, v_pool, table, k_pos, q_pos, *,
                           k_scale=None, v_scale=None, window: int = 0):
    """Flash-decode over a paged cache (``repro.models.paging`` layout):
    the block table is scalar-prefetched so the kernel reads physical pool
    blocks directly — no host- or device-side gather of a dense view.
    Quantized pools pass their scale pools as ``k_scale``/``v_scale``; the
    kernel prefetches each block's scale row with its payload and
    dequantizes inside the gather."""
    return paged_decode_attention_kernel(q, k_pool, v_pool, table, k_pos,
                                         q_pos, k_scale=k_scale,
                                         v_scale=v_scale, window=window,
                                         interpret=_interpret())


def ssd_chunk(c, b, v, cum, scale, h0):
    return ssd_chunk_kernel(c, b, v, cum, scale, h0, interpret=_interpret())
