"""Flash-decode GQA attention kernels (single query step per sequence).

The verify pass is memory-bound: per new token the whole KV cache streams
from HBM once.  These kernels tile the cache length into VMEM blocks and
keep the online-softmax state (m, l, acc) in revisited output refs, so HBM
traffic is exactly one read of K and V plus O(H·D) output — the roofline
minimum.

Two layouts share the same kernel body:

* ``decode_attention_kernel`` — dense per-slot ring: grid (B, L / BL),
  block j of row i is the contiguous slice ``k[i, j*BL:(j+1)*BL]``.
* ``paged_decode_attention_kernel`` — block-table cache
  (``repro.models.paging``): the table rides in as a **scalar-prefetch**
  operand (``pltpu.PrefetchScalarGridSpec``), so the k/v BlockSpec index
  map resolves ``table[i, j]`` *before* the kernel body runs and the DMA
  engine fetches physical pool block ``table[i, j]`` directly from HBM —
  the gather costs nothing over the dense layout.  Tables may alias the
  same physical block across batch rows (shared prefix blocks under the
  serving prefix cache): the kernel only ever reads through the table, so
  aliasing is free — two rows DMA the same block independently.

Block shapes: q (1, H, D); k/v (1, BL, Hkv, D).  D and BL are chosen
lane-aligned (multiples of 128) by the wrapper; for the paged kernel BL is
the pool's ``block_size``, so pick a lane-aligned block size on real TPUs.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params as _compiler_params

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, kpos_ref, qpos_ref,
            o_ref, m_ref, l_ref, *, bl: int, n_lblocks: int, window: int,
            hkv: int, g: int, d: int, ks_ref=None, vs_ref=None):
    lb = pl.program_id(1)

    @pl.when(lb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[0].astype(jnp.float32)                 # (H, D)
    k = k_ref[0].astype(jnp.float32)                 # (BL, Hkv, D)
    v = v_ref[0].astype(jnp.float32)
    if ks_ref is not None:
        # quantized pool: the block's scale row rode in with it — dequant
        # in-register, the dense f32 view never exists outside VMEM
        k = k * ks_ref[0].astype(jnp.float32)[..., None]
        v = v * vs_ref[0].astype(jnp.float32)[..., None]
    kpos = kpos_ref[0]                               # (BL,)
    qpos = qpos_ref[0]                               # scalar-ish (1,)

    qg = q.reshape(hkv, g, d)
    scores = jax.lax.dot_general(
        qg, k, (((2,), (2,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)          # (Hkv, G, BL)
    scores = scores * (1.0 / math.sqrt(d))

    valid = (kpos >= 0) & (kpos <= qpos)
    if window > 0:
        valid &= kpos > (qpos - window)
    scores = jnp.where(valid[None, None, :], scores, NEG)

    m_prev = m_ref[...].reshape(hkv, g)              # (Hkv, G)
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new[..., None])           # (Hkv, G, BL)
    l_new = l_ref[...].reshape(hkv, g) * alpha + jnp.sum(p, axis=-1)
    pv = jax.lax.dot_general(
        p, v, (((2,), (0,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)          # (Hkv, G, D)
    acc = o_ref[...].reshape(hkv, g, d) * alpha[..., None] + pv

    m_ref[...] = m_new.reshape(1, hkv * g)
    l_ref[...] = l_new.reshape(1, hkv * g)
    o_ref[...] = acc.reshape(1, hkv * g, d)

    @pl.when(lb == n_lblocks - 1)
    def _finish():
        l = l_ref[...].reshape(hkv, g)
        o_ref[...] = (o_ref[...].reshape(hkv, g, d)
                      / jnp.maximum(l, 1e-30)[..., None]).reshape(1, hkv * g, d)


@functools.partial(
    jax.jit, static_argnames=("window", "block_len", "interpret"))
def decode_attention_kernel(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                            k_pos: jnp.ndarray, q_pos: jnp.ndarray, *,
                            window: int = 0, block_len: int = 512,
                            interpret: bool = False) -> jnp.ndarray:
    """q: (B, H, D); k/v: (B, L, Hkv, D); k_pos: (B, L); q_pos: (B,).

    Returns (B, H, D) attention output (float32)."""
    b, h, d = q.shape
    l, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    bl = min(block_len, l)
    lp = -(-l // bl) * bl
    if lp != l:
        k = jnp.pad(k, ((0, 0), (0, lp - l), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, lp - l), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, lp - l)), constant_values=-1)
    n_lblocks = lp // bl
    grid = (b, n_lblocks)

    out, _, _ = pl.pallas_call(
        functools.partial(_kernel, bl=bl, n_lblocks=n_lblocks, window=window,
                          hkv=hkv, g=g, d=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, h, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, bl, hkv, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, bl, hkv, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, bl), lambda i, j: (i, j)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((1, h, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, h), lambda i, j: (i, 0)),
            pl.BlockSpec((1, h), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, d), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=_compiler_params(dimension_semantics=("parallel", "arbitrary")),
    )(q, k, v, k_pos, q_pos)
    return out


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_attention_kernel(q: jnp.ndarray, k_pool: jnp.ndarray,
                                  v_pool: jnp.ndarray, table: jnp.ndarray,
                                  k_pos: jnp.ndarray, q_pos: jnp.ndarray, *,
                                  k_scale: jnp.ndarray = None,
                                  v_scale: jnp.ndarray = None,
                                  window: int = 0,
                                  interpret: bool = False) -> jnp.ndarray:
    """q: (B, H, D); k_pool/v_pool: (N, bs, Hkv, D); table: (B, MB) physical
    block ids; k_pos: (B, MB*bs) logical positions; q_pos: (B,).

    Returns (B, H, D) attention output (float32).  Semantically equal to
    ``decode_attention_kernel`` over the gathered dense view
    ``pool[table].reshape(B, MB*bs, ...)`` — but nothing is gathered: the
    scalar-prefetched table drives the k/v block index map, so each grid
    step DMAs one pool block straight from HBM.

    Quantized pools (``repro.models.paging`` kv_dtype int8/fp8) pass the
    parallel scale pools ``k_scale``/``v_scale`` (N, bs, Hkv): their
    BlockSpec index map reads the same scalar-prefetched ``table[i, j]``
    entry, so each grid step's DMA brings the block's scale row in
    alongside its payload and the kernel dequantizes inside the gather —
    a dense dequantized view is never materialised in HBM.
    """
    b, h, d = q.shape
    n, bs, hkv, _ = k_pool.shape
    mb = table.shape[1]
    g = h // hkv
    quant = k_scale is not None

    in_specs = [
        pl.BlockSpec((1, h, d), lambda i, j, tbl: (i, 0, 0)),
        pl.BlockSpec((1, bs, hkv, d),
                     lambda i, j, tbl: (tbl[i, j], 0, 0, 0)),
        pl.BlockSpec((1, bs, hkv, d),
                     lambda i, j, tbl: (tbl[i, j], 0, 0, 0)),
    ]
    if quant:
        in_specs += [
            pl.BlockSpec((1, bs, hkv), lambda i, j, tbl: (tbl[i, j], 0, 0)),
            pl.BlockSpec((1, bs, hkv), lambda i, j, tbl: (tbl[i, j], 0, 0)),
        ]
    in_specs += [
        pl.BlockSpec((1, bs), lambda i, j, tbl: (i, j)),
        pl.BlockSpec((1,), lambda i, j, tbl: (i,)),
    ]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,           # the block table
        grid=(b, mb),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, h, d), lambda i, j, tbl: (i, 0, 0)),
            pl.BlockSpec((1, h), lambda i, j, tbl: (i, 0)),
            pl.BlockSpec((1, h), lambda i, j, tbl: (i, 0)),
        ],
    )

    if quant:
        def kernel(tbl_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, kpos_ref,
                   qpos_ref, o_ref, m_ref, l_ref):
            _kernel(q_ref, k_ref, v_ref, kpos_ref, qpos_ref, o_ref, m_ref,
                    l_ref, bl=bs, n_lblocks=mb, window=window, hkv=hkv,
                    g=g, d=d, ks_ref=ks_ref, vs_ref=vs_ref)
        operands = (table, q, k_pool, v_pool, k_scale, v_scale, k_pos, q_pos)
    else:
        def kernel(tbl_ref, q_ref, k_ref, v_ref, kpos_ref, qpos_ref,
                   o_ref, m_ref, l_ref):
            _kernel(q_ref, k_ref, v_ref, kpos_ref, qpos_ref, o_ref, m_ref,
                    l_ref, bl=bs, n_lblocks=mb, window=window, hkv=hkv,
                    g=g, d=d)
        operands = (table, q, k_pool, v_pool, k_pos, q_pos)

    out, _, _ = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, h, d), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
    )(*operands)
    return out
