"""SSD chunk kernel: intra-chunk linear-recurrence compute for Mamba2/xLSTM.

Computes, for one (batch, head) tile and one chunk of length Q:

    scores[l, s] = (C_l · B_s) * exp(cum_l - cum_s) * scale_s   (s <= l)
    Y_intra      = scores @ V                                (Q, P)
    state        = (B * w)^T @ V,  w_s = exp(cum_Q - cum_s) * scale_s
    Y_inter      = (C * exp(cum)) @ H_prev                    (Q, P)

i.e. everything inside one chunk of ``chunked_linear_recurrence`` — the MXU
matmul-heavy part.  The cross-chunk scan stays in XLA (it is a tiny
(N, P)-state recurrence).  Q, N, P are picked MXU-friendly by the caller
(Q=128/256, N=64/128, P=64/128).

Grid: (B, H) — fully parallel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params as _compiler_params


def _kernel(c_ref, b_ref, v_ref, cum_ref, scale_ref, h0_ref,
            y_ref, state_ref):
    c = c_ref[0, :, 0].astype(jnp.float32)       # (Q, N)
    bmat = b_ref[0, :, 0].astype(jnp.float32)    # (Q, N)
    vmat = v_ref[0, :, 0].astype(jnp.float32)    # (Q, P)
    cum = cum_ref[0, :, 0].astype(jnp.float32)   # (Q,)
    scale = scale_ref[0, :, 0].astype(jnp.float32)
    h0 = h0_ref[0, 0].astype(jnp.float32)        # (N, P)

    q = c.shape[0]
    li = cum[:, None]
    si = cum[None, :]
    decay = jnp.exp(jnp.minimum(li - si, 0.0))
    rows = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    decay = jnp.where(rows >= cols, decay, 0.0)

    scores = jax.lax.dot_general(
        c, bmat, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)      # (Q, Q)
    scores = scores * decay * scale[None, :]
    y_intra = jnp.dot(scores, vmat, preferred_element_type=jnp.float32)

    total = cum[-1]
    w = jnp.exp(total - cum) * scale             # (Q,)
    state = jax.lax.dot_general(
        bmat * w[:, None], vmat, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)      # (N, P)
    state = state + jnp.exp(total) * h0

    y_inter = jnp.dot(c * jnp.exp(cum)[:, None], h0,
                      preferred_element_type=jnp.float32)

    y_ref[...] = (y_intra + y_inter)[None, :, None]
    state_ref[...] = state[None, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_kernel(c, b, v, cum, scale, h0, *, interpret: bool = False):
    """One chunk for all (batch, head) tiles.

    c, b : (B, Q, H, N);  v: (B, Q, H, P);  cum/scale: (B, Q, H);
    h0   : (B, H, N, P)   — state entering the chunk.

    Returns (y (B, Q, H, P), state_out (B, H, N, P))."""
    bsz, q, h, n = b.shape
    p = v.shape[-1]
    grid = (bsz, h)
    y, state = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q, 1, n), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, q, 1, n), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, q, 1, p), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, q, 1), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, q, 1), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, 1, n, p), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, 1, p), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, 1, n, p), lambda i, j: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, q, h, p), jnp.float32),
            jax.ShapeDtypeStruct((bsz, h, n, p), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=_compiler_params(dimension_semantics=("parallel", "parallel")),
    )(c, b, v, cum, scale, h0)
    return y, state
