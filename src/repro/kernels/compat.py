"""Pallas API compatibility shims.

JAX renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` across
releases; every kernel in this package routes through :func:`compiler_params`
so either JAX works (and very old JAX without the class degrades to None,
which ``pallas_call`` accepts).
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_CompilerParams = getattr(pltpu, "TPUCompilerParams",
                          getattr(pltpu, "CompilerParams", None))


def compiler_params(**kwargs):
    if _CompilerParams is None:
        return None
    return _CompilerParams(**kwargs)
