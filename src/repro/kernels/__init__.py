"""Pallas TPU kernels for the performance-critical compute of MARS serving:

* ``mars_verify`` — fused top-2 + logit-ratio + accept decision in one HBM
  pass over the target logits (the paper's verification rule as a kernel).
* ``decode_attn`` — flash-decode GQA attention over the KV cache (the
  memory-bound core of the parallel verify pass).
* ``ssd_chunk``  — Mamba2/xLSTM chunked linear-recurrence inner step.

Each kernel ships with ``ref.py`` oracles (pure jnp) and is validated in
``interpret=True`` mode on CPU; on TPU the same ``pl.pallas_call`` lowers to
Mosaic.  ``ops.py`` holds the jit'd public wrappers.
"""
from repro.kernels import ops

__all__ = ["ops"]
