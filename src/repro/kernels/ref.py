"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def mars_verify_ref(draft_tokens: jnp.ndarray, logits: jnp.ndarray,
                    theta):
    """Oracle for mars_verify_kernel: (exact, relax, top1, top2).

    ``theta`` is a scalar or any shape broadcastable against
    ``draft_tokens`` (per-row thresholds), in lockstep with the kernel's
    per-row theta operand."""
    vals, idx = jax.lax.top_k(logits.astype(jnp.float32), 2)
    z1, z2 = vals[..., 0], vals[..., 1]
    top1, top2 = idx[..., 0], idx[..., 1]
    theta = jnp.asarray(theta, jnp.float32)
    exact = draft_tokens == top1
    relax = ((draft_tokens == top2) & (z1 > 0.0) & (z2 > 0.0)
             & (z2 > theta * z1))
    return exact, relax, top1.astype(jnp.int32), top2.astype(jnp.int32)


def decode_attention_ref(q, k, v, k_pos, q_pos, *, window: int = 0):
    """Oracle for decode_attention_kernel.  q: (B,H,D); k/v: (B,L,Hkv,D)."""
    b, h, d = q.shape
    l, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    scores = jnp.einsum("bkgd,blkd->bkgl", qg, kf) / math.sqrt(d)
    valid = (k_pos >= 0) & (k_pos <= q_pos[:, None])
    if window > 0:
        valid &= k_pos > (q_pos[:, None] - window)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgl,blkd->bkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, h, d)


def ssd_chunk_ref(c, b, v, cum, scale, h0):
    """Oracle for ssd_chunk_kernel (one chunk, batched over B,H)."""
    li = cum[:, :, None, :]
    si = cum[:, None, :, :]
    decay = jnp.exp(jnp.minimum(li - si, 0.0))        # (B,Q,Q,H)
    q = cum.shape[1]
    mask = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(mask[None, :, :, None], decay, 0.0)
    scores = jnp.einsum("blhn,bshn->blsh", c.astype(jnp.float32),
                        b.astype(jnp.float32))
    scores = scores * decay * scale[:, None, :, :]
    y_intra = jnp.einsum("blsh,bshp->blhp", scores, v.astype(jnp.float32))

    total = cum[:, -1]                                 # (B,H)
    w = jnp.exp(total[:, None] - cum) * scale          # (B,Q,H)
    state = jnp.einsum("bshn,bshp->bhnp", b * w[..., None],
                       v.astype(jnp.float32))
    state = state + jnp.exp(total)[..., None, None] * h0

    y_inter = jnp.einsum("blhn,bhnp->blhp",
                         c * jnp.exp(cum)[..., None], h0)
    return y_intra + y_inter, state
